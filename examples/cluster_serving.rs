//! Sharded multi-worker serving end to end: a heterogeneous
//! code-writer + deep-research workload offered to a 4-shard
//! `ClusterEngine`, comparing the agent-oblivious round-robin baseline
//! with KV-aware agent-affinity routing (plus cross-worker migration).
//!
//!     cargo run --release --example cluster_serving
//!
//! The single-worker analogue is `examples/e2e_serving.rs`; this one runs
//! on the discrete-event substrate so it needs no PJRT artifacts.

use tokencake::cluster::ClusterEngine;
use tokencake::config::{
    ClusterConfig, Mode, PlacementPolicy, ServeConfig,
};
use tokencake::graph::templates;
use tokencake::workload::{ClusterWorkload, Dataset};

fn main() {
    let workload = ClusterWorkload::mixed(
        &[
            (templates::code_writer(), 2.0),
            (templates::deep_research(), 1.0),
        ],
        1.5,
        30,
    )
    .with_dataset(Dataset::D1);

    println!("=== TokenCake cluster serving (4 shards, mixed workload) ===");
    println!(
        "offered load: {} apps at {} QPS, mix 2:1 code-writer:deep-research\n",
        workload.num_apps, workload.qps
    );

    for placement in [
        PlacementPolicy::RoundRobin,
        PlacementPolicy::LeastLoaded,
        PlacementPolicy::AgentAffinity,
    ] {
        let serve = ServeConfig::default()
            .with_mode(Mode::TokenCake)
            .with_seed(42)
            .with_gpu_mem_frac(0.06);
        let cfg = ClusterConfig::default()
            .with_serve(serve)
            .with_shards(4)
            .with_placement(placement);
        let report = ClusterEngine::new(cfg).run(&workload);
        for line in report.shard_lines() {
            println!("{line}");
        }
        println!("{}\n", report.summary());
        assert_eq!(report.aggregate.apps_completed, 30);
    }
    println!("cluster example OK");
}
