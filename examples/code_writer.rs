//! The paper's Code-Writer benchmark application (§7.1, Fig 1a): 11 agent
//! types with frequent function calls to file I/O, search, git, and
//! external test tools — the high-memory-pressure workload.
//!
//!     cargo run --release --example code_writer [qps] [apps]
//!
//! Runs the full system-mode comparison (Fig 9's configuration at one
//! load point) and prints per-mode metrics plus TokenCake's scheduler
//! internals.

use tokencake::config::{Mode, ServeConfig};
use tokencake::engine::sim::SimEngine;
use tokencake::graph::templates;
use tokencake::workload::{Dataset, WorkloadSpec};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let qps: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1.0);
    let apps: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(20);

    let graph = templates::code_writer();
    println!(
        "Code-Writer: {} agents ({} types), depth {}, {} QPS, {} apps",
        graph.len(),
        graph.agent_types().len(),
        graph.max_depth(),
        qps,
        apps
    );
    let spec =
        WorkloadSpec::poisson(&graph, qps, apps).with_dataset(Dataset::D1);

    for mode in [
        Mode::Vllm,
        Mode::VllmPrefix,
        Mode::Mooncake,
        Mode::Parrot,
        Mode::TokenCake,
    ] {
        let cfg = ServeConfig::default()
            .with_mode(mode)
            .with_seed(0xC0DE)
            .with_gpu_mem_frac(0.08);
        let mut engine = SimEngine::new(cfg);
        let report = engine.run_workload(&spec);
        println!("{}", report.summary());
        if mode == Mode::TokenCake {
            let c = &report.metrics.counters;
            println!(
                "    scheduler internals: reserved_admissions={} \
                 deferrals={} offload_rejects={} early_returns={} \
                 prefix_hits={}+{}",
                c.reserved_admissions,
                c.deferrals,
                c.offloads_rejected,
                c.early_returns,
                c.prefix_hits_gpu,
                c.prefix_hits_cpu
            );
            println!(
                "    peak stalled KV fraction: {:.1}% (Fig 2a view)",
                report.metrics.stalled_fraction.max() * 100.0
            );
        }
    }
}
