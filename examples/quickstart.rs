//! Quickstart: define a multi-agent app graph, run it through TokenCake's
//! simulated serving engine, and compare against the vLLM baseline.
//!
//!     cargo run --release --example quickstart
//!
//! This is the Fig 5 RAG application plus the Code-Writer template, at a
//! load high enough to create real memory contention.

use tokencake::config::{Mode, ServeConfig};
use tokencake::engine::sim::SimEngine;
use tokencake::graph::{templates, CallSpec, FuncKind, GraphBuilder};
use tokencake::workload::{Dataset, WorkloadSpec};

fn main() {
    // ---- 1. Define an application as a DAG (the §3.1 frontend API). ----
    let mut gb = GraphBuilder::new("my-rag");
    let retriever = gb.agent_with_call(
        "retriever",
        "retriever",
        256,
        &[48, 96],
        CallSpec::new(FuncKind::WebSearch)
            .with_predict_time_us(3_000_000) // predict_time hint (Eq. 1)
            .with_stages(2),
    );
    let generator = gb.agent("generator", "generator", 192, &[384]);
    gb.edge(retriever, generator);
    let rag = gb.build().expect("valid DAG");
    println!("registered graph '{}' with {} nodes", rag.name, rag.len());
    println!(
        "  critical path: {:?}",
        rag.nodes()
            .filter(|n| rag.is_critical(n.id))
            .map(|n| n.name.as_str())
            .collect::<Vec<_>>()
    );

    // ---- 2. Serve it under TokenCake. ----
    let cfg = ServeConfig::default()
        .with_mode(Mode::TokenCake)
        .with_gpu_mem_frac(0.05) // induce memory pressure
        .with_seed(42);
    let spec =
        WorkloadSpec::poisson(&rag, 1.0, 24).with_dataset(Dataset::D1);
    let report = SimEngine::new(cfg.clone()).run_workload(&spec);
    println!("\nRAG app, 24 instances @ 1.0 QPS:");
    println!("  {}", report.summary());

    // ---- 3. Compare modes on the paper's Code-Writer workload. ----
    let cw = templates::code_writer();
    let spec =
        WorkloadSpec::poisson(&cw, 0.5, 20).with_dataset(Dataset::D1);
    println!("\nCode-Writer, 20 apps @ 0.5 QPS (gpu_mem_frac=0.05):");
    for mode in [Mode::Vllm, Mode::Mooncake, Mode::TokenCake] {
        let cfg = cfg.clone().with_mode(mode);
        let report = SimEngine::new(cfg).run_workload(&spec);
        println!("  {}", report.summary());
    }
}
