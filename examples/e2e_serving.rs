//! End-to-end validation (DESIGN.md §6): load the real TinyQwen PJRT
//! artifacts, register a multi-agent application, and serve batched
//! requests through the full TokenCake stack — frontend graph → pressure
//! snapshot → spatial reservations → temporal offload/upload → real
//! prefill/decode on the AOT-compiled model — reporting latency and
//! throughput.
//!
//!     make artifacts && cargo run --release --example e2e_serving
//!
//! All three layers execute for real: L1 Pallas kernels (inside the HLO),
//! L2 TinyQwen, L3 the Rust coordinator. Python is not running.

use tokencake::config::Mode;
use tokencake::engine::real::{real_engine_config, RealEngine};
use tokencake::graph::{CallSpec, FuncKind, GraphBuilder};

fn small_pipeline() -> tokencake::graph::AppGraph {
    // A compact 3-agent pipeline with one function call, sized so each
    // agent fits a 256-token TinyQwen slot.
    let mut gb = GraphBuilder::new("e2e-pipeline");
    let planner = gb.agent("planner", "planner", 24, &[16]);
    // Critical branch: heavy worker with a long web-search stall.
    let worker = gb.agent_with_call(
        "worker",
        "worker",
        32,
        &[24, 16],
        CallSpec::new(FuncKind::WebSearch).with_predict_time_us(2_500_000),
    );
    // Non-critical side branch: its stalled cache is the offload target.
    let logger = gb.agent_with_call(
        "logger",
        "logger",
        16,
        &[8, 8],
        CallSpec::new(FuncKind::UserConfirm).with_predict_time_us(6_000_000),
    );
    gb.tune_last(|s| s.static_priority = 0.1);
    let summarizer = gb.agent("summarizer", "summarizer", 24, &[24]);
    gb.edge(planner, worker);
    gb.edge(planner, logger);
    gb.edge(worker, summarizer);
    gb.edge(logger, summarizer);
    gb.build().unwrap()
}

fn main() -> anyhow::Result<()> {
    let artifacts = tokencake::runtime::artifacts_dir();
    if !artifacts.join("manifest.txt").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(1);
    }

    let graph = small_pipeline();
    println!("=== TokenCake end-to-end serving (real PJRT TinyQwen) ===");
    println!(
        "app '{}': {} agents, critical path {:?}",
        graph.name,
        graph.len(),
        graph
            .nodes()
            .filter(|n| graph.is_critical(n.id))
            .map(|n| n.name.as_str())
            .collect::<Vec<_>>()
    );

    for mode in [Mode::Vllm, Mode::TokenCake] {
        let cfg = real_engine_config(mode, 42);
        let mut engine = RealEngine::new(cfg, &artifacts)?;
        // 12 apps → 48 agents over 8 slots: real contention.
        let report = engine.serve(&graph, 12, 400_000)?;
        println!("[{}] {}", mode.name(), report.summary());
        assert_eq!(report.metrics.apps_completed, 12);
        assert!(report.tokens_generated > 0);
    }
    println!("e2e OK — all layers composed");
    Ok(())
}
