//! The paper's Deep-Research benchmark application (§7.1, Fig 1b): fewer
//! agents, deeper dependency chains — the critical-path stress test.
//!
//!     cargo run --release --example deep_research [qps] [apps]
//!
//! Also demonstrates the §3.1 frontend metadata: per-node criticality and
//! the effect of user-supplied `predict_time` hints on the Temporal
//! Scheduler's first predictions.

use tokencake::config::{Mode, ServeConfig};
use tokencake::engine::sim::SimEngine;
use tokencake::graph::{templates, NodeKind};
use tokencake::workload::{Dataset, WorkloadSpec};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let qps: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.5);
    let apps: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(20);

    let graph = templates::deep_research();
    println!("Deep-Research graph:");
    for node in graph.nodes() {
        let crit = if graph.is_critical(node.id) { "CRIT" } else { "    " };
        let hint = match &node.kind {
            NodeKind::Agent(a) => a
                .phases
                .iter()
                .filter_map(|p| p.call.as_ref())
                .map(|c| {
                    format!(
                        "{}~{}ms",
                        c.kind.name(),
                        c.predict_time_us.unwrap_or(0) / 1000
                    )
                })
                .collect::<Vec<_>>()
                .join(","),
            NodeKind::Func(c) => c.kind.name().to_string(),
        };
        println!(
            "  {crit} depth={} {:<14} {}",
            graph.depth(node.id),
            node.name,
            hint
        );
    }

    let spec =
        WorkloadSpec::poisson(&graph, qps, apps).with_dataset(Dataset::D2);
    println!("\n{} QPS, {} apps, dataset {}:", qps, apps,
             spec.dataset.name());
    for mode in [Mode::Vllm, Mode::Mooncake, Mode::AgentOnly,
                 Mode::OffloadOnly, Mode::TokenCake] {
        let cfg = ServeConfig::default()
            .with_mode(mode)
            .with_seed(0xD0C5)
            .with_gpu_mem_frac(0.06);
        let report = SimEngine::new(cfg).run_workload(&spec);
        println!("{}", report.summary());
    }
}
