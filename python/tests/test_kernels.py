"""Layer-1 correctness: Pallas kernels (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; every property asserts allclose against
ref.py. This is the core numeric signal for the whole stack — the AOT
artifacts embed exactly these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as A
from compile.kernels import ref as R

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=8, deadline=None)


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash_prefill
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 2]),
    h=st.sampled_from([1, 2, 4]),
    t=st.sampled_from([64, 128, 256]),
    d=st.sampled_from([32, 64]),
    block_q=st.sampled_from([32, 64]),
    block_k=st.sampled_from([32, 64]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**16),
)
def test_flash_prefill_matches_ref(b, h, t, d, block_q, block_k, dtype, seed):
    key = jax.random.PRNGKey(seed)
    q = rand(jax.random.fold_in(key, 0), (b, h, t, d), dtype)
    k = rand(jax.random.fold_in(key, 1), (b, h, t, d), dtype)
    v = rand(jax.random.fold_in(key, 2), (b, h, t, d), dtype)
    out = A.flash_prefill(q, k, v, block_q=block_q, block_k=block_k)
    ref = R.ref_flash_prefill(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32))
    np.testing.assert_allclose(out.astype(jnp.float32), ref, **tol(dtype))


def test_flash_prefill_is_causal():
    """Perturbing future keys/values must not change earlier outputs."""
    key = jax.random.PRNGKey(3)
    b, h, t, d = 1, 2, 128, 64
    q = rand(jax.random.fold_in(key, 0), (b, h, t, d), jnp.float32)
    k = rand(jax.random.fold_in(key, 1), (b, h, t, d), jnp.float32)
    v = rand(jax.random.fold_in(key, 2), (b, h, t, d), jnp.float32)
    out1 = A.flash_prefill(q, k, v)
    k2 = k.at[:, :, t // 2:, :].set(99.0)
    v2 = v.at[:, :, t // 2:, :].set(-99.0)
    out2 = A.flash_prefill(q, k2, v2)
    np.testing.assert_allclose(out1[:, :, : t // 2], out2[:, :, : t // 2],
                               rtol=1e-6, atol=1e-6)


def test_flash_prefill_single_tile():
    """T == block covers the degenerate single-tile path."""
    key = jax.random.PRNGKey(4)
    q = rand(jax.random.fold_in(key, 0), (1, 1, 64, 32), jnp.float32)
    k = rand(jax.random.fold_in(key, 1), (1, 1, 64, 32), jnp.float32)
    v = rand(jax.random.fold_in(key, 2), (1, 1, 64, 32), jnp.float32)
    out = A.flash_prefill(q, k, v, block_q=64, block_k=64)
    ref = R.ref_flash_prefill(q, k, v)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# masked_decode
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 2, 4, 8]),
    h=st.sampled_from([1, 2]),
    s=st.sampled_from([64, 128, 256]),
    d=st.sampled_from([32, 64]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**16),
)
def test_masked_decode_matches_ref(b, h, s, d, dtype, seed):
    key = jax.random.PRNGKey(seed)
    q = rand(jax.random.fold_in(key, 0), (b, h, d), dtype)
    kc = rand(jax.random.fold_in(key, 1), (b, s, h, d), dtype)
    vc = rand(jax.random.fold_in(key, 2), (b, s, h, d), dtype)
    lens = jax.random.randint(jax.random.fold_in(key, 3), (b,), 1, s + 1)
    out = A.masked_decode(q, kc, vc, lens)
    ref = R.ref_masked_decode(q.astype(jnp.float32),
                              kc.astype(jnp.float32),
                              vc.astype(jnp.float32), lens)
    np.testing.assert_allclose(out.astype(jnp.float32), ref, **tol(dtype))


def test_masked_decode_ignores_tail():
    """Entries at positions >= lens must not affect the output."""
    key = jax.random.PRNGKey(5)
    b, h, s, d = 2, 2, 128, 64
    q = rand(jax.random.fold_in(key, 0), (b, h, d), jnp.float32)
    kc = rand(jax.random.fold_in(key, 1), (b, s, h, d), jnp.float32)
    vc = rand(jax.random.fold_in(key, 2), (b, s, h, d), jnp.float32)
    lens = jnp.array([50, 100], jnp.int32)
    out1 = A.masked_decode(q, kc, vc, lens)
    kc2 = kc.at[:, 100:, :, :].set(1e4)
    vc2 = vc.at[:, 100:, :, :].set(-1e4)
    out2 = A.masked_decode(q, kc2, vc2, lens)
    np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)


def test_masked_decode_len_one():
    """lens=1 attends only to position 0 -> output equals v[0]."""
    key = jax.random.PRNGKey(6)
    b, h, s, d = 1, 2, 64, 32
    q = rand(jax.random.fold_in(key, 0), (b, h, d), jnp.float32)
    kc = rand(jax.random.fold_in(key, 1), (b, s, h, d), jnp.float32)
    vc = rand(jax.random.fold_in(key, 2), (b, s, h, d), jnp.float32)
    out = A.masked_decode(q, kc, vc, jnp.array([1], jnp.int32))
    np.testing.assert_allclose(out[0], vc[0, 0], rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# paged_decode
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 2, 4]),
    h=st.sampled_from([1, 2]),
    page=st.sampled_from([8, 16]),
    pps=st.sampled_from([4, 8]),
    d=st.sampled_from([32, 64]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**16),
)
def test_paged_decode_matches_ref(b, h, page, pps, d, dtype, seed):
    key = jax.random.PRNGKey(seed)
    n_pages = b * pps + 3  # a few spare pages never referenced
    q = rand(jax.random.fold_in(key, 0), (b, h, d), dtype)
    kp = rand(jax.random.fold_in(key, 1), (n_pages, page, h, d), dtype)
    vp = rand(jax.random.fold_in(key, 2), (n_pages, page, h, d), dtype)
    # Random permutation table: distinct pages per sequence.
    perm = jax.random.permutation(jax.random.fold_in(key, 3),
                                  np.arange(n_pages))[: b * pps]
    table = perm.reshape(b, pps).astype(jnp.int32)
    lens = jax.random.randint(jax.random.fold_in(key, 4), (b,), 1,
                              page * pps + 1)
    out = A.paged_decode(q, kp, vp, table, lens)
    ref = R.ref_paged_decode(q.astype(jnp.float32), kp.astype(jnp.float32),
                             vp.astype(jnp.float32), table, lens)
    np.testing.assert_allclose(out.astype(jnp.float32), ref, **tol(dtype))


def test_paged_decode_equals_dense():
    """Paged layout with an identity block table == dense masked decode."""
    key = jax.random.PRNGKey(7)
    b, h, d, page, pps = 2, 2, 64, 16, 8
    s = page * pps
    q = rand(jax.random.fold_in(key, 0), (b, h, d), jnp.float32)
    kc = rand(jax.random.fold_in(key, 1), (b, s, h, d), jnp.float32)
    vc = rand(jax.random.fold_in(key, 2), (b, s, h, d), jnp.float32)
    lens = jnp.array([77, 128], jnp.int32)
    kp = kc.reshape(b * pps, page, h, d)
    vp = vc.reshape(b * pps, page, h, d)
    table = jnp.arange(b * pps, dtype=jnp.int32).reshape(b, pps)
    out_paged = A.paged_decode(q, kp, vp, table, lens)
    out_dense = A.masked_decode(q, kc, vc, lens)
    np.testing.assert_allclose(out_paged, out_dense, rtol=1e-6, atol=1e-6)


def test_paged_decode_scattered_table():
    """Pages placed at arbitrary physical indices — the vLLM/TokenCake case:
    logical order comes entirely from the block table."""
    key = jax.random.PRNGKey(8)
    b, h, d, page, pps = 1, 2, 32, 16, 4
    s = page * pps
    q = rand(jax.random.fold_in(key, 0), (b, h, d), jnp.float32)
    kc = rand(jax.random.fold_in(key, 1), (b, s, h, d), jnp.float32)
    vc = rand(jax.random.fold_in(key, 2), (b, s, h, d), jnp.float32)
    lens = jnp.array([s], jnp.int32)
    # Scatter logical pages to physical slots [5, 2, 7, 0] in a pool of 8.
    phys = [5, 2, 7, 0]
    kp = jnp.zeros((8, page, h, d), jnp.float32)
    vp = jnp.zeros((8, page, h, d), jnp.float32)
    for logical, physical in enumerate(phys):
        kp = kp.at[physical].set(
            kc[0, logical * page:(logical + 1) * page])
        vp = vp.at[physical].set(
            vc[0, logical * page:(logical + 1) * page])
    table = jnp.array([phys], jnp.int32)
    out_paged = A.paged_decode(q, kp, vp, table, lens)
    out_dense = A.masked_decode(q, kc, vc, lens)
    np.testing.assert_allclose(out_paged, out_dense, rtol=1e-6, atol=1e-6)
