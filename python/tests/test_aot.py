"""AOT pipeline sanity: manifest/params consistency and HLO-text validity.

These run against the generated artifacts when present (`make artifacts`),
and regenerate the manifest pieces in-memory otherwise.
"""

import os

import jax
import numpy as np
import pytest

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

CFG = M.DEFAULT_CONFIG


def test_param_manifest_roundtrip(tmp_path):
    params = M.init_params(CFG, seed=0)
    entries = aot.write_params(CFG, params, str(tmp_path))
    aot.write_manifest(CFG, entries, str(tmp_path))

    data = open(tmp_path / "params.bin", "rb").read()
    total = sum(int(np.prod(s)) for _, s in M.param_spec(CFG))
    assert len(data) == total * 4

    # Re-read each tensor at its manifest offset and compare.
    for (name, shape, offset), arr in zip(entries, params):
        n = int(np.prod(shape))
        back = np.frombuffer(data, dtype="<f4", count=n, offset=offset)
        np.testing.assert_array_equal(back, np.asarray(arr).ravel())

    manifest = open(tmp_path / "manifest.txt").read()
    assert "config vocab=512" in manifest
    assert manifest.count("param ") == len(entries)
    assert "artifact prefill" in manifest
    assert "artifact decode" in manifest


def test_params_deterministic():
    a = M.init_params(CFG, seed=0)
    b = M.init_params(CFG, seed=0)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def _hlo_or_skip(name):
    path = os.path.join(ART, name)
    if not os.path.exists(path):
        pytest.skip(f"{name} not built (run `make artifacts`)")
    return open(path).read()


@pytest.mark.parametrize("name,n_inputs", [
    ("prefill_t128.hlo.txt", len(M.param_spec(CFG)) + 2),
    ("decode_b8.hlo.txt", len(M.param_spec(CFG)) + 4),
    ("paged_attn.hlo.txt", 5),
])
def test_hlo_text_entry_signature(name, n_inputs):
    text = _hlo_or_skip(name)
    assert "ENTRY" in text
    # Every parameter appears as parameter(k) exactly once.
    for k in range(n_inputs):
        assert f"parameter({k})" in text, f"missing parameter({k}) in {name}"
    assert f"parameter({n_inputs})" not in text
    # Tuple-rooted (lowered with return_tuple=True).
    assert "ROOT" in text


def test_hlo_no_custom_calls():
    """interpret=True must lower Pallas to plain HLO — a Mosaic custom-call
    would be unloadable by the CPU PJRT client."""
    for name in ("prefill_t128.hlo.txt", "decode_b8.hlo.txt",
                 "paged_attn.hlo.txt"):
        text = _hlo_or_skip(name)
        assert "mosaic" not in text.lower(), name


def test_manifest_matches_artifacts():
    path = os.path.join(ART, "manifest.txt")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    lines = open(path).read().splitlines()
    cfg_line = [l for l in lines if l.startswith("config ")][0]
    kv = dict(p.split("=") for p in cfg_line.split()[1:])
    assert int(kv["vocab"]) == CFG.vocab
    assert int(kv["n_layers"]) == CFG.n_layers
    assert int(kv["decode_batch"]) == aot.DECODE_BATCH
    n_params = len([l for l in lines if l.startswith("param ")])
    assert n_params == len(M.param_spec(CFG))
    size = os.path.getsize(os.path.join(ART, "params.bin"))
    total = sum(int(np.prod(s)) for _, s in M.param_spec(CFG))
    assert size == total * 4
