"""Layer-2 correctness: TinyQwen prefill/decode vs the dense full-context
oracle, plus unit properties of the building blocks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig()  # default TinyQwen


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


def test_param_spec_matches_init(params):
    spec = M.param_spec(CFG)
    assert len(spec) == len(params)
    for (name, shape), arr in zip(spec, params):
        assert tuple(arr.shape) == tuple(shape), name


def test_rmsnorm_unit_scale():
    x = jnp.ones((4, CFG.d_model))
    out = M.rmsnorm(x, jnp.ones(CFG.d_model), 1e-6)
    np.testing.assert_allclose(out, x, rtol=1e-5)


def test_rmsnorm_scale_invariant_direction():
    """RMSNorm output is invariant to positive rescaling of the input."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (3, CFG.d_model))
    w = jnp.ones(CFG.d_model)
    a = M.rmsnorm(x, w, 1e-9)
    b = M.rmsnorm(x * 7.5, w, 1e-9)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_rope_preserves_norm():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (5, CFG.head_dim))
    cos, sin = M.rope_freqs(CFG, jnp.arange(5))
    y = M.apply_rope(x, cos, sin)
    np.testing.assert_allclose(jnp.linalg.norm(x, axis=-1),
                               jnp.linalg.norm(y, axis=-1), rtol=1e-5)


def test_rope_position_zero_is_identity():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (1, CFG.head_dim))
    cos, sin = M.rope_freqs(CFG, jnp.zeros((1,), jnp.int32))
    y = M.apply_rope(x, cos, sin)
    np.testing.assert_allclose(x, y, rtol=1e-6, atol=1e-6)


def test_rope_relative_property():
    """<rope(q,m), rope(k,n)> depends only on m-n (RoPE's defining property)."""
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(jax.random.fold_in(key, 0), (CFG.head_dim,))
    k = jax.random.normal(jax.random.fold_in(key, 1), (CFG.head_dim,))

    def dot_at(m, n):
        cm, sm = M.rope_freqs(CFG, jnp.array([m]))
        cn, sn = M.rope_freqs(CFG, jnp.array([n]))
        return jnp.dot(M.apply_rope(q[None], cm, sm)[0],
                       M.apply_rope(k[None], cn, sn)[0])

    np.testing.assert_allclose(dot_at(3, 1), dot_at(12, 10), rtol=1e-4)
    np.testing.assert_allclose(dot_at(7, 7), dot_at(0, 0), rtol=1e-4)


def test_prefill_matches_full_forward(params):
    """Prefill's last-token logits equal the dense oracle at true_len-1."""
    key = jax.random.PRNGKey(4)
    T = 128
    tokens = jax.random.randint(key, (1, T), 0, CFG.vocab)
    for true_len in (5, 64, T):
        logits, k_cache, v_cache = M.prefill(
            params, tokens, jnp.array([true_len], jnp.int32), CFG)
        ref = M.full_forward_ref(params, tokens, CFG)
        np.testing.assert_allclose(logits[0], ref[0, true_len - 1],
                                   rtol=2e-4, atol=2e-4)
        assert k_cache.shape == (CFG.n_layers, T, CFG.n_heads, CFG.head_dim)
        assert v_cache.shape == k_cache.shape


def test_prefill_padding_inert(params):
    """Changing pad tokens after true_len must not change the logits."""
    key = jax.random.PRNGKey(5)
    T, true_len = 128, 40
    tokens = jax.random.randint(key, (1, T), 0, CFG.vocab)
    tl = jnp.array([true_len], jnp.int32)
    a, _, _ = M.prefill(params, tokens, tl, CFG)
    tokens2 = tokens.at[0, true_len:].set(0)
    b, _, _ = M.prefill(params, tokens2, tl, CFG)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_prefill_then_decode_matches_oracle(params):
    """The serving path: prefill a prompt, then decode several steps; each
    step's logits must match the dense full-context forward."""
    key = jax.random.PRNGKey(6)
    T, S, B = 128, CFG.max_len, 8
    prompt_len, n_decode = 17, 5
    full = jax.random.randint(key, (1, prompt_len + n_decode), 0, CFG.vocab)

    # Prefill the prompt (padded to T).
    padded = jnp.zeros((1, T), jnp.int32).at[:, :prompt_len].set(
        full[:, :prompt_len])
    logits, k_pre, v_pre = M.prefill(
        params, padded, jnp.array([prompt_len], jnp.int32), CFG)
    ref = M.full_forward_ref(params, full, CFG)
    np.testing.assert_allclose(logits[0], ref[0, prompt_len - 1],
                               rtol=2e-4, atol=2e-4)

    # Scatter prefill cache into decode slot 3 of a B-slot cache.
    slot = 3
    k_cache = jnp.zeros((CFG.n_layers, B, S, CFG.n_heads, CFG.head_dim))
    v_cache = jnp.zeros_like(k_cache)
    k_cache = k_cache.at[:, slot, :prompt_len].set(k_pre[:, :prompt_len])
    v_cache = v_cache.at[:, slot, :prompt_len].set(v_pre[:, :prompt_len])

    lens_val = prompt_len
    for step in range(n_decode):
        tok = full[0, lens_val]  # teacher-forced next token
        tokens_b = jnp.zeros((B,), jnp.int32).at[slot].set(tok)
        lens_b = jnp.zeros((B,), jnp.int32).at[slot].set(lens_val)
        logits_b, k_cache, v_cache = M.decode_step(
            params, tokens_b, k_cache, v_cache, lens_b, CFG)
        np.testing.assert_allclose(logits_b[slot], ref[0, lens_val],
                                   rtol=5e-4, atol=5e-4)
        lens_val += 1


def test_decode_slots_independent(params):
    """Other slots' contents must not leak into a slot's logits."""
    key = jax.random.PRNGKey(7)
    B, S = 8, CFG.max_len
    k_cache = jnp.zeros((CFG.n_layers, B, S, CFG.n_heads, CFG.head_dim))
    v_cache = jnp.zeros_like(k_cache)
    tokens = jax.random.randint(key, (B,), 0, CFG.vocab)
    lens = jnp.zeros((B,), jnp.int32)

    out1, _, _ = M.decode_step(params, tokens, k_cache, v_cache, lens, CFG)
    # Garbage in other slots' caches:
    k2 = k_cache.at[:, 1:].set(123.0)
    v2 = v_cache.at[:, 1:].set(-321.0)
    out2, _, _ = M.decode_step(params, tokens, k2, v2, lens, CFG)
    np.testing.assert_allclose(out1[0], out2[0], rtol=1e-5, atol=1e-5)
