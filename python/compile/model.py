"""Layer-2 JAX model: TinyQwen — a Qwen2-style decoder substrate.

This is the serving substrate for TokenCake's end-to-end path: a small
transformer (RMSNorm → attention(+RoPE) → SwiGLU MLP) whose attention hot
spots are the Layer-1 Pallas kernels in ``kernels/attention.py``.

Two entry points get AOT-lowered by ``aot.py``:

  * ``prefill(params, tokens[1,T], true_len[1])``
        -> (last_logits[1,V], k_cache[L,T,H,D], v_cache[L,T,H,D])
  * ``decode_step(params, tokens[B], k_cache[L,B,S,H,D], v_cache, lens[B])``
        -> (logits[B,V], k_cache', v_cache')

Shapes are static (one compiled executable per variant); the Rust
coordinator pads prompts to T and manages per-slot ``lens``. Python never
runs at serve time.
"""

import math

import jax
import jax.numpy as jnp

from .kernels.attention import flash_prefill, masked_decode


class ModelConfig:
    """TinyQwen hyperparameters. Mirrored in artifacts/manifest.txt."""

    def __init__(self, vocab=512, d_model=128, n_layers=2, n_heads=2,
                 head_dim=64, d_ff=256, max_len=256, rope_theta=10000.0,
                 norm_eps=1e-6):
        self.vocab = vocab
        self.d_model = d_model
        self.n_layers = n_layers
        self.n_heads = n_heads
        self.head_dim = head_dim
        self.d_ff = d_ff
        self.max_len = max_len
        self.rope_theta = rope_theta
        self.norm_eps = norm_eps

    @property
    def d_attn(self):
        return self.n_heads * self.head_dim


DEFAULT_CONFIG = ModelConfig()


# ---------------------------------------------------------------------------
# Parameters — a flat, ordered list of (name, array) so the AOT manifest and
# the Rust loader agree on input ordering without a pytree protocol.
# ---------------------------------------------------------------------------


def param_spec(cfg: ModelConfig):
    """Ordered [(name, shape)] for every weight tensor."""
    spec = [("embed", (cfg.vocab, cfg.d_model))]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        spec += [
            (p + "attn_norm", (cfg.d_model,)),
            (p + "wq", (cfg.d_model, cfg.d_attn)),
            (p + "wk", (cfg.d_model, cfg.d_attn)),
            (p + "wv", (cfg.d_model, cfg.d_attn)),
            (p + "wo", (cfg.d_attn, cfg.d_model)),
            (p + "mlp_norm", (cfg.d_model,)),
            (p + "w_gate", (cfg.d_model, cfg.d_ff)),
            (p + "w_up", (cfg.d_model, cfg.d_ff)),
            (p + "w_down", (cfg.d_ff, cfg.d_model)),
        ]
    spec += [("final_norm", (cfg.d_model,)),
             ("lm_head", (cfg.d_model, cfg.vocab))]
    return spec


def init_params(cfg: ModelConfig, seed=0):
    """Deterministic scaled-normal init; list of arrays in param_spec order."""
    key = jax.random.PRNGKey(seed)
    params = []
    for idx, (name, shape) in enumerate(param_spec(cfg)):
        k = jax.random.fold_in(key, idx)
        if len(shape) == 1:
            params.append(jnp.ones(shape, jnp.float32))
        else:
            scale = 1.0 / math.sqrt(shape[0])
            params.append(jax.random.normal(k, shape, jnp.float32) * scale)
    return params


def params_by_name(cfg: ModelConfig, params):
    return {name: p for (name, _), p in zip(param_spec(cfg), params)}


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x, w, eps):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope_freqs(cfg: ModelConfig, positions):
    """positions: [...]; returns (cos, sin) of shape [..., head_dim//2]."""
    half = cfg.head_dim // 2
    inv = 1.0 / (cfg.rope_theta
                 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [..., head_dim]; cos/sin broadcastable to [..., head_dim//2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1)


def swiglu(x, wg, wu, wd):
    return (jax.nn.silu(x @ wg) * (x @ wu)) @ wd


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def prefill(params, tokens, true_len, cfg: ModelConfig = DEFAULT_CONFIG,
            interpret=True):
    """Process a full (padded) prompt; return last valid logits + KV cache.

    tokens: [1, T] int32 padded to T; true_len: [1] int32 — number of valid
    prompt tokens. Causality makes tail padding inert for positions
    < true_len. Returns (logits[1, V], k_cache[L, T, H, D], v_cache[...]).
    """
    P = params_by_name(cfg, params)
    B, T = tokens.shape
    H, D = cfg.n_heads, cfg.head_dim

    x = P["embed"][tokens]  # [1, T, d_model]
    pos = jnp.arange(T, dtype=jnp.int32)
    cos, sin = rope_freqs(cfg, pos)  # [T, D/2]

    k_layers, v_layers = [], []
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        h = rmsnorm(x, P[p + "attn_norm"], cfg.norm_eps)
        q = (h @ P[p + "wq"]).reshape(B, T, H, D)
        k = (h @ P[p + "wk"]).reshape(B, T, H, D)
        v = (h @ P[p + "wv"]).reshape(B, T, H, D)
        q = apply_rope(q, cos[None, :, None, :], sin[None, :, None, :])
        k = apply_rope(k, cos[None, :, None, :], sin[None, :, None, :])
        # Layer-1 kernel: [B, H, T, D] layout.
        attn = flash_prefill(q.transpose(0, 2, 1, 3),
                             k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), interpret=interpret)
        attn = attn.transpose(0, 2, 1, 3).reshape(B, T, H * D)
        x = x + attn @ P[p + "wo"]
        h = rmsnorm(x, P[p + "mlp_norm"], cfg.norm_eps)
        x = x + swiglu(h, P[p + "w_gate"], P[p + "w_up"], P[p + "w_down"])
        k_layers.append(k[0])  # [T, H, D]
        v_layers.append(v[0])

    x = rmsnorm(x, P["final_norm"], cfg.norm_eps)
    logits = x @ P["lm_head"]  # [1, T, V]
    last = jax.lax.dynamic_slice_in_dim(logits, true_len[0] - 1, 1, axis=1)
    return (last[:, 0, :], jnp.stack(k_layers), jnp.stack(v_layers))


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def decode_step(params, tokens, k_cache, v_cache, lens,
                cfg: ModelConfig = DEFAULT_CONFIG, interpret=True):
    """One batched decode step against a dense per-slot KV cache.

    tokens: [B] int32 (the previously sampled token per slot);
    k_cache, v_cache: [L, B, S, H, D]; lens: [B] int32 — tokens already in
    the cache (the new token is written at position lens[b]).
    Returns (logits[B, V], k_cache', v_cache'). Inactive slots produce
    garbage logits that the coordinator ignores.
    """
    P = params_by_name(cfg, params)
    B = tokens.shape[0]
    L, _, S, H, D = k_cache.shape

    x = P["embed"][tokens]  # [B, d_model]
    cos, sin = rope_freqs(cfg, lens)  # [B, D/2]

    new_k, new_v = [], []
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        h = rmsnorm(x, P[p + "attn_norm"], cfg.norm_eps)
        q = (h @ P[p + "wq"]).reshape(B, H, D)
        k = (h @ P[p + "wk"]).reshape(B, H, D)
        v = (h @ P[p + "wv"]).reshape(B, H, D)
        q = apply_rope(q, cos[:, None, :], sin[:, None, :])
        k = apply_rope(k, cos[:, None, :], sin[:, None, :])

        # Write the new K/V at position lens[b] for each slot (overwriting
        # any stale value so slot reuse is safe).
        write = jnp.arange(S)[None, :] == lens[:, None]  # [B, S]
        kc = jnp.where(write[:, :, None, None], k[:, None, :, :], k_cache[i])
        vc = jnp.where(write[:, :, None, None], v[:, None, :, :], v_cache[i])

        # Layer-1 kernel over the updated cache; query sees lens[b]+1 keys.
        attn = masked_decode(q, kc, vc, lens + 1, interpret=interpret)
        x = x + attn.reshape(B, H * D) @ P[p + "wo"]
        h = rmsnorm(x, P[p + "mlp_norm"], cfg.norm_eps)
        x = x + swiglu(h, P[p + "w_gate"], P[p + "w_up"], P[p + "w_down"])
        new_k.append(kc)
        new_v.append(vc)

    x = rmsnorm(x, P["final_norm"], cfg.norm_eps)
    logits = x @ P["lm_head"]  # [B, V]
    return logits, jnp.stack(new_k), jnp.stack(new_v)


# ---------------------------------------------------------------------------
# Reference full-context forward (oracle for prefill/decode equivalence)
# ---------------------------------------------------------------------------


def full_forward_ref(params, tokens, cfg: ModelConfig = DEFAULT_CONFIG):
    """Dense causal forward over [1, T] tokens -> logits [1, T, V].

    Pure jnp (no Pallas): the oracle that prefill+decode must match.
    """
    from .kernels.ref import ref_flash_prefill

    P = params_by_name(cfg, params)
    B, T = tokens.shape
    H, D = cfg.n_heads, cfg.head_dim

    x = P["embed"][tokens]
    pos = jnp.arange(T, dtype=jnp.int32)
    cos, sin = rope_freqs(cfg, pos)

    for i in range(cfg.n_layers):
        p = f"layer{i}."
        h = rmsnorm(x, P[p + "attn_norm"], cfg.norm_eps)
        q = (h @ P[p + "wq"]).reshape(B, T, H, D)
        k = (h @ P[p + "wk"]).reshape(B, T, H, D)
        v = (h @ P[p + "wv"]).reshape(B, T, H, D)
        q = apply_rope(q, cos[None, :, None, :], sin[None, :, None, :])
        k = apply_rope(k, cos[None, :, None, :], sin[None, :, None, :])
        attn = ref_flash_prefill(q.transpose(0, 2, 1, 3),
                                 k.transpose(0, 2, 1, 3),
                                 v.transpose(0, 2, 1, 3))
        attn = attn.transpose(0, 2, 1, 3).reshape(B, T, H * D)
        x = x + attn @ P[p + "wo"]
        h = rmsnorm(x, P[p + "mlp_norm"], cfg.norm_eps)
        x = x + swiglu(h, P[p + "w_gate"], P[p + "w_up"], P[p + "w_down"])

    x = rmsnorm(x, P["final_norm"], cfg.norm_eps)
    return x @ P["lm_head"]
