"""Layer-1 Pallas attention kernels for TokenCake's TinyQwen substrate.

Three kernels cover the serving hot path:

  * ``flash_prefill``   — blocked causal attention with online softmax
                          (flash-attention schedule), used in the prefill
                          artifact.
  * ``masked_decode``   — single-token decode attention over a dense KV cache
                          with per-sequence valid lengths, used in the decode
                          artifact.
  * ``paged_decode``    — the paper-faithful layout: KV lives in 16-token
                          pages indexed via a per-sequence block table
                          (vLLM/TokenCake PagedAttention), with the block
                          table delivered through scalar prefetch so the
                          BlockSpec index_map performs the page gather.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the CUDA formulation assigns
a threadblock per (sequence, head) and stages KV tiles through shared memory.
Here each grid step owns a (q-tile | sequence, head) and BlockSpec stages KV
tiles through VMEM; online-softmax accumulators live in VMEM scratch. Shapes
are padded to lane multiples (last dim 64/128) so the MXU sees aligned
matmuls. ``interpret=True`` is mandatory on CPU PJRT — real TPU lowering
emits Mosaic custom-calls the CPU plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# flash_prefill
# ---------------------------------------------------------------------------


def _flash_prefill_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k,
                          seq_len, scale):
    """One grid step handles one (batch*head, q-tile) pair.

    q_ref: [block_q, D]; k_ref, v_ref: [seq_len, D] (whole KV row staged —
    small for the tile sizes we compile); o_ref: [block_q, D]. Online softmax
    over k-tiles with causal masking; tiles strictly above the diagonal are
    skipped entirely.
    """
    q_tile = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale
    D = q.shape[-1]

    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, D), dtype=jnp.float32)

    q_pos = q_tile * block_q + jax.lax.iota(jnp.int32, block_q)
    num_k_tiles = seq_len // block_k

    def body(i, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[...], i * block_k, block_k,
                                         axis=0).astype(jnp.float32)
        v = jax.lax.dynamic_slice_in_dim(v_ref[...], i * block_k, block_k,
                                         axis=0).astype(jnp.float32)
        s = q @ k.T  # [block_q, block_k]
        k_pos = i * block_k + jax.lax.iota(jnp.int32, block_k)
        causal = q_pos[:, None] >= k_pos[None, :]
        s = jnp.where(causal, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[:, None] + p @ v
        return m_new, l_new, acc_new

    # Last KV tile that can contain in-range keys for this q-tile.
    last = jnp.minimum(((q_tile + 1) * block_q + block_k - 1) // block_k,
                       num_k_tiles)
    m, l, acc = jax.lax.fori_loop(0, last, body, (m0, l0, acc0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_prefill(q, k, v, *, block_q=64, block_k=64, scale=None,
                  interpret=True):
    """Causal flash attention. q, k, v: [B, H, T, D] -> [B, H, T, D]."""
    B, H, T, D = q.shape
    scale = scale if scale is not None else 1.0 / (D**0.5)
    block_q = min(block_q, T)
    block_k = min(block_k, T)
    assert T % block_q == 0 and T % block_k == 0, (T, block_q, block_k)

    qf = q.reshape(B * H, T, D)
    kf = k.reshape(B * H, T, D)
    vf = v.reshape(B * H, T, D)

    out = pl.pallas_call(
        functools.partial(_flash_prefill_kernel, block_q=block_q,
                          block_k=block_k, seq_len=T, scale=scale),
        grid=(B * H, T // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, D), lambda bh, qt: (bh, qt, 0)),
            pl.BlockSpec((None, T, D), lambda bh, qt: (bh, 0, 0)),
            pl.BlockSpec((None, T, D), lambda bh, qt: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, D), lambda bh, qt: (bh, qt, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, T, D), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, T, D)


# ---------------------------------------------------------------------------
# masked_decode
# ---------------------------------------------------------------------------


def _masked_decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, seq_len,
                          block_k, scale):
    """One grid step handles one (batch, head).

    q_ref: [D]; k_ref, v_ref: [seq_len, D]; len_ref: [1] int32 (valid length);
    o_ref: [D]. Online softmax over KV tiles with a length mask.
    """
    q = q_ref[...].astype(jnp.float32) * scale
    valid = len_ref[0]
    D = q.shape[-1]

    m0 = jnp.float32(NEG_INF)
    l0 = jnp.float32(0.0)
    acc0 = jnp.zeros((D,), dtype=jnp.float32)

    def body(i, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[...], i * block_k, block_k,
                                         axis=0).astype(jnp.float32)
        v = jax.lax.dynamic_slice_in_dim(v_ref[...], i * block_k, block_k,
                                         axis=0).astype(jnp.float32)
        s = k @ q  # [block_k]
        pos = i * block_k + jax.lax.iota(jnp.int32, block_k)
        s = jnp.where(pos < valid, s, NEG_INF)
        m_new = jnp.maximum(m, s.max())
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum()
        acc_new = acc * corr + p @ v
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, seq_len // block_k, body, (m0, l0, acc0))
    o_ref[...] = (acc / l).astype(o_ref.dtype)


def masked_decode(q, k_cache, v_cache, lens, *, block_k=64, scale=None,
                  interpret=True):
    """Single-token decode attention over a dense cache.

    q: [B, H, D]; k_cache, v_cache: [B, S, H, D]; lens: [B] int32.
    Returns [B, H, D].
    """
    B, S, H, D = k_cache.shape
    scale = scale if scale is not None else 1.0 / (D**0.5)
    block_k = min(block_k, S)
    assert S % block_k == 0, (S, block_k)

    # [B, H, S, D] so a (b, h) grid step owns a contiguous KV row.
    kt = k_cache.transpose(0, 2, 1, 3)
    vt = v_cache.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        functools.partial(_masked_decode_kernel, seq_len=S, block_k=block_k,
                          scale=scale),
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h: (b,)),
            pl.BlockSpec((None, None, D), lambda b, h: (b, h, 0)),
            pl.BlockSpec((None, None, S, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, S, D), lambda b, h: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, D), lambda b, h: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(lens.astype(jnp.int32), q, kt, vt)
    return out


# ---------------------------------------------------------------------------
# paged_decode
# ---------------------------------------------------------------------------


def _paged_decode_kernel(table_ref, len_ref, q_ref, kp_ref, vp_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, page, pages_per_seq,
                         scale):
    """Grid (B, H, pages_per_seq); the page axis accumulates online softmax.

    table_ref/len_ref are scalar-prefetch refs (whole arrays); kp_ref/vp_ref
    are the [page, D] tile of the page chosen by the block-table index_map.
    Scratch m/l/acc carry softmax state across page steps of one (b, h).
    """
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[0] = NEG_INF
        l_ref[0] = 0.0
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32) * scale  # [D]
    k = kp_ref[...].astype(jnp.float32)  # [page, D]
    v = vp_ref[...].astype(jnp.float32)
    valid = len_ref[b]

    s = k @ q  # [page]
    pos = p * page + jax.lax.iota(jnp.int32, page)
    s = jnp.where(pos < valid, s, NEG_INF)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, s.max())
    pexp = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    m_ref[0] = m_new
    l_ref[0] = l_ref[0] * corr + pexp.sum()
    acc_ref[...] = acc_ref[...] * corr + pexp @ v

    @pl.when(p == pages_per_seq - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...] / l_ref[0]).astype(o_ref.dtype)


def paged_decode(q, k_pages, v_pages, block_table, lens, *, scale=None,
                 interpret=True):
    """PagedAttention-style decode: KV in fixed pages + per-seq block table.

    The block table is fed through scalar prefetch so the KV BlockSpec
    index_map resolves ``table[b, p]`` — the page gather happens in the
    HBM→VMEM pipeline, exactly how the threadblock-indirection works on GPU.

    q: [B, H, D]; k_pages, v_pages: [P, page, H, D];
    block_table: [B, pages_per_seq] int32; lens: [B] int32. Returns [B, H, D].
    """
    B, H, D = q.shape
    P, page, Hk, Dk = k_pages.shape
    assert (H, D) == (Hk, Dk), (q.shape, k_pages.shape)
    _, pages_per_seq = block_table.shape
    scale = scale if scale is not None else 1.0 / (D**0.5)

    # [P, H, page, D] so one (page-index, head) pair is a contiguous tile.
    kp = k_pages.transpose(0, 2, 1, 3)
    vp = v_pages.transpose(0, 2, 1, 3)

    def kv_map(b, h, p, table, lens):
        return (table[b, p], h, 0, 0)

    out = pl.pallas_call(
        functools.partial(_paged_decode_kernel, page=page,
                          pages_per_seq=pages_per_seq, scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, H, pages_per_seq),
            in_specs=[
                pl.BlockSpec((None, None, D),
                             lambda b, h, p, table, lens: (b, h, 0)),
                pl.BlockSpec((None, None, page, D), kv_map),
                pl.BlockSpec((None, None, page, D), kv_map),
            ],
            out_specs=pl.BlockSpec((None, None, D),
                                   lambda b, h, p, table, lens: (b, h, 0)),
            scratch_shapes=[
                pltpu.VMEM((1,), jnp.float32),
                pltpu.VMEM((1,), jnp.float32),
                pltpu.VMEM((D,), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), lens.astype(jnp.int32), q, kp, vp)
    return out
