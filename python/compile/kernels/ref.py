"""Pure-jnp reference oracles for the Pallas kernels (Layer 1 correctness).

Every kernel in this package has a reference implementation here; pytest
(python/tests/test_kernels.py) sweeps shapes/dtypes with hypothesis and
asserts allclose between the Pallas output (interpret=True) and these.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def ref_flash_prefill(q, k, v, scale=None):
    """Causal self-attention.

    q, k, v: [B, H, T, D] -> out [B, H, T, D]
    """
    B, H, T, D = q.shape
    scale = scale if scale is not None else 1.0 / (D**0.5)
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) * scale
    causal = jnp.tril(jnp.ones((T, T), dtype=bool))
    scores = jnp.where(causal[None, None], scores, NEG_INF)
    w = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhts,bhsd->bhtd", w, v).astype(q.dtype)


def ref_masked_decode(q, k_cache, v_cache, lens, scale=None):
    """Single-token decode attention against a dense cache with valid lengths.

    q: [B, H, D]; k_cache, v_cache: [B, S, H, D]; lens: [B] int32 — number of
    valid cache entries per sequence (the query attends to positions < lens[b]).
    Returns [B, H, D].
    """
    B, S, H, D = k_cache.shape
    scale = scale if scale is not None else 1.0 / (D**0.5)
    scores = jnp.einsum("bhd,bshd->bhs", q, k_cache) * scale
    mask = jnp.arange(S)[None, :] < lens[:, None]  # [B, S]
    scores = jnp.where(mask[:, None, :], scores, NEG_INF)
    w = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhs,bshd->bhd", w, v_cache).astype(q.dtype)


def ref_paged_decode(q, k_pages, v_pages, block_table, lens, scale=None):
    """Paged single-token decode attention (the paper's KV layout: 16-token
    blocks indexed through a per-sequence block table).

    q: [B, H, D]; k_pages, v_pages: [P, page, H, D];
    block_table: [B, pages_per_seq] int32; lens: [B] int32.
    Returns [B, H, D].
    """
    B, H, D = q.shape
    _, page, _, _ = k_pages.shape
    # Gather each sequence's pages into a dense cache, then reuse the dense ref.
    k_dense = k_pages[block_table]  # [B, pages_per_seq, page, H, D]
    v_dense = v_pages[block_table]
    B_, n, p, H_, D_ = k_dense.shape
    k_dense = k_dense.reshape(B_, n * p, H_, D_)
    v_dense = v_dense.reshape(B_, n * p, H_, D_)
    return ref_masked_decode(q, k_dense, v_dense, lens, scale)
