#!/usr/bin/env bash
# Regenerate every BENCH_*.json with real measurements.
#
# The checked-in BENCH files were authored in a container without a Rust
# toolchain, so their rows are projections ("provenance" field) with the
# regeneration commands buried in comments. This script is those
# commands, exactly, in one place: run it on a machine with cargo and
# the projected files are replaced by measured ones.
#
#   scripts/bench.sh            # writes BENCH_2..BENCH_5 and BENCH_7
#   OUT=/tmp scripts/bench.sh   # writes elsewhere
#
# BENCH_2 (hot-path throughput), BENCH_3 (epoch gating / batched
# migration), and BENCH_4 (prefix directory) all come from the same
# trajectory command with the flags each file documents; BENCH_5 is the
# autoscale comparison: fixed-4 vs elastic 1..8 vs fixed-8 under a
# bursty workload (p99 latency, effective GPU util, scale events).

set -euo pipefail
cd "$(dirname "$0")/.."
OUT="${OUT:-.}"
RUN="cargo run --release --"

cargo build --release

# ---- BENCH_2: hot-path throughput (wall_s / sim_events_per_s) --------
# (from BENCH_2.json "regenerate_after")
$RUN bench --qps 2.0 --apps 48 --frac 0.05 --seed 1 \
  --json "$OUT/BENCH_2.json"

# ---- BENCH_3: epoch gating + batched migration on the 4-shard run ----
# (from BENCH_3.json "regenerate_after"; rows carry
# planner_runs_per_1k_ticks and mean_migration_batch)
$RUN bench --qps 2.0 --apps 48 --frac 0.05 --seed 1 --shards 4 \
  --json "$OUT/BENCH_3.json"

# ---- BENCH_4: prefix directory vs per-shard-index baseline -----------
# (from BENCH_4.json "regenerate_after" / "regenerate_baseline"; a
# directory-on cluster row paired with a directory-off baseline row —
# compare prefix_hit_rate_remote / prefill_tokens_saved across the two)
$RUN cluster --shards 4 --policy affinity --qps 2.0 --apps 48 \
  --frac 0.05 --seed 1 \
  --json "$OUT/BENCH_4.json" --json-name prefix-directory-on
cat > /tmp/tokencake_no_prefix_dir.toml <<'EOF'
[cluster]
prefix_directory = false
EOF
$RUN cluster --shards 4 --policy affinity --qps 2.0 --apps 48 \
  --frac 0.05 --seed 1 --config /tmp/tokencake_no_prefix_dir.toml \
  --json "$OUT/BENCH_4_baseline.json" --json-name per-shard-index-baseline

# ---- BENCH_5: fixed fleet vs elastic autoscale under bursts ----------
# Shared workload: 0.3 QPS base, 4.0 QPS bursts (60 s period, 25% duty),
# 48 apps, frac 0.06, seed 1, agent-affinity.
BURST="--qps 0.3 --burst-qps 4.0 --burst-period-s 60 --burst-duty 0.25 \
  --apps 48 --frac 0.06 --seed 1 --policy affinity"
$RUN cluster --shards 4 $BURST \
  --json /tmp/bench5_fixed4.json --json-name fixed-4
$RUN cluster --shards 8 $BURST \
  --json /tmp/bench5_fixed8.json --json-name fixed-8-max
$RUN cluster --shards 1 $BURST --autoscale --min-shards 1 --max-shards 8 \
  --warmup-ms 1000 --cooldown-ms 1000 --assert-autoscale \
  --json /tmp/bench5_auto.json --json-name autoscale-1-to-8
{
  printf '{\n  "benchmark": "tokencake_autoscale",\n'
  printf '  "workload": "mix cw:2,dr:1, base 0.3 qps, burst 4.0 qps x 60s period x 0.25 duty, 48 apps, frac 0.06, seed 1",\n'
  printf '  "metric": "p99_latency_s (elastic must beat fixed-min), effective_gpu_util (fixed-max must be worse than elastic), scale events + shard lifetimes",\n'
  printf '  "runs": [\n'
  sed -e 's/[[:space:]]*$//' /tmp/bench5_fixed4.json | sed -e '$ s/$/,/'
  sed -e 's/[[:space:]]*$//' /tmp/bench5_fixed8.json | sed -e '$ s/$/,/'
  cat /tmp/bench5_auto.json
  printf '  ]\n}\n'
} > "$OUT/BENCH_5.json"

# ---- BENCH_7: crash recovery cost vs replica aggressiveness ----------
# Fixed crash schedule (shard 1 dies at t=3s) over the same pressured
# workload; the replica knob sweeps no-replicas (prefix directory off)
# vs default (replicate after 2 remote hits) vs aggressive (replicate
# on the first). Compare crash_requeue_tokens, prefill_tokens_saved,
# and mean/p99 latency across the rows — warm survivor replicas should
# cut the re-prefill bill. Every run must pass --assert-recovery.
CRASH="--shards 4 --policy affinity --qps 2.0 --apps 48 --frac 0.06 \
  --seed 1 --crash 1@3000 --assert-recovery"
cat > /tmp/tokencake_no_replicas.toml <<'EOF'
[cluster]
prefix_directory = false
EOF
cat > /tmp/tokencake_aggressive_replicas.toml <<'EOF'
[cluster]
prefix_replicate_threshold = 1
EOF
$RUN cluster $CRASH --config /tmp/tokencake_no_replicas.toml \
  --json /tmp/bench7_none.json --json-name crash-no-replicas
$RUN cluster $CRASH \
  --json /tmp/bench7_default.json --json-name crash-replicas-thresh2
$RUN cluster $CRASH --config /tmp/tokencake_aggressive_replicas.toml \
  --json /tmp/bench7_aggr.json --json-name crash-replicas-thresh1
{
  printf '{\n  "benchmark": "tokencake_crash_recovery",\n'
  printf '  "workload": "mix cw:2,dr:1, 2.0 qps, 48 apps, frac 0.06, seed 1, crash shard 1 at t=3s",\n'
  printf '  "metric": "crash_requeue_tokens + prefill_tokens_saved + latency vs replica aggressiveness (directory off / threshold 2 / threshold 1)",\n'
  printf '  "runs": [\n'
  sed -e 's/[[:space:]]*$//' /tmp/bench7_none.json | sed -e '$ s/$/,/'
  sed -e 's/[[:space:]]*$//' /tmp/bench7_default.json | sed -e '$ s/$/,/'
  cat /tmp/bench7_aggr.json
  printf '  ]\n}\n'
} > "$OUT/BENCH_7.json"

# ---- BENCH_8: QoS on/off x Batch-flood intensity ---------------------
# Tiered mix (code-writer = interactive, deep-research = batch flood)
# at two flood intensities; each intensity runs ungated and gated.
# Compare tier_p99_s[0] (Interactive) across the on/off pairs — the
# gate must hold it inside the 60 s SLO while the ungated run lets the
# flood push it up — plus qos_shed (explicit, accounted degradation)
# and effective_gpu_util (must not drop by more than the shed
# fraction). Gated runs must pass --assert-qos.
QOSW="--shards 2 --policy affinity --apps 48 --frac 0.05 --seed 17"
QOSON="--qos --tiers interactive,batch --qos-rates 50,4,0.25 \
  --slo-ms 60000,120000,600000 --qos-age-ms 4000"
$RUN cluster $QOSW --qps 3.0 --mix cw:1,dr:3 \
  --json /tmp/bench8_off_mild.json --json-name flood-mild-qos-off
$RUN cluster $QOSW --qps 3.0 --mix cw:1,dr:3 $QOSON --assert-qos \
  --json /tmp/bench8_on_mild.json --json-name flood-mild-qos-on
$RUN cluster $QOSW --qps 6.0 --mix cw:1,dr:5 \
  --json /tmp/bench8_off_heavy.json --json-name flood-heavy-qos-off
$RUN cluster $QOSW --qps 6.0 --mix cw:1,dr:5 $QOSON --assert-qos \
  --json /tmp/bench8_on_heavy.json --json-name flood-heavy-qos-on
{
  printf '{\n  "benchmark": "tokencake_qos",\n'
  printf '  "workload": "cw=interactive : dr=batch tiered mix, 48 apps, frac 0.05, seed 17; mild flood (3 qps, cw:1,dr:3) and heavy flood (6 qps, cw:1,dr:5), each QoS off/on (rates 50/4/0.25, SLO 60/120/600 s)",\n'
  printf '  "metric": "tier_p99_s[0] (Interactive: gated must stay <= 60 s SLO, ungated degrades with flood), qos_shed + qos_starved (starved always 0), effective_gpu_util (drop bounded by shed fraction)",\n'
  printf '  "runs": [\n'
  sed -e 's/[[:space:]]*$//' /tmp/bench8_off_mild.json | sed -e '$ s/$/,/'
  sed -e 's/[[:space:]]*$//' /tmp/bench8_on_mild.json | sed -e '$ s/$/,/'
  sed -e 's/[[:space:]]*$//' /tmp/bench8_off_heavy.json | sed -e '$ s/$/,/'
  cat /tmp/bench8_on_heavy.json
  printf '  ]\n}\n'
} > "$OUT/BENCH_8.json"

# ---- BENCH_9: serial oracle vs parallel shard execution --------------
# The same pressured mixed workload at 4 / 16 / 64 shards, each run
# twice: --serial (single-thread oracle) and --parallel (scoped worker
# threads) with --assert-parity, so every parallel row is only written
# if its digest matched the serial oracle byte for byte. Compare
# wall_s and sim_events_per_s across each pair — the parallel multiplier
# should grow with the shard count (4-shard runs are barrier-dominated;
# 64-shard runs are where the scoped threads pay).
PAR="--policy affinity --qps 2.0 --apps 48 --frac 0.05 --seed 1"
for n in 4 16 64; do
  $RUN cluster --shards "$n" $PAR --serial \
    --json "/tmp/bench9_serial_$n.json" --json-name "serial-$n-shards"
  $RUN cluster --shards "$n" $PAR --parallel --assert-parity \
    --json "/tmp/bench9_parallel_$n.json" --json-name "parallel-$n-shards"
done
{
  printf '{\n  "benchmark": "tokencake_parallel_execution",\n'
  printf '  "workload": "mix cw:2,dr:1, 2.0 qps, 48 apps, frac 0.05, seed 1, affinity routing; 4/16/64 shards, each serial vs parallel (--assert-parity on every parallel run)",\n'
  printf '  "metric": "wall_s + sim_events_per_s per serial/parallel pair (identical digests enforced in-run; the parallel multiplier must grow with shard count)",\n'
  printf '  "runs": [\n'
  for n in 4 16 64; do
    sed -e 's/[[:space:]]*$//' "/tmp/bench9_serial_$n.json" | sed -e '$ s/$/,/'
    if [ "$n" = 64 ]; then
      cat "/tmp/bench9_parallel_$n.json"
    else
      sed -e 's/[[:space:]]*$//' "/tmp/bench9_parallel_$n.json" | sed -e '$ s/$/,/'
    fi
  done
  printf '  ]\n}\n'
} > "$OUT/BENCH_9.json"

# ---- BENCH_10: latency attribution (phase ledger headlines) ----------
# The same pressured 4-shard workload three ways, every row carrying
# the attribution headlines (stall_hidden_frac, exposed_upload_us_p99,
# queue_wait_us_p99) and each traced run passing --assert-attrib (exact
# per-request phase conservation + byte-identical trace replay):
#   - tokencake: temporal offload on — part of the FC stall hides
#     behind the wire, stall_hidden_frac > 0;
#   - agent-only: no offload path — the same stalls are all held
#     on-GPU, stall_hidden_frac == 0 (the attribution control);
#   - tokencake + QoS flood: queue_wait_us_p99 picks up the deferred
#     admission wait the gate imposes on the Batch tier.
ATTR="--shards 4 --policy affinity --qps 2.0 --apps 48 --frac 0.05 --seed 1"
$RUN cluster $ATTR --mode tokencake --assert-attrib \
  --json /tmp/bench10_tc.json --json-name attrib-tokencake \
  --metrics-out "$OUT/BENCH_10.prom"
$RUN cluster $ATTR --mode agent \
  --json /tmp/bench10_agent.json --json-name attrib-agent-only
$RUN cluster $ATTR --mode tokencake --qps 6.0 --mix cw:1,dr:5 \
  --qos --tiers interactive,batch --qos-rates 50,4,0.25 \
  --slo-ms 60000,120000,600000 --qos-age-ms 4000 --assert-attrib \
  --json /tmp/bench10_qos.json --json-name attrib-qos-flood
{
  printf '{\n  "benchmark": "tokencake_latency_attribution",\n'
  printf '  "workload": "mix cw:2,dr:1, 2.0 qps, 48 apps, frac 0.05, seed 1, 4 shards affinity; tokencake vs agent-only (offload path off), plus a QoS Batch flood (6 qps, cw:1,dr:5, tiered); traced runs pass --assert-attrib (exact phase conservation, trace replay == live ledger)",\n'
  printf '  "metric": "stall_hidden_frac (tokencake > 0, agent-only == 0), exposed_upload_us_p99 (the un-hidden wire tail), queue_wait_us_p99 (grows under the QoS flood)",\n'
  printf '  "runs": [\n'
  sed -e 's/[[:space:]]*$//' /tmp/bench10_tc.json | sed -e '$ s/$/,/'
  sed -e 's/[[:space:]]*$//' /tmp/bench10_agent.json | sed -e '$ s/$/,/'
  cat /tmp/bench10_qos.json
  printf '  ]\n}\n'
} > "$OUT/BENCH_10.json"

echo "wrote $OUT/BENCH_2.json $OUT/BENCH_3.json $OUT/BENCH_4.json" \
     "$OUT/BENCH_4_baseline.json $OUT/BENCH_5.json $OUT/BENCH_7.json" \
     "$OUT/BENCH_8.json $OUT/BENCH_9.json $OUT/BENCH_10.json"
