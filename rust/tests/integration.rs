//! Cross-module integration tests: full scheduling pipeline, baselines'
//! relative ordering, coordination-protocol properties, and the HTTP
//! frontend over a real socket.

use std::io::{Read, Write};
use std::net::TcpStream;

use tokencake::config::{Mode, ServeConfig};
use tokencake::coordination::ReqState;
use tokencake::engine::sim::SimEngine;
use tokencake::graph::templates;
use tokencake::server::Server;
use tokencake::workload::{Dataset, WorkloadSpec};

fn run(mode: Mode, qps: f64, apps: usize, frac: f64, seed: u64)
    -> tokencake::engine::sim::RunReport {
    let cfg = ServeConfig::default()
        .with_mode(mode)
        .with_seed(seed)
        .with_gpu_mem_frac(frac);
    let g = templates::code_writer();
    let spec =
        WorkloadSpec::poisson(&g, qps, apps).with_dataset(Dataset::D1);
    SimEngine::new(cfg).run_workload(&spec)
}

/// The paper's headline ordering under memory pressure (§7.2/§7.3):
/// TokenCake < agent-only < vLLM on average latency, with offload-only
/// also beating vLLM but losing to agent-only standalone.
#[test]
fn headline_ordering_under_pressure() {
    let mut avg = std::collections::HashMap::new();
    for mode in [Mode::Vllm, Mode::AgentOnly, Mode::OffloadOnly,
                 Mode::TokenCake] {
        let mut total = 0.0;
        for seed in [1u64, 2, 3] {
            let rep = run(mode, 0.5, 20, 0.05, seed);
            assert!(!rep.truncated, "{mode:?}");
            assert_eq!(rep.metrics.apps_completed, 20, "{mode:?}");
            total += rep.metrics.latency.mean_s();
        }
        avg.insert(mode.name(), total / 3.0);
    }
    let (tc, ag, of, vl) = (
        avg["tokencake"],
        avg["agent"],
        avg["offload"],
        avg["vllm"],
    );
    assert!(tc < vl * 0.9, "TokenCake {tc} must beat vLLM {vl} by >10%");
    assert!(ag < vl, "agent-only {ag} must beat vLLM {vl}");
    assert!(of < vl, "offload-only {of} must beat vLLM {vl}");
    assert!(
        tc <= ag + 1.0,
        "full TokenCake {tc} must not lose to agent-only {ag}"
    );
}

/// Effective-utilization gap (Fig 10's mechanism): vLLM's occupied blocks
/// are partly idle stalled caches; TokenCake keeps occupancy productive.
#[test]
fn effective_utilization_gap() {
    let v = run(Mode::Vllm, 0.5, 20, 0.08, 7);
    let t = run(Mode::TokenCake, 0.5, 20, 0.08, 7);
    let v_eff = v.metrics.effective_usage.steady_state_mean(0.15);
    let t_eff = t.metrics.effective_usage.steady_state_mean(0.15);
    assert!(
        t_eff > v_eff + 0.05,
        "TokenCake effective {t_eff:.2} must exceed vLLM {v_eff:.2}"
    );
    // And vLLM's stalled fraction is substantial (Fig 2a).
    assert!(
        v.metrics.stalled_fraction.max() > 0.10,
        "stalled peak {:.2}",
        v.metrics.stalled_fraction.max()
    );
}

/// Critical inversion protection (Fig 3 / §5): reservation cuts
/// critical-path evictions relative to FCFS.
#[test]
fn reservation_reduces_critical_inversions() {
    let mut v_inv = 0;
    let mut t_inv = 0;
    for seed in [11u64, 12, 13] {
        v_inv += run(Mode::Vllm, 1.0, 20, 0.08, seed)
            .metrics
            .counters
            .critical_inversions;
        t_inv += run(Mode::TokenCake, 1.0, 20, 0.08, seed)
            .metrics
            .counters
            .critical_inversions;
    }
    assert!(
        t_inv < v_inv,
        "TokenCake inversions {t_inv} must be below vLLM {v_inv}"
    );
}

/// Offload pairing and CPU hygiene across a long multi-seed campaign.
#[test]
fn migration_accounting_closed() {
    for seed in 0..5u64 {
        let cfg = ServeConfig::default()
            .with_mode(Mode::TokenCake)
            .with_seed(seed)
            .with_gpu_mem_frac(0.05);
        let g = templates::deep_research();
        let spec = WorkloadSpec::poisson(&g, 1.0, 10);
        let mut e = SimEngine::new(cfg);
        let rep = e.run_workload(&spec);
        assert_eq!(rep.metrics.offload_count, rep.metrics.upload_count);
        // Pools drain except for backing the prefix index still pins.
        assert_eq!(
            e.st.cpu.used_blocks(),
            e.st.prefix.resident_cpu_blocks()
        );
        assert_eq!(
            e.st.gpu.free_blocks() + e.st.prefix.resident_gpu_blocks(),
            e.st.gpu.total()
        );
        // No request left in a transfer state.
        assert!(e
            .st
            .reqs
            .values()
            .all(|r| r.state == ReqState::Finished));
    }
}

/// Latency attribution pins the temporal scheduler's effect: with the
/// offload path on, part of the function-call stall time is hidden
/// behind the D2H/H2D wire (`stall_hidden_frac > 0`); with no offload
/// path the same FC-heavy workload holds every stall on-GPU, so the
/// hidden fraction is exactly zero.
#[test]
fn stall_hidden_fraction_tracks_temporal_scheduling() {
    let tc = run(Mode::TokenCake, 1.0, 10, 0.05, 9);
    assert!(!tc.truncated);
    assert!(tc.metrics.offload_count > 0, "pressure must force offloads");
    let f = tc.metrics.stall_hidden_frac();
    assert!(
        f > 0.0,
        "temporal offload must hide some stall time (frac={f})"
    );
    let vl = run(Mode::Vllm, 1.0, 10, 0.05, 9);
    assert!(!vl.truncated);
    assert_eq!(
        vl.metrics.stall_hidden_frac(),
        0.0,
        "no offload path, no hidden stall time"
    );
    // Both runs stall on function calls, so the denominator is real:
    // held stall time accrues even when nothing is hidden.
    assert!(
        vl.metrics.phase_us[tokencake::obs::Phase::FcStallHeld as usize]
            > 0,
        "vLLM run never held a stalled cache?"
    );
}

/// Forecaster learns through the engine: after a run, per-function-type
/// observations exist for every tool the workload used.
#[test]
fn forecaster_learns_tool_types() {
    let cfg = ServeConfig::default()
        .with_mode(Mode::TokenCake)
        .with_seed(3)
        .with_gpu_mem_frac(0.2);
    let g = templates::code_writer();
    let spec = WorkloadSpec::poisson(&g, 0.5, 5);
    let mut e = SimEngine::new(cfg);
    let _ = e.run_workload(&spec);
    for tool in ["web_search", "external_test", "git", "file_write"] {
        assert!(
            e.st.forecaster.observations(tool) > 0,
            "no observations for {tool}"
        );
    }
}

/// Tool-noise degrades or preserves — never corrupts — the run.
#[test]
fn noise_injection_is_stable() {
    for noise in [0.0, 0.25, 0.5, 0.9] {
        let cfg = ServeConfig::default()
            .with_mode(Mode::TokenCake)
            .with_seed(5)
            .with_gpu_mem_frac(0.1);
        let g = templates::rag();
        let spec = WorkloadSpec::poisson(&g, 1.0, 8)
            .with_tool_noise(noise);
        let rep = SimEngine::new(cfg).run_workload(&spec);
        assert_eq!(rep.metrics.apps_completed, 8, "noise={noise}");
    }
}

// -----------------------------------------------------------------------
// HTTP frontend over a real socket
// -----------------------------------------------------------------------

fn http_post(addr: std::net::SocketAddr, path: &str, body: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    let req = format!(
        "POST {path} HTTP/1.1\r\nhost: test\r\ncontent-length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

fn http_get(addr: std::net::SocketAddr, path: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(format!("GET {path} HTTP/1.1\r\n\r\n").as_bytes())
        .unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

#[test]
fn http_frontend_full_lifecycle() {
    let server = Server::start(0).unwrap();
    let addr = server.addr;

    assert!(http_get(addr, "/healthz").contains("200 OK"));

    // Register the Fig 5 RAG graph over the wire.
    let dsl = "graph rag\n\
               agent retriever retriever 256 48,96 web_search 3000000 2\n\
               agent generator generator 192 384\n\
               edge retriever generator\n";
    let resp = http_post(addr, "/graphs", dsl);
    assert!(resp.contains("200 OK"), "{resp}");
    assert!(resp.contains("graph=0"));

    // Instantiate an app.
    let resp = http_post(addr, "/apps", "graph=0");
    assert!(resp.contains("app=0"));

    // call_start → /state shows the stalled call → call_finish feeds the
    // forecaster (visible through the next prediction).
    let resp = http_post(
        addr,
        "/call_start",
        "req=1\nfunc=web_search\nestimate_us=3000000",
    );
    assert!(resp.contains("predicted_us=3000000"), "{resp}");
    assert!(http_get(addr, "/state").contains("stalled=1"));
    let resp =
        http_post(addr, "/call_finish", "req=1\nelapsed_us=1000000");
    assert!(resp.contains("observed_us=1000000"));
    // Eq. 1 blend: 0.4·3s + 0.6·1s = 1.8s.
    let resp = http_post(
        addr,
        "/call_start",
        "req=2\nfunc=web_search\nestimate_us=3000000",
    );
    assert!(resp.contains("predicted_us=1800000"), "{resp}");

    // Bad requests are rejected, not crashed.
    assert!(http_post(addr, "/apps", "graph=99").contains("400"));
    assert!(http_post(addr, "/call_finish", "req=777").contains("400"));
    assert!(http_get(addr, "/nope").contains("404"));
}
