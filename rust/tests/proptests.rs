//! Property-based tests over coordinator invariants.
//!
//! proptest is not vendored in this offline environment, so these use a
//! seeded-random harness of the same shape: generate hundreds of random
//! operation sequences / graphs / workloads, assert invariants on every
//! step, and print the failing seed on violation (re-run with that seed to
//! reproduce — everything is deterministic).

use tokencake::config::{Mode, ServeConfig};
use tokencake::engine::sim::SimEngine;
use tokencake::graph::{CallSpec, FuncKind, GraphBuilder};
use tokencake::kvcache::{
    AllocOutcome, BlockSet, CpuBlockPool, GpuPool, PrefixBacking,
    PrefixIndex, PrefixKey, Route,
};
use tokencake::metrics::{LatencyRecorder, MetricsBundle};
use tokencake::sim::Rng;
use tokencake::workload::{Dataset, WorkloadSpec};

// ---------------------------------------------------------------------
// GPU pool invariants under random alloc/free/pending/quota traffic
// ---------------------------------------------------------------------

#[test]
fn prop_gpu_pool_conservation() {
    for seed in 0..300u64 {
        let mut rng = Rng::new(seed + 1);
        let total = rng.range_u64(8, 300) as u32;
        let mut pool = GpuPool::new(total);
        // live allocations: (blocks, charged, type)
        let mut live: Vec<(BlockSet, u32, u16)> = Vec::new();
        let mut pending: Vec<BlockSet> = Vec::new();

        for _step in 0..200 {
            let op = rng.range_u64(0, 100);
            match op {
                0..=39 => {
                    let t = rng.range_u64(0, 4) as u16;
                    let n = rng.range_u64(0, 20) as u32;
                    let route = if rng.next_f64() < 0.5 {
                        Route::Shared
                    } else {
                        Route::Reserved(t)
                    };
                    if let AllocOutcome::Granted {
                        blocks,
                        reserved_charged,
                    } = pool.alloc(n, route)
                    {
                        assert_eq!(blocks.len(), n, "seed {seed}");
                        live.push((blocks, reserved_charged, t));
                    }
                }
                40..=64 => {
                    if !live.is_empty() {
                        let i = rng.range_u64(0, live.len() as u64) as usize;
                        let (b, c, t) = live.swap_remove(i);
                        pool.free(b, c, Some(t));
                    }
                }
                65..=79 => {
                    if !live.is_empty() {
                        let i = rng.range_u64(0, live.len() as u64) as usize;
                        let (b, c, t) = live.swap_remove(i);
                        pool.mark_pending_free(&b, c, Some(t));
                        pending.push(b);
                    }
                }
                80..=89 => {
                    if !pending.is_empty() {
                        let i =
                            rng.range_u64(0, pending.len() as u64) as usize;
                        let b = pending.swap_remove(i);
                        pool.complete_pending(b);
                    }
                }
                _ => {
                    // Random quota plan.
                    let plan: Vec<(u16, u32)> = (0..rng.range_u64(0, 4))
                        .map(|t| {
                            (t as u16, rng.range_u64(0, total as u64 / 2)
                                as u32)
                        })
                        .collect();
                    pool.set_quotas(&plan);
                }
            }
            // ---- Invariants ----
            let held: u32 = live.iter().map(|(b, _, _)| b.len()).sum();
            let pend: u32 = pending.iter().map(|b| b.len()).sum();
            assert_eq!(
                pool.free_blocks() + held + pend,
                total,
                "conservation violated at seed {seed}"
            );
            assert_eq!(pool.pending_free_blocks(), pend, "seed {seed}");
            assert!(
                pool.shared_free() <= pool.free_blocks(),
                "seed {seed}"
            );
            assert!(
                pool.outstanding_reserved()
                    <= pool.total_quota(),
                "seed {seed}"
            );
            assert!(pool.usage() >= 0.0 && pool.usage() <= 1.0);
        }
    }
}

// ---------------------------------------------------------------------
// Extent allocator: conservation + coalescing + disjointness under
// arbitrary alloc / free / pending-free / migration-style interleavings
// ---------------------------------------------------------------------

#[test]
fn prop_extent_allocator_conserves_and_coalesces() {
    for seed in 0..200u64 {
        let mut rng = Rng::new(seed + 7_001);
        let total = rng.range_u64(8, 256) as u32;
        let mut pool = GpuPool::new(total);
        let mut live: Vec<BlockSet> = Vec::new();
        // Migration-style pending-free (blocks leaving through the
        // ledger path: owner released, copy in flight).
        let mut pending: Vec<BlockSet> = Vec::new();

        for _step in 0..250 {
            match rng.range_u64(0, 10) {
                0..=3 => {
                    let n = rng.range_u64(1, 24) as u32;
                    if let AllocOutcome::Granted { blocks, .. } =
                        pool.alloc(n, Route::Shared)
                    {
                        assert_eq!(blocks.len(), n);
                        live.push(blocks);
                    }
                }
                4..=6 => {
                    if !live.is_empty() {
                        let i =
                            rng.range_u64(0, live.len() as u64) as usize;
                        pool.free(live.swap_remove(i), 0, None);
                    }
                }
                7..=8 => {
                    // Migration leg: mark pending, complete later.
                    if !live.is_empty() {
                        let i =
                            rng.range_u64(0, live.len() as u64) as usize;
                        let b = live.swap_remove(i);
                        pool.mark_pending_free(&b, 0, None);
                        pending.push(b);
                    }
                }
                _ => {
                    if !pending.is_empty() {
                        let i = rng.range_u64(0, pending.len() as u64)
                            as usize;
                        pool.complete_pending(pending.swap_remove(i));
                    }
                }
            }
            // ---- Extent-level invariants, every step. ----
            let ext = pool.free_extents();
            // Sorted, coalesced (strict gaps: adjacent runs must have
            // merged), lengths sum to the reported free count.
            for w in ext.windows(2) {
                assert!(
                    w[0].start + w[0].len < w[1].start,
                    "uncoalesced/overlapping free extents at seed {seed}"
                );
            }
            let free_sum: u32 = ext.iter().map(|e| e.len).sum();
            assert_eq!(free_sum, pool.free_blocks(), "seed {seed}");
            // Every block is in exactly one place: live ∪ pending ∪ free
            // covers [0, total) with no duplicates.
            let mut all: Vec<u32> = Vec::with_capacity(total as usize);
            for b in live.iter().chain(pending.iter()) {
                all.extend(b.iter_blocks().map(|id| id.0));
            }
            all.extend(ext.iter().flat_map(|e| e.start..e.start + e.len));
            let n_all = all.len();
            all.sort_unstable();
            all.dedup();
            assert_eq!(all.len(), n_all, "block owned twice, seed {seed}");
            assert_eq!(n_all as u32, total, "block lost, seed {seed}");
        }
        // Drain everything: the free list must coalesce to one extent.
        for b in live.drain(..) {
            pool.free(b, 0, None);
        }
        for b in pending.drain(..) {
            pool.complete_pending(b);
        }
        assert_eq!(pool.free_blocks(), total);
        assert_eq!(pool.free_extents().len(), 1, "seed {seed}");
    }
}

#[test]
fn prop_shared_never_starves_reserved_headroom() {
    // Whatever sequence of shared allocations happens, a critical type
    // must always be able to claim its unused quota.
    for seed in 0..200u64 {
        let mut rng = Rng::new(seed + 77);
        let total = rng.range_u64(50, 400) as u32;
        let quota = rng.range_u64(1, (total / 2) as u64) as u32;
        let mut pool = GpuPool::new(total);
        pool.set_quotas(&[(9, quota)]);
        // Greedy shared allocation until refusal.
        loop {
            let n = rng.range_u64(1, 16) as u32;
            match pool.alloc(n, Route::Shared) {
                AllocOutcome::Granted { .. } => {}
                AllocOutcome::Deferred => break,
            }
        }
        // The full quota must still be available to type 9.
        assert!(
            matches!(
                pool.alloc(quota, Route::Reserved(9)),
                AllocOutcome::Granted { .. }
            ),
            "seed {seed}: reserved headroom was eaten by shared traffic"
        );
    }
}

// ---------------------------------------------------------------------
// Prefix-index lifecycle: pinned backing is disjoint from every other
// owner, and no hit can ever reference freed GPU blocks
// ---------------------------------------------------------------------

/// Random interleavings of request allocs/frees with prefix lifecycle
/// ops (record-by-carve, demote, drop, lookup). Invariants on every
/// step: pool conservation *including pinned prefix extents*, CPU-pool
/// agreement with the index, and full disjoint coverage of the block
/// space by free ∪ request-held ∪ prefix-held — which is exactly the
/// "no prefix hit ever references freed GPU blocks" property, since a
/// hit can only return an entry whose extents the index still owns.
#[test]
fn prop_prefix_backing_disjoint_and_conserved() {
    for seed in 0..200u64 {
        let mut rng = Rng::new(seed + 9_001);
        let total = rng.range_u64(32, 256) as u32;
        let mut gpu = GpuPool::new(total);
        let mut cpu = CpuBlockPool::new(total);
        let mut ix = PrefixIndex::new();
        let mut live: Vec<BlockSet> = Vec::new();
        let mut now = 0u64;
        for _step in 0..250 {
            now += rng.range_u64(1, 50);
            match rng.range_u64(0, 12) {
                0..=3 => {
                    let n = rng.range_u64(1, 16) as u32;
                    if let AllocOutcome::Granted { blocks, .. } =
                        gpu.alloc(n, Route::Shared)
                    {
                        live.push(blocks);
                    }
                }
                4..=5 => {
                    if !live.is_empty() {
                        let i =
                            rng.range_u64(0, live.len() as u64) as usize;
                        gpu.free(live.swap_remove(i), 0, None);
                    }
                }
                6..=7 => {
                    // record_prefix-style: carve backing out of a live
                    // request and hand ownership to the index.
                    let Some(i) = (!live.is_empty()).then(|| {
                        rng.range_u64(0, live.len() as u64) as usize
                    }) else {
                        continue;
                    };
                    if live[i].len() < 2 {
                        continue;
                    }
                    let nb =
                        rng.range_u64(1, live[i].len() as u64) as u32;
                    let backing =
                        PrefixBacking::Gpu(live[i].take_prefix(nb));
                    let key = PrefixKey(rng.range_u64(0, 8));
                    match ix.insert(key, nb, nb * 16, backing, 1.0, now)
                    {
                        Some(PrefixBacking::Gpu(old)) => {
                            gpu.free(old, 0, None)
                        }
                        Some(PrefixBacking::Cpu(old)) => {
                            cpu.release(old)
                        }
                        _ => {}
                    }
                }
                8 => {
                    // Gpu → Cpu demotion (synchronous free stands in
                    // for the pending-free D2H ride).
                    if let Some((key, blocks)) = ix.peek_lru_gpu() {
                        if let Some(cb) = cpu.alloc(blocks) {
                            let g =
                                ix.demote_to_cpu(key, cb).unwrap();
                            assert_eq!(g.len(), blocks, "seed {seed}");
                            gpu.free(g, 0, None);
                        }
                    }
                }
                9 => {
                    if let Some((key, _)) = ix.peek_lru_gpu() {
                        match ix.remove(key) {
                            Some(PrefixBacking::Gpu(b)) => {
                                gpu.free(b, 0, None)
                            }
                            _ => panic!("seed {seed}: bad backing"),
                        }
                    }
                }
                10 => {
                    if let Some((key, _)) = ix.peek_lru_cpu_unpinned() {
                        match ix.remove(key) {
                            Some(PrefixBacking::Cpu(b)) => {
                                cpu.release(b)
                            }
                            _ => panic!("seed {seed}: bad backing"),
                        }
                    }
                }
                _ => {
                    // Lookups churn the LRU secondary indices.
                    let key = PrefixKey(rng.range_u64(0, 8));
                    let _ = ix.lookup(key, now);
                }
            }
            // ---- Invariants, every step. ----
            let held: u32 = live.iter().map(|b| b.len()).sum();
            assert_eq!(
                gpu.free_blocks() + held + ix.resident_gpu_blocks(),
                total,
                "seed {seed}: conservation with pinned prefixes"
            );
            assert_eq!(
                cpu.used_blocks(),
                ix.resident_cpu_blocks(),
                "seed {seed}: cpu pool vs index disagree"
            );
            // Disjoint full coverage: free ∪ request-held ∪ prefix-held
            // owns every block exactly once — a hit can therefore never
            // reference a freed block.
            let mut all: Vec<u32> = Vec::with_capacity(total as usize);
            for b in &live {
                all.extend(b.iter_blocks().map(|id| id.0));
            }
            for e in ix.resident_gpu_extents() {
                all.extend(e.start..e.start + e.len);
            }
            all.extend(
                gpu.free_extents()
                    .iter()
                    .flat_map(|e| e.start..e.start + e.len),
            );
            let n_all = all.len();
            all.sort_unstable();
            all.dedup();
            assert_eq!(
                all.len(),
                n_all,
                "seed {seed}: block owned twice (prefix overlap)"
            );
            assert_eq!(n_all as u32, total, "seed {seed}: block lost");
        }
    }
}

// ---------------------------------------------------------------------
// CPU pool: ids never double-allocated
// ---------------------------------------------------------------------

#[test]
fn prop_cpu_pool_unique_ids() {
    for seed in 0..200u64 {
        let mut rng = Rng::new(seed + 11);
        let total = rng.range_u64(4, 200) as u32;
        let mut pool = CpuBlockPool::new(total);
        let mut live: Vec<Vec<tokencake::kvcache::CpuBlockId>> = Vec::new();
        for _ in 0..150 {
            if rng.next_f64() < 0.6 {
                let n = rng.range_u64(0, 12) as u32;
                if let Some(b) = pool.alloc(n) {
                    live.push(b);
                }
            } else if !live.is_empty() {
                let i = rng.range_u64(0, live.len() as u64) as usize;
                pool.release(live.swap_remove(i));
            }
            // No id appears twice across live allocations.
            let mut all: Vec<u32> = live
                .iter()
                .flatten()
                .map(|b| b.0)
                .collect();
            let n_all = all.len();
            all.sort_unstable();
            all.dedup();
            assert_eq!(all.len(), n_all, "duplicate id at seed {seed}");
            assert_eq!(
                pool.used_blocks() as usize, n_all,
                "accounting at seed {seed}"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Random DAGs: topo order, critical path, f_struct bounds
// ---------------------------------------------------------------------

fn random_dag(rng: &mut Rng) -> tokencake::graph::AppGraph {
    let n = rng.range_u64(2, 14) as usize;
    let mut gb = GraphBuilder::new("random");
    let ids: Vec<_> = (0..n)
        .map(|i| {
            let gens: Vec<u32> = (0..rng.range_u64(1, 4))
                .map(|_| rng.range_u64(5, 200) as u32)
                .collect();
            if gens.len() >= 2 && rng.next_f64() < 0.5 {
                gb.agent_with_call(
                    &format!("n{i}"),
                    &format!("t{}", rng.range_u64(0, 5)),
                    rng.range_u64(10, 400) as u32,
                    &gens,
                    CallSpec::new(FuncKind::WebSearch),
                )
            } else {
                gb.agent(
                    &format!("n{i}"),
                    &format!("t{}", rng.range_u64(0, 5)),
                    rng.range_u64(10, 400) as u32,
                    &gens,
                )
            }
        })
        .collect();
    // Forward edges only → acyclic by construction.
    for j in 1..n {
        let parents = rng.range_u64(1, 3.min(j as u64) + 1) as usize;
        for _ in 0..parents.min(j) {
            let p = rng.range_u64(0, j as u64) as usize;
            gb.edge(ids[p], ids[j]);
        }
    }
    gb.build().expect("forward-edge graph is a DAG")
}

#[test]
fn prop_dag_invariants() {
    for seed in 0..300u64 {
        let mut rng = Rng::new(seed + 31);
        let g = random_dag(&mut rng);
        // Topo order respects every edge.
        let pos: std::collections::HashMap<_, _> = g
            .topo_order()
            .iter()
            .enumerate()
            .map(|(i, &n)| (n, i))
            .collect();
        for node in g.nodes() {
            for &c in g.children(node.id) {
                assert!(pos[&node.id] < pos[&c], "seed {seed}");
                assert!(
                    g.depth(c) > g.depth(node.id),
                    "child depth must exceed parent (seed {seed})"
                );
            }
            assert!(
                (0.0..=1.0).contains(&g.f_struct(node.id)),
                "f_struct out of range (seed {seed})"
            );
        }
        // Exactly one connected critical path from a root to a leaf.
        let crit: Vec<_> = g
            .nodes()
            .filter(|n| g.is_critical(n.id))
            .map(|n| n.id)
            .collect();
        assert!(!crit.is_empty(), "seed {seed}");
        let roots_on_path = crit
            .iter()
            .filter(|&&c| g.parents(c).is_empty())
            .count();
        assert!(roots_on_path >= 1, "critical path must reach a root");
        // Every non-root critical node has a critical parent.
        for &c in &crit {
            if !g.parents(c).is_empty() {
                assert!(
                    g.parents(c).iter().any(|&p| g.is_critical(p)),
                    "critical path disconnected (seed {seed})"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// End-to-end workload invariants on random configurations
// ---------------------------------------------------------------------

#[test]
fn prop_engine_conservation_random_workloads() {
    let modes = [
        Mode::TokenCake,
        Mode::Vllm,
        Mode::Mooncake,
        Mode::AgentOnly,
        Mode::OffloadOnly,
        Mode::Parrot,
        Mode::Infercept,
        Mode::VllmPrefix,
    ];
    for seed in 0..24u64 {
        let mut rng = Rng::new(seed + 101);
        let mode = modes[rng.range_u64(0, modes.len() as u64) as usize];
        let qps = rng.range_f64(0.2, 2.0);
        let apps = rng.range_u64(2, 8) as usize;
        let frac = rng.range_f64(0.02, 0.2);
        let cfg = ServeConfig::default()
            .with_mode(mode)
            .with_seed(seed * 7 + 1)
            .with_gpu_mem_frac(frac);
        let g = random_dag(&mut rng);
        let spec = WorkloadSpec::poisson(&g, qps, apps)
            .with_dataset(if rng.next_f64() < 0.5 {
                Dataset::D1
            } else {
                Dataset::D2
            })
            .with_tool_noise(rng.range_f64(0.0, 0.5));
        let mut engine = SimEngine::new(cfg);
        let rep = engine.run_workload(&spec);

        // Every app completes (no silent drops).
        assert!(
            !rep.truncated,
            "seed {seed}: {mode:?} truncated ({})",
            rep.summary()
        );
        assert_eq!(
            rep.metrics.apps_completed as usize, apps,
            "seed {seed} {mode:?}"
        );
        // All memory returned, except backing the prefix index pins
        // (prefix-cache modes keep shared prefixes resident by design).
        assert_eq!(
            engine.st.gpu.free_blocks()
                + engine.st.prefix.resident_gpu_blocks(),
            engine.st.gpu.total(),
            "seed {seed} {mode:?}: gpu leak"
        );
        assert_eq!(engine.st.gpu.pending_free_blocks(), 0);
        assert_eq!(
            engine.st.cpu.used_blocks(),
            engine.st.prefix.resident_cpu_blocks(),
            "seed {seed} {mode:?}: cpu leak"
        );
        if !mode.prefix_cache() {
            assert!(engine.st.prefix.is_empty(), "{mode:?}");
        }
        // Offloads and uploads pair up by completion.
        assert_eq!(
            rep.metrics.offload_count, rep.metrics.upload_count,
            "seed {seed} {mode:?}"
        );
        // Latency sanity.
        assert!(rep.metrics.latency.mean_us() > 0.0);
        assert!(
            rep.metrics.latency.percentile_s(90.0)
                >= rep.metrics.latency.percentile_s(50.0)
        );
    }
}

#[test]
fn prop_non_offload_modes_never_touch_cpu() {
    for seed in 0..12u64 {
        for mode in [Mode::Vllm, Mode::VllmPrefix, Mode::Parrot,
                     Mode::AgentOnly] {
            let mut rng = Rng::new(seed + 900);
            let g = random_dag(&mut rng);
            let cfg = ServeConfig::default()
                .with_mode(mode)
                .with_seed(seed)
                .with_gpu_mem_frac(0.05);
            let spec = WorkloadSpec::poisson(&g, 1.0, 4);
            let mut engine = SimEngine::new(cfg);
            let rep = engine.run_workload(&spec);
            assert_eq!(rep.metrics.offload_count, 0, "{mode:?}");
            assert_eq!(engine.st.cpu.peak_used(), 0, "{mode:?}");
        }
    }
}

// ---------------------------------------------------------------------
// Batched multi-victim migration: conservation + bandwidth cap
// ---------------------------------------------------------------------

/// Random pressured cluster runs with an aggressive batched planner:
/// every migrated block must either land on a destination pool or be
/// accounted as a recompute drop, no planning window may exceed the
/// interconnect budget, and every shard pool must conserve.
#[test]
fn prop_batched_migration_conserves_and_respects_budget() {
    use tokencake::cluster::ClusterEngine;
    use tokencake::config::{ClusterConfig, PlacementPolicy};
    use tokencake::graph::templates;
    use tokencake::workload::ClusterWorkload;

    for seed in 0..6u64 {
        let mut rng = Rng::new(seed + 2024);
        let serve = ServeConfig::default()
            .with_mode(Mode::TokenCake)
            .with_seed(seed * 13 + 1)
            .with_gpu_mem_frac(rng.range_f64(0.03, 0.08));
        let shards = if seed % 2 == 0 { 2 } else { 4 };
        let mut cfg = ClusterConfig::default()
            .with_serve(serve)
            .with_shards(shards)
            .with_placement(PlacementPolicy::AgentAffinity);
        // Overlapping bands + short windows so the planner fires often.
        cfg.migrate_src_usage = 0.30;
        cfg.migrate_dst_usage = 0.60;
        cfg.migrate_payback = 0.5;
        cfg.rebalance_interval_us = 50_000;
        cfg.migrate_batch_budget_blocks =
            rng.range_u64(64, 512) as u32;
        let budget = cfg.migrate_batch_budget_blocks;
        let w = ClusterWorkload::mixed(
            &[
                (templates::code_writer(), 2.0),
                (templates::deep_research(), 1.0),
            ],
            2.0,
            12,
        )
        .with_tool_noise(0.25);
        let mut eng = ClusterEngine::new(cfg);
        let rep = eng.run(&w);
        assert!(!rep.truncated, "seed {seed}");
        assert_eq!(rep.aggregate.apps_completed, 12, "seed {seed}");
        // Conservation: sum of extents leaving sources == sum landing +
        // accounted recompute drops (no transfer in flight after a
        // completed run — a mid-flight app cannot finish).
        assert_eq!(
            rep.migration_blocks,
            rep.migration_landed_blocks + rep.migration_drop_blocks,
            "seed {seed}: migrated blocks neither landed nor dropped"
        );
        // The interconnect budget bounds every planning window.
        assert!(
            rep.max_window_migration_blocks <= budget as u64,
            "seed {seed}: window {} exceeded budget {budget}",
            rep.max_window_migration_blocks,
        );
        if rep.migrations > 0 {
            assert!(rep.migration_batches >= 1, "seed {seed}");
            assert!(
                rep.migrations >= rep.migration_batches,
                "seed {seed}"
            );
        }
        // Shard pools drained completely (modulo pinned prefixes).
        for i in 0..rep.num_shards {
            let st = &eng.shard(i).st;
            assert_eq!(
                st.gpu.free_blocks() + st.prefix.resident_gpu_blocks(),
                st.gpu.total(),
                "seed {seed} shard {i}: gpu leak"
            );
            assert_eq!(st.gpu.pending_free_blocks(), 0, "seed {seed}");
            assert_eq!(
                st.cpu.used_blocks(),
                st.prefix.resident_cpu_blocks(),
                "seed {seed}"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Autoscale drain: blocks conserved end to end, invariant every step
// ---------------------------------------------------------------------

/// Every drained shard's blocks are exactly accounted (landed on a
/// destination or dropped to recompute) and the per-shard
/// `free + pending + request-held + prefix == total` invariant holds at
/// every step of the evacuation, across random cluster shapes, victim
/// sizes, and drain victims. The drain must converge to retirement.
#[test]
fn prop_drain_conserves_blocks() {
    use tokencake::cluster::ClusterEngine;
    use tokencake::config::{ClusterConfig, PlacementPolicy};
    use tokencake::coordination::ReqState;
    use tokencake::graph::templates;
    use tokencake::temporal;
    use tokencake::workload::{SampledLengths, ToolSim};

    let check_pools = |eng: &ClusterEngine, n: usize, seed: u64| {
        for i in 0..n {
            let st = &eng.shard(i).st;
            let held: u32 = st
                .reqs
                .values()
                .map(|r| r.blocks.len() + r.upload_reserved.len())
                .sum();
            assert_eq!(
                st.gpu.free_blocks()
                    + st.gpu.pending_free_blocks()
                    + held
                    + st.prefix.resident_gpu_blocks(),
                st.gpu.total(),
                "seed {seed} shard {i}: gpu accounting broken"
            );
            let cpu_held: u32 = st
                .reqs
                .values()
                .map(|r| r.cpu_blocks.len() as u32)
                .sum();
            assert_eq!(
                st.cpu.used_blocks(),
                st.prefix.resident_cpu_blocks() + cpu_held,
                "seed {seed} shard {i}: cpu accounting broken"
            );
        }
    };

    for seed in 0..10u64 {
        let mut rng = Rng::new(seed + 0xD8A1);
        let shards = rng.range_u64(2, 5) as usize;
        let serve = ServeConfig::default()
            .with_mode(Mode::TokenCake)
            .with_seed(seed * 7 + 3)
            .with_gpu_mem_frac(0.05);
        let mut cfg = ClusterConfig::default()
            .with_serve(serve)
            .with_shards(shards)
            .with_placement(PlacementPolicy::RoundRobin);
        cfg.autoscale.enabled = true;
        // Floor = shards - 1: exactly one drain is permitted, so the
        // forced control steps below can never pick a *second* victim
        // (which would fold other shards' blocks into the accounting
        // this property pins).
        cfg.autoscale.min_shards = shards - 1;
        cfg.autoscale.max_shards = shards;
        cfg.autoscale.drain_confirm = 1;
        cfg.autoscale.cooldown_us = 0;
        cfg.migrate_batch_budget_blocks =
            rng.range_u64(64, 512) as u32;
        let mut eng = ClusterEngine::new(cfg);
        let g = templates::code_writer();
        for i in 0..shards {
            eng.shard_mut(i).register_template(&g);
        }
        // Random stalled apps across random shards.
        let tool_sim = ToolSim::new(0.0);
        let scales = SampledLengths {
            prompt_scale: 1.0,
            gen_scale: 1.0,
        };
        let mut placed_blocks = 0u64;
        let victim = rng.range_u64(0, shards as u64) as usize;
        for _ in 0..rng.range_u64(1, 7) {
            let shard = rng.range_u64(0, shards as u64) as usize;
            let blocks = rng.range_u64(4, 40) as u32;
            let app =
                eng.shard_mut(shard).inject_app(0, scales, &tool_sim);
            let st = &mut eng.shard_mut(shard).st;
            let rid = st.apps[&app].node_req[0].unwrap();
            st.waiting.retain(|&x| x != rid);
            let AllocOutcome::Granted { blocks: b, .. } =
                st.gpu.alloc(blocks, Route::Shared)
            else {
                panic!()
            };
            {
                let r = st.reqs.get_mut(&rid).unwrap();
                r.blocks = b;
                r.state = ReqState::Running;
            }
            temporal::call_start(
                st,
                rid,
                "web_search",
                Some(60_000_000),
                480,
                0,
            );
            if shard == victim {
                placed_blocks += blocks as u64;
            }
        }
        check_pools(&eng, shards, seed);
        assert!(
            eng.request_drain(victim),
            "seed {seed}: drain must start"
        );
        // Drive the evacuation to retirement, checking pools each step.
        let mut guard = 0u32;
        while eng.shard_phase(victim) != "retired" {
            eng.autoscale_step_now();
            check_pools(&eng, shards, seed);
            if eng.shard_phase(victim) == "retired" {
                break;
            }
            let progressed = eng.pump_next_event();
            check_pools(&eng, shards, seed);
            guard += 1;
            assert!(
                progressed || guard < 64,
                "seed {seed}: drain stopped making progress"
            );
            assert!(guard < 10_000, "seed {seed}: drain diverged");
        }
        // Drained shard fully empty; every block it shipped is landed
        // or dropped; the global ledger balances.
        let st = &eng.shard(victim).st;
        assert_eq!(st.gpu.free_blocks(), st.gpu.total(), "seed {seed}");
        assert_eq!(st.gpu.pending_free_blocks(), 0, "seed {seed}");
        assert_eq!(st.cpu.used_blocks(), 0, "seed {seed}");
        let (_migs, blocks, _batches, landed, dropped, _maxw) =
            eng.migration_stats();
        assert_eq!(
            blocks,
            landed + dropped,
            "seed {seed}: drained blocks neither landed nor dropped"
        );
        let stats = eng.autoscale_stats().unwrap();
        assert_eq!(
            stats.drained_app_blocks, placed_blocks,
            "seed {seed}: drained volume must equal what was parked \
             on the victim"
        );
        assert_eq!(stats.shards_retired, 1, "seed {seed}");
    }
}

// ---------------------------------------------------------------------
// Multi-GPU pool (§5 Multi-GPU Support): lockstep conservation
// ---------------------------------------------------------------------

#[test]
fn prop_multi_gpu_lockstep_conservation() {
    use tokencake::kvcache::{MultiGpuPool, Route, ShardedAlloc};
    for seed in 0..150u64 {
        let mut rng = Rng::new(seed + 501);
        let tp = rng.range_u64(1, 5) as usize;
        let per_dev = rng.range_u64(8, 120) as u32;
        let mut m = MultiGpuPool::new(tp, per_dev);
        let mut live: Vec<ShardedAlloc> = Vec::new();
        for _ in 0..120 {
            match rng.range_u64(0, 10) {
                0..=4 => {
                    let n = rng.range_u64(0, 16) as u32;
                    let t = rng.range_u64(0, 3) as u16;
                    let route = if rng.next_f64() < 0.5 {
                        Route::Shared
                    } else {
                        Route::Reserved(t)
                    };
                    if let Some(a) = m.alloc(n, route) {
                        assert_eq!(a.blocks.len(), tp, "seed {seed}");
                        assert!(a.blocks.iter().all(|b| b.len() == n));
                        live.push(a);
                    }
                }
                5..=7 => {
                    if !live.is_empty() {
                        let i =
                            rng.range_u64(0, live.len() as u64) as usize;
                        let a = live.swap_remove(i);
                        let charged = a.reserved_charged;
                        m.free(a, if charged > 0 { Some(0) } else { None });
                    }
                }
                _ => {
                    let q = rng.range_u64(0, per_dev as u64 / 2) as u32;
                    m.set_quotas(&[(0, q)]);
                }
            }
            // Lockstep invariant: identical free counts on every device.
            let rows = m.pressure();
            let f0 = rows[0].free;
            assert!(
                rows.iter().all(|r| r.free == f0),
                "device divergence at seed {seed}"
            );
            let held: u32 = live.iter().map(|a| a.len()).sum();
            assert_eq!(f0 + held, per_dev, "conservation seed {seed}");
        }
    }
}

// ---------------------------------------------------------------------
// Metric aggregation is order-insensitive (cluster rollup contract)
// ---------------------------------------------------------------------

/// Shards report in whatever order the reducer visits them; the
/// aggregate digest must not depend on it. These build random per-shard
/// bundles and absorb them under different permutations.
fn random_bundle(rng: &mut Rng) -> MetricsBundle {
    let mut m = MetricsBundle::default();
    for _ in 0..rng.range_u64(0, 40) {
        m.latency.record_us(rng.range_u64(0, 5_000_000));
    }
    for _ in 0..rng.range_u64(0, 40) {
        m.request_latency.record_us(rng.range_u64(0, 2_000_000));
    }
    for _ in 0..rng.range_u64(0, 60) {
        m.stall_hist.record(rng.range_u64(0, 10_000_000));
    }
    for _ in 0..rng.range_u64(0, 60) {
        m.wire_hist.record(rng.range_u64(0, 500_000));
    }
    for _ in 0..rng.range_u64(0, 60) {
        m.queue_hist.record(rng.range_u64(0, 1_000_000));
    }
    m.counters.preemptions = rng.range_u64(0, 100);
    m.counters.recomputes = rng.range_u64(0, 100);
    m.counters.prefix_hits_gpu = rng.range_u64(0, 1000);
    m.counters.prefix_lookups = rng.range_u64(0, 2000);
    m.counters.planner_runs = rng.range_u64(0, 500);
    m.counters.planner_skips = rng.range_u64(0, 5000);
    m.swap_volume_blocks = rng.range_u64(0, 10_000);
    m.offload_count = rng.range_u64(0, 200);
    m.upload_count = rng.range_u64(0, 200);
    m.apps_completed = rng.range_u64(0, 50);
    m.makespan_us = rng.range_u64(0, 600_000_000);
    m
}

#[test]
fn prop_metrics_absorb_is_order_insensitive() {
    for seed in 0..200u64 {
        let mut rng = Rng::new(seed + 901);
        let n = rng.range_u64(2, 7) as usize;
        let bundles: Vec<MetricsBundle> =
            (0..n).map(|_| random_bundle(&mut rng)).collect();

        // Identity order vs a Fisher–Yates shuffle of the same bundles.
        let mut perm: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = rng.range_u64(0, i as u64 + 1) as usize;
            perm.swap(i, j);
        }
        let mut fwd = MetricsBundle::default();
        for b in &bundles {
            fwd.absorb(b);
        }
        let mut shuf = MetricsBundle::default();
        for &i in &perm {
            shuf.absorb(&bundles[i]);
        }
        // digest_line covers counters, volumes, latency sums and
        // percentiles, and all three histogram triplets.
        assert_eq!(
            fwd.digest_line("agg"),
            shuf.digest_line("agg"),
            "absorb order changed the aggregate at seed {seed} \
             (perm {perm:?})"
        );
        assert!(
            (fwd.throughput() - shuf.throughput()).abs() < 1e-12,
            "seed {seed}"
        );
    }
}

/// The latency recorder specifically: merge order must not leak into
/// any query — percentiles answer from a sorted view, sums and counts
/// are permutation-invariant by construction.
#[test]
fn prop_latency_merge_is_order_insensitive() {
    for seed in 0..200u64 {
        let mut rng = Rng::new(seed + 1201);
        let n = rng.range_u64(2, 6) as usize;
        let parts: Vec<LatencyRecorder> = (0..n)
            .map(|_| {
                let mut r = LatencyRecorder::new();
                for _ in 0..rng.range_u64(0, 50) {
                    r.record_us(rng.range_u64(0, 3_000_000));
                }
                r
            })
            .collect();
        let mut fwd = LatencyRecorder::new();
        for p in &parts {
            fwd.merge(p);
        }
        let mut rev = LatencyRecorder::new();
        for p in parts.iter().rev() {
            rev.merge(p);
        }
        let ps = [50.0, 90.0, 99.0, 99.9];
        assert_eq!(
            fwd.percentiles_us(ps),
            rev.percentiles_us(ps),
            "seed {seed}"
        );
        assert_eq!(fwd.total_us(), rev.total_us(), "seed {seed}");
        assert_eq!(fwd.len(), rev.len(), "seed {seed}");
        assert_eq!(fwd.max_us(), rev.max_us(), "seed {seed}");
    }
}

// ---------------------------------------------------------------------
// Fault injection: seeded replay determinism + accounted-loss
// conservation
// ---------------------------------------------------------------------

/// A seeded fault plan (random crashes + partition windows) replayed
/// twice produces byte-identical report digests, every application
/// still completes, and the conservation invariant extended with the
/// crash-loss ledger holds: migrated blocks settle into landed +
/// dropped + crash-wire-lost, and the engine's own pool check passes.
#[test]
fn prop_fault_replay_is_deterministic_and_conserving() {
    use tokencake::cluster::ClusterEngine;
    use tokencake::config::{ClusterConfig, PlacementPolicy};
    use tokencake::graph::templates;
    use tokencake::workload::ClusterWorkload;

    for seed in 0..6u64 {
        let mut rng = Rng::new(seed + 0xFA17);
        let shards = rng.range_u64(3, 6) as usize;
        let serve = ServeConfig::default()
            .with_mode(Mode::TokenCake)
            .with_seed(seed * 11 + 5)
            .with_gpu_mem_frac(0.06);
        let mut cfg = ClusterConfig::default()
            .with_serve(serve)
            .with_shards(shards)
            .with_placement(PlacementPolicy::AgentAffinity);
        cfg.faults.enabled = true;
        cfg.faults.seed = seed + 1;
        cfg.faults.crashes = rng.range_u64(1, 3) as u32;
        cfg.faults.partitions = rng.range_u64(0, 3) as u32;
        cfg.faults.window_start_us = 500_000;
        cfg.faults.window_len_us = 8_000_000;
        let w = ClusterWorkload::mixed(
            &[
                (templates::code_writer(), 2.0),
                (templates::deep_research(), 1.0),
            ],
            2.0,
            10,
        )
        .with_tool_noise(0.2);
        let mut eng_a = ClusterEngine::new(cfg.clone());
        let rep_a = eng_a.run(&w);
        let rep_b = ClusterEngine::new(cfg).run(&w);
        assert_eq!(
            rep_a.digest(),
            rep_b.digest(),
            "seed {seed}: fault replay diverged"
        );
        assert!(!rep_a.truncated, "seed {seed}");
        assert_eq!(rep_a.aggregate.apps_completed, 10, "seed {seed}");
        assert_eq!(
            rep_a.migration_blocks,
            rep_a.migration_landed_blocks
                + rep_a.migration_drop_blocks
                + rep_a.crash_lost_wire_blocks,
            "seed {seed}: migrated blocks unaccounted under faults"
        );
        eng_a
            .check_conservation()
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    }
}

// ---------------------------------------------------------------------
// QoS admission gate: no starvation under Batch floods
// ---------------------------------------------------------------------

/// Seeded Batch-flood workloads through the QoS gate: every deferred
/// arrival eventually admits or sheds (nothing left queued at end of
/// run), per-tier arrivals == admitted + shed, every admitted app
/// completes, block conservation holds, and a same-seed rerun is
/// byte-identical — the gate's aging queues are part of the
/// deterministic event clock, not a side channel.
#[test]
fn prop_no_starvation_under_flood() {
    use tokencake::cluster::ClusterEngine;
    use tokencake::config::{ClusterConfig, PlacementPolicy};
    use tokencake::graph::templates;
    use tokencake::qos::Tier;
    use tokencake::workload::ClusterWorkload;

    for seed in 0..5u64 {
        let mut rng = Rng::new(seed + 0x0905);
        let shards = rng.range_u64(2, 4) as usize;
        let apps = rng.range_u64(8, 14) as usize;
        let serve = ServeConfig::default()
            .with_mode(Mode::TokenCake)
            .with_seed(seed * 13 + 3)
            .with_gpu_mem_frac(0.08);
        let mut cfg = ClusterConfig::default()
            .with_serve(serve)
            .with_shards(shards)
            .with_placement(PlacementPolicy::AgentAffinity);
        cfg.qos.enabled = true;
        // A tight Batch bucket so the flood defers hard, with aging
        // fast enough that deferred arrivals reach the top level well
        // inside the run — the no-starvation path must carry them.
        cfg.qos.rate_per_s = [8.0, 4.0, 0.5];
        cfg.qos.burst = [4, 2, 1];
        cfg.qos.age_promote_us = 1_000_000;
        let w = ClusterWorkload::mixed(
            &[
                (templates::code_writer(), 1.0),
                (templates::deep_research(), 3.0),
            ],
            4.0,
            apps,
        )
        .with_tiers(&[Tier::Interactive, Tier::Batch]);
        let mut eng_a = ClusterEngine::new(cfg.clone());
        let rep_a = eng_a.run(&w);
        let rep_b = ClusterEngine::new(cfg).run(&w);
        assert_eq!(
            rep_a.digest(),
            rep_b.digest(),
            "seed {seed}: QoS rerun diverged"
        );
        assert!(!rep_a.truncated, "seed {seed}");
        assert_eq!(
            rep_a.qos_starved, 0,
            "seed {seed}: requests starved in the gate"
        );
        let mut admitted_total = 0u64;
        let mut arrivals_total = 0u64;
        for i in 0..tokencake::qos::TIERS {
            assert_eq!(
                rep_a.qos_arrivals[i],
                rep_a.qos_admitted[i] + rep_a.qos_shed[i],
                "seed {seed}: tier {i} accounting broken"
            );
            admitted_total += rep_a.qos_admitted[i];
            arrivals_total += rep_a.qos_arrivals[i];
        }
        assert_eq!(arrivals_total, apps as u64, "seed {seed}");
        assert_eq!(
            rep_a.aggregate.apps_completed, admitted_total,
            "seed {seed}: an admitted app did not complete"
        );
        eng_a
            .check_conservation()
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    }
}

// ---------------------------------------------------------------------
// Parallel execution: conservation + serial parity under load
// ---------------------------------------------------------------------

/// Seeded mixed workloads through the parallel engine: the
/// scoped-thread phases must conserve blocks exactly like the serial
/// oracle (pool ∪ requests ∪ prefix ∪ wire all accounted) and produce
/// the byte-identical digest a serial run of the same seed produces —
/// the concurrency contract under migration, offload, and tool noise.
#[test]
fn prop_parallel_conserves_blocks() {
    use tokencake::cluster::ClusterEngine;
    use tokencake::config::{ClusterConfig, PlacementPolicy};
    use tokencake::graph::templates;
    use tokencake::workload::ClusterWorkload;

    for seed in 0..6u64 {
        let mut rng = Rng::new(seed + 0x9A11);
        let shards = rng.range_u64(2, 8) as usize;
        let apps = rng.range_u64(8, 14) as usize;
        let serve = ServeConfig::default()
            .with_mode(Mode::TokenCake)
            .with_seed(seed * 7 + 1)
            .with_gpu_mem_frac(0.06);
        let cfg = ClusterConfig::default()
            .with_serve(serve)
            .with_shards(shards)
            .with_placement(PlacementPolicy::AgentAffinity);
        let w = ClusterWorkload::mixed(
            &[
                (templates::code_writer(), 2.0),
                (templates::deep_research(), 1.0),
            ],
            2.0,
            apps,
        )
        .with_tool_noise(0.2);
        let mut par =
            ClusterEngine::new(cfg.clone().with_parallel(true));
        let rep_par = par.run(&w);
        let rep_ser = ClusterEngine::new(cfg).run(&w);
        assert_eq!(
            rep_par.digest(),
            rep_ser.digest(),
            "seed {seed}: parallel diverged from the serial oracle"
        );
        assert!(!rep_par.truncated, "seed {seed}");
        par.check_conservation()
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    }
}

// ---------------------------------------------------------------------
// Latency attribution: exact phase conservation on mixed workloads
// ---------------------------------------------------------------------

/// Seeded mixed workloads — random shard counts, tool noise, QoS gating,
/// and crash injection — through the phase ledger: every finished
/// request's phases must sum to its end-to-end latency *exactly* (the
/// ledger tiles [spawn − qos_wait, finish] with no gaps or overlaps),
/// the ledgers rebuilt from the exported trace alone must match the
/// live ones byte-for-byte, and a same-seed rerun without tracing must
/// produce the identical digest — capture is passive, attribution is
/// part of the deterministic clockwork.
#[test]
fn prop_phase_ledger_conserves_latency() {
    use tokencake::cluster::ClusterEngine;
    use tokencake::config::{ClusterConfig, PlacementPolicy};
    use tokencake::graph::templates;
    use tokencake::qos::Tier;
    use tokencake::workload::ClusterWorkload;

    for seed in 0..6u64 {
        let mut rng = Rng::new(seed + 0xA77B);
        let shards = rng.range_u64(2, 5) as usize;
        let apps = rng.range_u64(8, 14) as usize;
        let serve = ServeConfig::default()
            .with_mode(Mode::TokenCake)
            .with_seed(seed * 17 + 2)
            .with_gpu_mem_frac(0.06);
        let mut cfg = ClusterConfig::default()
            .with_serve(serve)
            .with_shards(shards)
            .with_placement(PlacementPolicy::AgentAffinity);
        // Odd seeds run the hard cases: QoS deferral phases and a
        // random crash mid-run (requeue/recompute attribution).
        if seed % 2 == 1 {
            cfg.qos.enabled = true;
            cfg.qos.rate_per_s = [8.0, 4.0, 0.5];
            cfg.qos.burst = [4, 2, 1];
            cfg.qos.age_promote_us = 1_000_000;
            cfg.faults.enabled = true;
            cfg.faults.seed = seed + 3;
            cfg.faults.crashes = 1;
            cfg.faults.window_start_us = 500_000;
            cfg.faults.window_len_us = 8_000_000;
        }
        let mut w = ClusterWorkload::mixed(
            &[
                (templates::code_writer(), 2.0),
                (templates::deep_research(), 1.0),
            ],
            2.0,
            apps,
        )
        .with_tool_noise(0.2);
        if seed % 2 == 1 {
            w = w.with_tiers(&[Tier::Interactive, Tier::Batch]);
        }
        let mut traced = ClusterEngine::new(cfg.clone());
        traced.enable_trace();
        let rep_a = traced.run(&w);
        assert!(!rep_a.truncated, "seed {seed}");
        // Conservation + live-vs-trace byte equality for every
        // finished request.
        traced
            .check_attrib()
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert!(
            !traced.render_ledgers().is_empty(),
            "seed {seed}: attribution audited nothing"
        );
        // Capture is passive: the untraced rerun is byte-identical.
        let rep_b = ClusterEngine::new(cfg).run(&w);
        assert_eq!(
            rep_a.digest(),
            rep_b.digest(),
            "seed {seed}: tracing perturbed the run"
        );
    }
}
