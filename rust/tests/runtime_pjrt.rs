//! Cross-language integration: the Python-AOT artifacts loaded and
//! executed from Rust via PJRT, with numeric checks implemented
//! independently in Rust.
//!
//! Skips (passes trivially) when `artifacts/` hasn't been built — run
//! `make artifacts` first.

use std::path::PathBuf;

use tokencake::runtime::TinyQwen;

fn artifacts() -> Option<PathBuf> {
    let dir = tokencake::runtime::artifacts_dir();
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn loads_and_reports_config() {
    let Some(dir) = artifacts() else { return };
    let m = TinyQwen::load(&dir).expect("load artifacts");
    assert_eq!(m.vocab, 512);
    assert_eq!(m.n_layers, 2);
    assert_eq!(m.decode_batch, 8);
    assert!(m.platform().to_lowercase().contains("cpu")
        || m.platform().to_lowercase().contains("host"));
}

#[test]
fn prefill_shapes_and_finiteness() {
    let Some(dir) = artifacts() else { return };
    let m = TinyQwen::load(&dir).unwrap();
    let prompt: Vec<i32> = (1..=17).collect();
    let out = m.prefill(&prompt).unwrap();
    assert_eq!(out.logits.len(), m.vocab);
    assert_eq!(
        out.k.len(),
        m.n_layers * m.prefill_len * m.n_heads * m.head_dim
    );
    assert!(out.logits.iter().all(|x| x.is_finite()));
    assert!(out.k.iter().all(|x| x.is_finite()));
    // Prompt too long / empty must error.
    assert!(m.prefill(&[]).is_err());
    assert!(m.prefill(&vec![1; m.prefill_len + 1]).is_err());
}

#[test]
fn prefill_deterministic() {
    let Some(dir) = artifacts() else { return };
    let m = TinyQwen::load(&dir).unwrap();
    let prompt: Vec<i32> = (10..40).collect();
    let a = m.prefill(&prompt).unwrap();
    let b = m.prefill(&prompt).unwrap();
    assert_eq!(a.logits, b.logits);
}

/// The core serving-path consistency check: prefill(prompt) followed by
/// teacher-forced decode steps must reproduce the logits that a fresh
/// prefill over the extended prompt yields.
#[test]
fn decode_matches_extended_prefill() {
    let Some(dir) = artifacts() else { return };
    let m = TinyQwen::load(&dir).unwrap();
    let b = m.decode_batch;
    let slot = 3usize;
    let prompt: Vec<i32> = vec![11, 45, 3, 200, 77, 150, 9];
    let n = prompt.len();

    // Prefill, scatter into slot `slot` of the batched cache.
    let pre = m.prefill(&prompt).unwrap();
    let stride = m.slot_stride(); // max_len*H*D per (layer, slot)
    let row = m.n_heads * m.head_dim;
    let mut k = vec![0f32; m.cache_len()];
    let mut v = vec![0f32; m.cache_len()];
    for l in 0..m.n_layers {
        for t in 0..n {
            let src = (l * m.prefill_len + t) * row;
            let dst = (l * b + slot) * stride + t * row;
            k[dst..dst + row].copy_from_slice(&pre.k[src..src + row]);
            v[dst..dst + row].copy_from_slice(&pre.v[src..src + row]);
        }
    }

    // Decode three teacher-forced continuation tokens.
    let continuation = [400i32, 31, 256];
    let mut logits_after = Vec::new();
    let mut len = n;
    let (mut kc, mut vc) = (k, v);
    for &tok in &continuation {
        let mut tokens = vec![0i32; b];
        let mut lens = vec![0i32; b];
        tokens[slot] = tok;
        lens[slot] = len as i32;
        let out = m.decode(&tokens, &kc, &vc, &lens).unwrap();
        logits_after =
            out.logits[slot * m.vocab..(slot + 1) * m.vocab].to_vec();
        kc = out.k;
        vc = out.v;
        len += 1;
    }

    // Fresh prefill over prompt ++ continuation must match the last
    // decode step's logits.
    let mut full = prompt.clone();
    full.extend_from_slice(&continuation);
    let re = m.prefill(&full).unwrap();
    let max_err = re
        .logits
        .iter()
        .zip(logits_after.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(
        max_err < 2e-3,
        "decode path diverges from full prefill: max_err={max_err}"
    );
}

#[test]
fn decode_slots_are_independent() {
    let Some(dir) = artifacts() else { return };
    let m = TinyQwen::load(&dir).unwrap();
    let b = m.decode_batch;
    let zeros_k = vec![0f32; m.cache_len()];
    let zeros_v = vec![0f32; m.cache_len()];
    let mut tokens = vec![0i32; b];
    tokens[0] = 42;
    let lens = vec![0i32; b];
    let a = m.decode(&tokens, &zeros_k, &zeros_v, &lens).unwrap();
    // Garbage in other slots' caches must not leak into slot 0.
    let mut dirty_k = zeros_k.clone();
    let stride = m.slot_stride();
    for l in 0..m.n_layers {
        for s in 1..b {
            let at = (l * b + s) * stride;
            for x in dirty_k[at..at + stride].iter_mut() {
                *x = 123.0;
            }
        }
    }
    let c = m.decode(&tokens, &dirty_k, &zeros_v, &lens).unwrap();
    let a0 = &a.logits[..m.vocab];
    let c0 = &c.logits[..m.vocab];
    let max_err = a0
        .iter()
        .zip(c0)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 1e-5, "slot leakage: {max_err}");
}

/// Paged attention artifact vs an independent Rust implementation.
#[test]
fn paged_attention_matches_rust_reference() {
    let Some(dir) = artifacts() else { return };
    let m = TinyQwen::load(&dir).unwrap();
    // Shapes fixed by aot.py: B=4, P=64, page=16, PPS=16, H/D from model.
    let (b, p, page, pps) = (4usize, 64usize, 16usize, 16usize);
    let (h, d) = (m.n_heads, m.head_dim);

    // Deterministic pseudo-random inputs.
    let mut seed = 0x12345678u64;
    let mut rnd = || {
        seed ^= seed << 13;
        seed ^= seed >> 7;
        seed ^= seed << 17;
        ((seed >> 11) as f64 / (1u64 << 53) as f64) as f32 - 0.5
    };
    let q: Vec<f32> = (0..b * h * d).map(|_| rnd()).collect();
    let kp: Vec<f32> = (0..p * page * h * d).map(|_| rnd()).collect();
    let vp: Vec<f32> = (0..p * page * h * d).map(|_| rnd()).collect();
    // Block table: sequence s uses pages [s*pps .. (s+1)*pps).
    let table: Vec<i32> = (0..b * pps).map(|i| i as i32).collect();
    let lens: Vec<i32> = vec![37, 128, 1, 256];

    let got = m
        .paged_attn(&q, &kp, &vp, &table, &lens, (b, p, page, h, d))
        .unwrap();

    // Independent reference: gather pages, masked softmax attention.
    let scale = 1.0 / (d as f32).sqrt();
    let mut want = vec![0f32; b * h * d];
    for s in 0..b {
        let valid = lens[s] as usize;
        for hh in 0..h {
            let qv = &q[(s * h + hh) * d..(s * h + hh + 1) * d];
            let mut scores = Vec::with_capacity(valid);
            for pos in 0..valid {
                let pg = table[s * pps + pos / page] as usize;
                let off = ((pg * page + pos % page) * h + hh) * d;
                let kv = &kp[off..off + d];
                let dot: f32 = qv.iter().zip(kv).map(|(a, b)| a * b).sum();
                scores.push(dot * scale);
            }
            let mx = scores.iter().copied().fold(f32::MIN, f32::max);
            let exps: Vec<f32> =
                scores.iter().map(|x| (x - mx).exp()).collect();
            let denom: f32 = exps.iter().sum();
            let out = &mut want[(s * h + hh) * d..(s * h + hh + 1) * d];
            for (pos, &w) in exps.iter().enumerate() {
                let pg = table[s * pps + pos / page] as usize;
                let off = ((pg * page + pos % page) * h + hh) * d;
                for i in 0..d {
                    out[i] += w / denom * vp[off + i];
                }
            }
        }
    }
    let max_err = got
        .iter()
        .zip(want.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(
        max_err < 1e-4,
        "paged attention artifact diverges from rust ref: {max_err}"
    );
}

/// Real-engine smoke: the full coordinator over the compiled model with a
/// small pipeline — every app completes, memory closes, offloads pair.
#[test]
fn real_engine_serves_small_workload() {
    use tokencake::config::Mode;
    use tokencake::engine::real::{real_engine_config, RealEngine};
    use tokencake::graph::{CallSpec, FuncKind, GraphBuilder};

    let Some(dir) = artifacts() else { return };
    let mut gb = GraphBuilder::new("itest");
    let a = gb.agent("a", "planner", 16, &[8]);
    let b = gb.agent_with_call(
        "b",
        "worker",
        16,
        &[8, 8],
        CallSpec::new(FuncKind::FileRead).with_predict_time_us(100_000),
    );
    gb.edge(a, b);
    let g = gb.build().unwrap();

    let cfg = real_engine_config(Mode::TokenCake, 11);
    let mut engine = RealEngine::new(cfg, &dir).unwrap();
    let report = engine.serve(&g, 4, 150_000).unwrap();
    assert_eq!(report.metrics.apps_completed, 4);
    assert!(report.tokens_generated >= 4 * 10);
    assert_eq!(
        report.metrics.offload_count,
        report.metrics.upload_count
    );
    assert_eq!(engine.st.cpu.used_blocks(), 0);
    assert_eq!(
        engine.st.gpu.free_blocks(),
        engine.st.gpu.total()
    );
}
