//! Determinism regression tests for the arena/index/extent hot path.
//!
//! The refactored serving loop is deterministic *by construction* — slab
//! arenas iterate in insertion order, the stalled/offloaded indices are
//! id-ordered BTreeSets, and no scheduling decision ever observes
//! `HashMap` iteration order — so the per-tick defensive sorts are gone.
//! These tests pin that contract: same seed + config ⇒ byte-identical
//! metric digests, for the single-worker engine and for 1/2/4-shard
//! cluster runs, with offload, migration, and tool-noise all in play.

use tokencake::cluster::ClusterEngine;
use tokencake::config::{
    ClusterConfig, Mode, PlacementPolicy, ServeConfig,
};
use tokencake::engine::sim::SimEngine;
use tokencake::graph::templates;
use tokencake::workload::{
    BurstSpec, ClusterWorkload, Dataset, WorkloadSpec,
};

fn engine_digest(seed: u64) -> String {
    let cfg = ServeConfig::default()
        .with_mode(Mode::TokenCake)
        .with_seed(seed)
        .with_gpu_mem_frac(0.05);
    let g = templates::code_writer();
    let spec = WorkloadSpec::poisson(&g, 1.0, 10)
        .with_dataset(Dataset::D1)
        .with_tool_noise(0.25);
    SimEngine::new(cfg).run_workload(&spec).digest()
}

fn cluster_digest(shards: usize, seed: u64) -> String {
    let serve = ServeConfig::default()
        .with_mode(Mode::TokenCake)
        .with_seed(seed)
        .with_gpu_mem_frac(0.05);
    let cfg = ClusterConfig::default()
        .with_serve(serve)
        .with_shards(shards)
        .with_placement(PlacementPolicy::AgentAffinity);
    let w = ClusterWorkload::mixed(
        &[
            (templates::code_writer(), 2.0),
            (templates::deep_research(), 1.0),
        ],
        2.0,
        16,
    )
    .with_dataset(Dataset::D1)
    .with_tool_noise(0.25);
    ClusterEngine::new(cfg).run(&w).digest()
}

/// Single-worker engine: two runs of the same seed/workload produce a
/// byte-identical digest (offload + noise active).
#[test]
fn engine_digest_byte_identical_across_runs() {
    let a = engine_digest(41);
    let b = engine_digest(41);
    assert_eq!(a, b, "same seed must be byte-identical");
    // The digest actually reflects the run.
    let c = engine_digest(42);
    assert_ne!(a, c, "different seeds should diverge");
}

/// Cluster engine: at every shard scale, re-running the same seed/config
/// reproduces the digest byte-for-byte (migration + forwarding in play).
#[test]
fn cluster_digest_byte_identical_across_shard_scales() {
    for shards in [1usize, 2, 4] {
        let a = cluster_digest(shards, 42);
        let b = cluster_digest(shards, 42);
        assert_eq!(a, b, "{shards} shards: digest must be reproducible");
    }
}

/// Different seeds diverge at cluster scale too (guards against a digest
/// that ignores the run).
#[test]
fn cluster_digest_depends_on_seed() {
    let a = cluster_digest(2, 42);
    let b = cluster_digest(2, 43);
    assert_ne!(a, b);
}

/// An elastic (autoscaled) run under a bursty workload: grows, drains,
/// warm-ups, and retirements are all scheduler decisions on the shared
/// clock, so same seed + config ⇒ byte-identical digests — including
/// the scale-event counters and the shard-lifetime histogram, which the
/// digest carries.
fn autoscale_digest(seed: u64) -> String {
    let serve = ServeConfig::default()
        .with_mode(Mode::TokenCake)
        .with_seed(seed)
        .with_gpu_mem_frac(0.06);
    let mut cfg = ClusterConfig::default()
        .with_serve(serve)
        .with_shards(1)
        .with_placement(PlacementPolicy::AgentAffinity);
    cfg.autoscale.enabled = true;
    cfg.autoscale.min_shards = 1;
    cfg.autoscale.max_shards = 6;
    cfg.autoscale.warmup_cost_us = 1_000_000;
    cfg.autoscale.cooldown_us = 1_000_000;
    cfg.autoscale.drain_confirm = 2;
    cfg.autoscale.interval_us = 100_000;
    let w = ClusterWorkload::mixed(
        &[
            (templates::code_writer(), 2.0),
            (templates::deep_research(), 1.0),
        ],
        0.3,
        24,
    )
    .with_dataset(Dataset::D1)
    .with_tool_noise(0.25)
    .with_burst(BurstSpec {
        burst_qps: 4.0,
        period_us: 60_000_000,
        duty: 0.25,
    });
    let rep = ClusterEngine::new(cfg).run(&w);
    assert!(!rep.truncated);
    assert!(rep.autoscale_enabled);
    rep.digest()
}

#[test]
fn autoscale_digest_byte_identical_across_runs() {
    let a = autoscale_digest(42);
    let b = autoscale_digest(42);
    assert_eq!(
        a, b,
        "autoscaled runs must be byte-identical across reruns"
    );
    assert!(a.contains("autoscale=true"));
    let c = autoscale_digest(43);
    assert_ne!(a, c, "different seeds should diverge");
}

// ---------------------------------------------------------------------
// Trace determinism (the obs-layer contract)
// ---------------------------------------------------------------------

fn engine_trace(seed: u64) -> String {
    let cfg = ServeConfig::default()
        .with_mode(Mode::TokenCake)
        .with_seed(seed)
        .with_gpu_mem_frac(0.05);
    let g = templates::code_writer();
    let spec = WorkloadSpec::poisson(&g, 1.0, 10)
        .with_dataset(Dataset::D1)
        .with_tool_noise(0.25);
    let mut eng = SimEngine::new(cfg);
    eng.enable_trace();
    eng.run_workload(&spec);
    eng.export_trace()
}

fn cluster_trace(shards: usize, seed: u64) -> String {
    let serve = ServeConfig::default()
        .with_mode(Mode::TokenCake)
        .with_seed(seed)
        .with_gpu_mem_frac(0.05);
    let cfg = ClusterConfig::default()
        .with_serve(serve)
        .with_shards(shards)
        .with_placement(PlacementPolicy::AgentAffinity);
    let w = ClusterWorkload::mixed(
        &[
            (templates::code_writer(), 2.0),
            (templates::deep_research(), 1.0),
        ],
        2.0,
        16,
    )
    .with_dataset(Dataset::D1)
    .with_tool_noise(0.25);
    let mut eng = ClusterEngine::new(cfg);
    eng.enable_trace();
    eng.run(&w);
    eng.export_trace()
}

/// The exported trace document is part of the determinism contract:
/// same seed + config ⇒ byte-identical JSON, single-worker and at every
/// cluster shard scale. (Records are integer-only and the merge is a
/// total order on `(at_us, shard, seq)`, so nothing float- or
/// hash-ordered can leak in.)
#[test]
fn trace_export_byte_identical_across_runs() {
    let a = engine_trace(41);
    let b = engine_trace(41);
    assert_eq!(a, b, "engine trace must be byte-identical");
    assert_ne!(a, engine_trace(42), "different seeds should diverge");

    for shards in [1usize, 2, 4] {
        let a = cluster_trace(shards, 42);
        let b = cluster_trace(shards, 42);
        assert_eq!(
            a, b,
            "{shards}-shard cluster trace must be byte-identical"
        );
    }
}

/// The epoch gate is live on real workloads (the digest lines pin its
/// exact run/skip counts across reruns and shard scales — see the
/// cluster digest tests above): on a pressured mixed run, steady-state
/// decode ticks dominate, so the planner must skip the majority of
/// scheduling steps, and the gate must account for every step exactly
/// once.
#[test]
fn epoch_gating_skips_majority_of_ticks() {
    let serve = ServeConfig::default()
        .with_mode(Mode::TokenCake)
        .with_seed(42)
        .with_gpu_mem_frac(0.05);
    let cfg = ClusterConfig::default()
        .with_serve(serve)
        .with_shards(4)
        .with_placement(PlacementPolicy::AgentAffinity);
    let w = ClusterWorkload::mixed(
        &[
            (templates::code_writer(), 2.0),
            (templates::deep_research(), 1.0),
        ],
        2.0,
        16,
    )
    .with_dataset(Dataset::D1)
    .with_tool_noise(0.25);
    let rep = ClusterEngine::new(cfg).run(&w);
    let c = &rep.aggregate.counters;
    assert_eq!(
        c.planner_runs + c.planner_skips,
        c.sched_steps,
        "every gated tick runs or skips, exactly once"
    );
    assert!(
        c.planner_skips > c.planner_runs,
        "planner ran {} of {} steps — epoch gating ineffective",
        c.planner_runs,
        c.sched_steps
    );
    // Spatial replans are window- and epoch-gated: far rarer than ticks.
    assert!(c.spatial_plans + c.spatial_plan_skips < c.sched_steps / 10);
}

// ---------------------------------------------------------------------
// QoS determinism (the admission gate on the shared clock)
// ---------------------------------------------------------------------

/// A QoS-gated run under a tiered Batch-heavy mix: token-bucket refills,
/// aging promotions, and sheds are all decisions on the shared event
/// clock, so same seed + config ⇒ byte-identical digests — including
/// the per-tier admission counters and latency triplets the digest
/// carries.
fn qos_digest(seed: u64) -> String {
    use tokencake::qos::Tier;
    let serve = ServeConfig::default()
        .with_mode(Mode::TokenCake)
        .with_seed(seed)
        .with_gpu_mem_frac(0.06);
    let mut cfg = ClusterConfig::default()
        .with_serve(serve)
        .with_shards(2)
        .with_placement(PlacementPolicy::AgentAffinity);
    cfg.qos.enabled = true;
    cfg.qos.rate_per_s = [8.0, 4.0, 0.5];
    cfg.qos.burst = [4, 2, 1];
    cfg.qos.age_promote_us = 1_000_000;
    let w = ClusterWorkload::mixed(
        &[
            (templates::code_writer(), 1.0),
            (templates::deep_research(), 2.0),
        ],
        3.0,
        12,
    )
    .with_dataset(Dataset::D1)
    .with_tool_noise(0.25)
    .with_tiers(&[Tier::Interactive, Tier::Batch]);
    let rep = ClusterEngine::new(cfg).run(&w);
    assert!(!rep.truncated);
    assert!(rep.qos_enabled);
    rep.digest()
}

#[test]
fn qos_digest_byte_identical_across_runs() {
    let a = qos_digest(42);
    let b = qos_digest(42);
    assert_eq!(
        a, b,
        "QoS-gated runs must be byte-identical across reruns"
    );
    assert!(a.contains("qos=true"));
    let c = qos_digest(43);
    assert_ne!(a, c, "different seeds should diverge");
}

// ---------------------------------------------------------------------
// Serial/parallel execution parity (the concurrency contract)
// ---------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
enum Variant {
    Plain,
    Autoscale,
    Faulted,
    Qos,
}

/// One cluster run in the requested execution mode, returning the two
/// byte-level artifacts the parity contract covers: the report digest
/// and the exported trace document.
fn parity_run(
    variant: Variant,
    shards: usize,
    parallel: bool,
) -> (String, String) {
    use tokencake::qos::Tier;
    let serve = ServeConfig::default()
        .with_mode(Mode::TokenCake)
        .with_seed(42)
        .with_gpu_mem_frac(0.05);
    let mut cfg = ClusterConfig::default()
        .with_serve(serve)
        .with_shards(shards)
        .with_placement(PlacementPolicy::AgentAffinity)
        .with_parallel(parallel);
    let mut w = ClusterWorkload::mixed(
        &[
            (templates::code_writer(), 2.0),
            (templates::deep_research(), 1.0),
        ],
        2.0,
        12,
    )
    .with_dataset(Dataset::D1)
    .with_tool_noise(0.25);
    match variant {
        Variant::Plain => {}
        Variant::Autoscale => {
            cfg.autoscale.enabled = true;
            cfg.autoscale.min_shards = 1;
            cfg.autoscale.max_shards = shards + 2;
            cfg.autoscale.warmup_cost_us = 1_000_000;
            cfg.autoscale.cooldown_us = 1_000_000;
            cfg.autoscale.drain_confirm = 2;
            cfg.autoscale.interval_us = 100_000;
        }
        Variant::Faulted => {
            cfg.faults.enabled = true;
            cfg.faults.crash_schedule = "1@3000".to_string();
        }
        Variant::Qos => {
            cfg.qos.enabled = true;
            cfg.qos.rate_per_s = [8.0, 4.0, 0.5];
            cfg.qos.burst = [4, 2, 1];
            cfg.qos.age_promote_us = 1_000_000;
            w = w.with_tiers(&[Tier::Interactive, Tier::Batch]);
        }
    }
    let mut eng = ClusterEngine::new(cfg);
    eng.enable_trace();
    let rep = eng.run(&w);
    (rep.digest(), eng.export_trace())
}

/// The `--parallel` engine and the `--serial` oracle are
/// indistinguishable: byte-identical digests AND byte-identical
/// exported traces per seed, at every shard scale, with the autoscale,
/// fault, and QoS control planes in play. This is the invariant that
/// lets the scoped-thread phases exist at all — any scheduling
/// decision leaking thread interleaving into observable state breaks
/// this test.
#[test]
fn serial_parallel_digest_parity() {
    for shards in [1usize, 2, 4, 8] {
        for variant in
            [Variant::Plain, Variant::Autoscale, Variant::Qos]
        {
            let (ds, ts) = parity_run(variant, shards, false);
            let (dp, tp) = parity_run(variant, shards, true);
            assert_eq!(
                ds, dp,
                "{variant:?} @ {shards} shards: digest parity broken"
            );
            assert_eq!(
                ts, tp,
                "{variant:?} @ {shards} shards: trace parity broken"
            );
        }
    }
    // Faulted runs need a survivor: the crash executor skips a crash
    // that would kill the last router-eligible shard, so a one-shard
    // faulted run is degenerate (and the explicit schedule names
    // shard 1). Parity still must hold at every multi-shard scale.
    for shards in [2usize, 4, 8] {
        let (ds, ts) = parity_run(Variant::Faulted, shards, false);
        let (dp, tp) = parity_run(Variant::Faulted, shards, true);
        assert_eq!(
            ds, dp,
            "Faulted @ {shards} shards: digest parity broken"
        );
        assert_eq!(
            ts, tp,
            "Faulted @ {shards} shards: trace parity broken"
        );
    }
}
