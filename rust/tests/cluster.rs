//! Cluster-layer integration tests: end-to-end sharded runs, routing
//! policy comparisons, cross-worker migration accounting, and the
//! byte-identical determinism contract of the shared event clock.

use tokencake::cluster::ClusterEngine;
use tokencake::config::{
    ClusterConfig, Mode, PlacementPolicy, ServeConfig,
};
use tokencake::coordination::ReqState;
use tokencake::graph::templates;
use tokencake::kvcache::{AllocOutcome, Route};
use tokencake::temporal;
use tokencake::workload::{
    BurstSpec, ClusterWorkload, Dataset, SampledLengths, ToolSim,
};

fn cfg(
    shards: usize,
    placement: PlacementPolicy,
    frac: f64,
    seed: u64,
) -> ClusterConfig {
    let serve = ServeConfig::default()
        .with_mode(Mode::TokenCake)
        .with_seed(seed)
        .with_gpu_mem_frac(frac);
    ClusterConfig::default()
        .with_serve(serve)
        .with_shards(shards)
        .with_placement(placement)
}

fn mixed(qps: f64, apps: usize) -> ClusterWorkload {
    ClusterWorkload::mixed(
        &[
            (templates::code_writer(), 2.0),
            (templates::deep_research(), 1.0),
        ],
        qps,
        apps,
    )
    .with_dataset(Dataset::D1)
}

/// Every policy completes a pressured mixed workload at 1/2/4 shards and
/// conserves every block pool.
#[test]
fn cluster_completes_mixed_workload_across_scales() {
    for shards in [1usize, 2, 4] {
        for placement in [
            PlacementPolicy::RoundRobin,
            PlacementPolicy::LeastLoaded,
            PlacementPolicy::AgentAffinity,
        ] {
            let mut eng = ClusterEngine::new(cfg(shards, placement, 0.08, 7));
            let rep = eng.run(&mixed(1.0, 12));
            assert!(
                !rep.truncated,
                "{shards} shards / {placement:?} truncated"
            );
            assert_eq!(
                rep.aggregate.apps_completed, 12,
                "{shards} shards / {placement:?}"
            );
            assert!(rep.aggregate.latency.mean_s() > 0.0);
            assert!(rep.aggregate.counters.tokens_generated > 0);
            for i in 0..shards {
                let st = &eng.shard(i).st;
                assert_eq!(
                    st.gpu.free_blocks()
                        + st.prefix.resident_gpu_blocks(),
                    st.gpu.total(),
                    "{shards}/{placement:?} shard {i} leaked GPU blocks"
                );
                assert_eq!(st.gpu.pending_free_blocks(), 0);
                assert_eq!(
                    st.cpu.used_blocks(),
                    st.prefix.resident_cpu_blocks()
                );
            }
        }
    }
}

/// The determinism contract the shared clock + FIFO event queue provide:
/// same seed, same `ClusterConfig` ⇒ byte-identical report digests, with
/// migration and noise in play.
#[test]
fn cluster_run_is_byte_identical_across_runs() {
    let run = |seed: u64| {
        let c = cfg(4, PlacementPolicy::AgentAffinity, 0.05, seed);
        let w = mixed(2.0, 16).with_tool_noise(0.25);
        ClusterEngine::new(c).run(&w).digest()
    };
    let a = run(42);
    let b = run(42);
    assert_eq!(a, b, "same seed+config must be byte-identical");
    // And the seed actually matters (guards against a digest that
    // ignores the run).
    let c = run(43);
    assert_ne!(a, c, "different seeds should diverge");
}

/// The headline routing claim: KV-aware agent-affinity placement beats
/// agent-oblivious round robin on mean end-to-end latency once there is
/// more than one shard to choose between (averaged over seeds).
#[test]
fn agent_affinity_beats_round_robin_at_scale() {
    for shards in [2usize, 4] {
        let seeds = [1u64, 2, 3];
        let mean = |placement: PlacementPolicy| -> f64 {
            let mut total = 0.0;
            for &seed in &seeds {
                let rep = ClusterEngine::new(cfg(
                    shards, placement, 0.05, seed,
                ))
                .run(&mixed(2.0, 24));
                assert!(!rep.truncated, "{placement:?} seed {seed}");
                assert_eq!(rep.aggregate.apps_completed, 24);
                total += rep.aggregate.latency.mean_s();
            }
            total / seeds.len() as f64
        };
        let rr = mean(PlacementPolicy::RoundRobin);
        let aff = mean(PlacementPolicy::AgentAffinity);
        assert!(
            aff < rr,
            "{shards} shards: affinity {aff:.2}s must beat \
             round-robin {rr:.2}s"
        );
    }
}

/// Force the migration path: two shards, affinity pinning load onto one,
/// tight pools, and an aggressive planner. Migrations must occur, be
/// accounted through the ledgers (swap volume), and conserve blocks.
#[test]
fn migration_triggers_and_conserves_blocks() {
    let mut c = cfg(2, PlacementPolicy::AgentAffinity, 0.03, 9);
    // Overlapping bands: any usage imbalance makes one shard a source
    // and another a destination, so the planner fires on every window
    // where a stalled candidate exists.
    c.migrate_src_usage = 0.30;
    c.migrate_dst_usage = 0.60;
    c.migrate_payback = 0.5;
    c.rebalance_interval_us = 50_000;
    let mut eng = ClusterEngine::new(c);
    let rep = eng.run(&mixed(2.0, 16));
    assert!(!rep.truncated);
    assert_eq!(rep.aggregate.apps_completed, 16);
    assert!(
        rep.migrations > 0,
        "planner never migrated: {}",
        rep.summary()
    );
    assert!(rep.migration_blocks > 0);
    // Migration traffic flows through the same ledger accounting as
    // local offloads, so it shows up in the aggregate swap volume.
    assert!(
        rep.aggregate.swap_volume_blocks >= rep.migration_blocks,
        "swap volume must include migrated blocks"
    );
    for i in 0..2 {
        let st = &eng.shard(i).st;
        assert_eq!(
            st.gpu.free_blocks() + st.prefix.resident_gpu_blocks(),
            st.gpu.total(),
            "shard {i}"
        );
        assert_eq!(st.gpu.pending_free_blocks(), 0, "shard {i}");
        assert_eq!(
            st.cpu.used_blocks(),
            st.prefix.resident_cpu_blocks(),
            "shard {i}"
        );
    }
}

/// Migration disabled ⇒ zero migrations, run still completes.
#[test]
fn migration_can_be_disabled() {
    let c = cfg(2, PlacementPolicy::AgentAffinity, 0.03, 9)
        .with_migration(false);
    let rep = ClusterEngine::new(c).run(&mixed(2.0, 12));
    assert!(!rep.truncated);
    assert_eq!(rep.migrations, 0);
    assert_eq!(rep.migration_blocks, 0);
    assert_eq!(rep.aggregate.apps_completed, 12);
}

/// One-shard cluster ≈ the single-worker engine: same completion count
/// and sane metrics under the same load (not identical sample-for-sample
/// — arrival RNG streams differ — but structurally equivalent).
#[test]
fn one_shard_cluster_matches_single_worker_shape() {
    let rep = ClusterEngine::new(cfg(
        1,
        PlacementPolicy::RoundRobin,
        0.08,
        5,
    ))
    .run(&mixed(0.5, 8));
    assert!(!rep.truncated);
    assert_eq!(rep.aggregate.apps_completed, 8);
    assert_eq!(rep.shards.len(), 1);
    assert_eq!(rep.migrations, 0, "nowhere to migrate with one shard");
    assert!(rep.aggregate.latency.percentile_s(99.0)
        >= rep.aggregate.latency.mean_s() * 0.5);
}

/// Hand-build a 2-shard cluster with `n` migratable stalled apps on
/// shard 0 (40 GPU blocks each, 60 s predicted stalls) and shard 0's
/// pool filled past the source threshold. Shard 1 is cold and empty.
fn burst_cluster(n: usize, budget_blocks: u32) -> ClusterEngine {
    let mut c = cfg(2, PlacementPolicy::RoundRobin, 0.05, 1);
    c.migrate_src_usage = 0.50;
    c.migrate_dst_usage = 0.60;
    c.migrate_payback = 0.5;
    c.migrate_batch_budget_blocks = budget_blocks;
    let mut eng = ClusterEngine::new(c);
    let g = templates::code_writer();
    // Identical registration order on every shard (cluster contract).
    for i in 0..2 {
        eng.shard_mut(i).register_template(&g);
    }
    let tool_sim = ToolSim::new(0.0);
    let scales = SampledLengths {
        prompt_scale: 1.0,
        gen_scale: 1.0,
    };
    for _ in 0..n {
        let app = eng.shard_mut(0).inject_app(0, scales, &tool_sim);
        let st = &mut eng.shard_mut(0).st;
        let rid = st.apps[&app].node_req[0].unwrap();
        st.waiting.retain(|&x| x != rid);
        let AllocOutcome::Granted { blocks, .. } =
            st.gpu.alloc(40, Route::Shared)
        else {
            panic!()
        };
        {
            let r = st.reqs.get_mut(&rid).unwrap();
            r.blocks = blocks;
            r.state = ReqState::Running;
        }
        temporal::call_start(
            st,
            rid,
            "web_search",
            Some(60_000_000),
            480,
            0,
        );
        assert_eq!(st.reqs[&rid].state, ReqState::Stalled);
    }
    // Saturate shard 0 past the source threshold.
    let st = &mut eng.shard_mut(0).st;
    let total = st.gpu.total();
    let used = total - st.gpu.free_blocks();
    let fill = (total as f64 * 0.8) as u32 - used;
    let AllocOutcome::Granted { .. } = st.gpu.alloc(fill, Route::Shared)
    else {
        panic!()
    };
    eng
}

/// The acceptance scenario: a pressure burst with ≥ 4 stalled apps
/// drains via ONE bandwidth-capped multi-victim batch — a single
/// planning event migrates the whole burst to the cold shard.
#[test]
fn pressure_burst_drains_in_one_multi_victim_batch() {
    let mut eng = burst_cluster(5, 2048);
    let moved = eng.rebalance_now();
    assert_eq!(moved, 5, "one planning event must drain the burst");
    let (migrations, blocks, batches, _landed, _dropped, max_window) =
        eng.migration_stats();
    assert_eq!(migrations, 5);
    assert_eq!(blocks, 200);
    assert_eq!(batches, 1, "the burst is one batch, not five windows");
    assert_eq!(max_window, 200);
    assert!(max_window <= 2048);
    // The victims' blocks left through the pending-free D2H path.
    assert_eq!(eng.shard(0).st.gpu.pending_free_blocks(), 200);
}

/// Partial-batch fallback: a tight interconnect budget bounds every
/// window; the remainder of the burst goes out in later windows.
#[test]
fn migration_window_respects_interconnect_budget() {
    let mut eng = burst_cluster(5, 100);
    // 40-block victims against a 100-block window: two fit.
    assert_eq!(eng.rebalance_now(), 2);
    assert_eq!(eng.rebalance_now(), 2);
    assert_eq!(eng.rebalance_now(), 1);
    assert_eq!(eng.rebalance_now(), 0, "burst fully drained");
    let (migrations, blocks, batches, _landed, _dropped, max_window) =
        eng.migration_stats();
    assert_eq!(migrations, 5);
    assert_eq!(blocks, 200);
    assert_eq!(batches, 3);
    assert!(max_window <= 100, "window exceeded the budget");
}

/// The prefix-directory acceptance scenario: spread one template across
/// shards (round robin guarantees spills), and the directory must turn
/// cold-shard admissions into remote prefix hits — saving prefill the
/// per-shard-index baseline re-computes — while replicating hot prefixes
/// under the interconnect budget. Same seed ⇒ byte-identical digests.
#[test]
fn remote_prefix_hits_beat_cold_prefill() {
    let run = |directory: bool| {
        let mut c = cfg(4, PlacementPolicy::RoundRobin, 0.5, 21);
        c.prefix_directory = directory;
        let mut eng = ClusterEngine::new(c);
        let rep = eng.run(&mixed(1.0, 24));
        assert!(!rep.truncated);
        assert_eq!(rep.aggregate.apps_completed, 24);
        rep
    };
    let with_dir = run(true);
    let without = run(false);
    // Spilled apps hit remotely instead of re-prefilling from scratch.
    assert!(
        with_dir.aggregate.counters.prefix_hits_remote > 0,
        "no remote hits: {}",
        with_dir.summary()
    );
    assert!(
        with_dir.aggregate.counters.prefill_tokens_saved
            > without.aggregate.counters.prefill_tokens_saved,
        "directory saved {} prefill tokens vs baseline {}",
        with_dir.aggregate.counters.prefill_tokens_saved,
        without.aggregate.counters.prefill_tokens_saved,
    );
    // Per-shard-index baseline never sees a remote copy.
    assert_eq!(without.aggregate.counters.prefix_hits_remote, 0);
    assert_eq!(without.prefix_replications, 0);
    // Hot prefixes replicate once remote hits cross the threshold, and
    // replica volume respects the window budget.
    assert!(
        with_dir.prefix_replications > 0,
        "threshold never triggered replication: {}",
        with_dir.summary()
    );
    assert!(with_dir.prefix_replicated_blocks > 0);
    // Deterministic: rerun is byte-identical, directory active.
    let rerun = run(true);
    assert_eq!(with_dir.digest(), rerun.digest());
}

/// Directory-driven runs satisfy the planner-gating contract too: the
/// prefix event feed must not re-open the epoch gate on steady ticks.
#[test]
fn prefix_directory_keeps_epoch_gating_effective() {
    let c = cfg(4, PlacementPolicy::AgentAffinity, 0.08, 17);
    let rep = ClusterEngine::new(c).run(&mixed(1.0, 16));
    assert!(!rep.truncated);
    let counters = &rep.aggregate.counters;
    assert_eq!(
        counters.planner_runs + counters.planner_skips,
        counters.sched_steps
    );
    assert!(
        counters.planner_skips > counters.planner_runs,
        "planner ran {} of {} steps with the directory active",
        counters.planner_runs,
        counters.sched_steps
    );
}

// ----------------------------------------------------------------------
// Elastic replica autoscaling
// ----------------------------------------------------------------------

/// The flash-crowd workload autoscaling exists for: short intense
/// bursts over a quiet base rate.
fn bursty(apps: usize) -> ClusterWorkload {
    ClusterWorkload::mixed(
        &[
            (templates::code_writer(), 2.0),
            (templates::deep_research(), 1.0),
        ],
        0.3,
        apps,
    )
    .with_dataset(Dataset::D1)
    .with_burst(BurstSpec {
        burst_qps: 4.0,
        period_us: 60_000_000,
        duty: 0.25,
    })
}

/// An elastic 1..8 cluster with a responsive controller.
fn autoscale_cfg(seed: u64) -> ClusterConfig {
    let mut c = cfg(1, PlacementPolicy::AgentAffinity, 0.06, seed);
    c.autoscale.enabled = true;
    c.autoscale.min_shards = 1;
    c.autoscale.max_shards = 8;
    c.autoscale.grow_watermark = 0.85;
    c.autoscale.drain_watermark = 0.30;
    c.autoscale.warmup_cost_us = 1_000_000;
    c.autoscale.cooldown_us = 1_000_000;
    c.autoscale.drain_confirm = 2;
    c.autoscale.interval_us = 100_000;
    c
}

/// Under the burst workload the controller grows the fleet, stays in
/// its bounds, completes everything, and loses zero blocks — across
/// grows, drains, and retirements.
#[test]
fn autoscale_grows_under_burst_and_conserves() {
    let mut eng = ClusterEngine::new(autoscale_cfg(5));
    let rep = eng.run(&bursty(36));
    assert!(!rep.truncated);
    assert_eq!(rep.aggregate.apps_completed, 36);
    assert!(rep.autoscale_enabled);
    assert!(
        rep.scale_up_events > 0,
        "bursts at 4 QPS over one small shard must trigger growth: {}",
        rep.summary()
    );
    assert!(
        rep.final_active_shards >= 1 && rep.final_active_shards <= 8,
        "serving count {} out of bounds",
        rep.final_active_shards
    );
    // Zero lost blocks: every pool conserved, the migration ledger
    // balanced, nothing in flight.
    eng.check_conservation().expect("conservation after autoscale");
    // Retired shards (if any) contributed lifetime samples.
    assert_eq!(
        rep.shards_retired as usize,
        rep.shard_lifetimes_us.len()
    );
}

/// The acceptance comparison (averaged over seeds): the elastic fleet
/// beats the fixed *min*-size fleet on p99 latency (it grows into the
/// bursts), while the fixed *max*-size fleet pays for its headroom
/// with worse effective GPU utilization than the elastic fleet (which
/// drains it away between bursts).
#[test]
fn autoscale_beats_fixed_min_p99_and_fixed_max_util() {
    let seeds = [1u64, 2, 3];
    let mut fixed1_p99 = 0.0;
    let mut fixed8_util = 0.0;
    let mut auto_p99 = 0.0;
    let mut auto_util = 0.0;
    for &seed in &seeds {
        let w = bursty(30);

        let rep = ClusterEngine::new(cfg(
            1,
            PlacementPolicy::AgentAffinity,
            0.06,
            seed,
        ))
        .run(&w);
        assert!(!rep.truncated, "fixed-1 seed {seed}");
        assert_eq!(rep.aggregate.apps_completed, 30);
        fixed1_p99 += rep.aggregate.latency.percentile_s(99.0);

        let rep = ClusterEngine::new(cfg(
            8,
            PlacementPolicy::AgentAffinity,
            0.06,
            seed,
        ))
        .run(&w);
        assert!(!rep.truncated, "fixed-8 seed {seed}");
        fixed8_util += rep.effective_util();

        let rep = ClusterEngine::new(autoscale_cfg(seed)).run(&w);
        assert!(!rep.truncated, "autoscale seed {seed}");
        assert_eq!(rep.aggregate.apps_completed, 30);
        auto_p99 += rep.aggregate.latency.percentile_s(99.0);
        auto_util += rep.effective_util();
    }
    let n = seeds.len() as f64;
    assert!(
        auto_p99 / n < fixed1_p99 / n,
        "autoscale p99 {:.1}s must beat fixed-min p99 {:.1}s",
        auto_p99 / n,
        fixed1_p99 / n
    );
    assert!(
        fixed8_util / n < auto_util / n,
        "fixed-max util {:.3} must be worse than autoscale util {:.3}",
        fixed8_util / n,
        auto_util / n
    );
}

/// Warming shards receive nothing: every application lands on a shard
/// that was active at its arrival, and cold capacity that never grew
/// served zero apps.
#[test]
fn autoscale_cold_and_warming_shards_serve_nothing() {
    let mut c = autoscale_cfg(7);
    // A warm-up so long it never completes within the run: the fleet
    // must keep serving from shard 0 alone.
    c.autoscale.warmup_cost_us = u64::MAX / 4;
    let rep = ClusterEngine::new(c).run(&bursty(12));
    assert!(!rep.truncated);
    assert_eq!(rep.aggregate.apps_completed, 12);
    assert_eq!(rep.shards[0].apps_completed, 12);
    for (i, m) in rep.shards.iter().enumerate().skip(1) {
        assert_eq!(m.apps_completed, 0, "shard {i} never activated");
    }
    assert_eq!(rep.final_active_shards, 1);
}

// ---------------------------------------------------------------------
// Fault injection and crash recovery
// ---------------------------------------------------------------------

fn crash_cfg(seed: u64, directory: bool) -> ClusterConfig {
    let mut c = cfg(4, PlacementPolicy::AgentAffinity, 0.06, seed);
    c.faults.enabled = true;
    c.faults.crash_schedule = "1@3000".into();
    c.prefix_directory = directory;
    if directory {
        // Replicate on the first remote hit so survivors hold warm
        // copies of the shared prefixes before the crash lands.
        c.prefix_replicate_threshold = 1;
    }
    c
}

/// A mid-run shard crash is survivable: the scheduled crash executes,
/// every application still completes (the dead shard's apps re-queue
/// through the router onto survivors), and block conservation holds
/// with the crash-loss ledger folded in.
#[test]
fn crash_recovery_completes_all_apps_and_conserves() {
    let mut eng = ClusterEngine::new(crash_cfg(11, true));
    let rep = eng.run(&mixed(2.0, 16).with_tool_noise(0.2));
    assert!(!rep.truncated);
    assert_eq!(rep.crashes, 1, "scheduled crash must execute");
    assert_eq!(rep.aggregate.apps_completed, 16);
    eng.check_conservation().expect("conservation after crash");
}

/// The replica-warmed recovery claim: with the prefix directory
/// replicating hot prefixes onto survivors before the crash, the dead
/// shard's re-queued applications find warm copies at their new homes
/// and save more re-prefill tokens than the identical crash with the
/// directory off (no replicas anywhere), averaged over seeds.
#[test]
fn replica_warmed_recovery_saves_reprefill_tokens() {
    let seeds = [1u64, 2, 3];
    let mut warmed = 0u64;
    let mut cold = 0u64;
    for &seed in &seeds {
        let w = mixed(2.0, 20).with_tool_noise(0.2);
        let rep = ClusterEngine::new(crash_cfg(seed, true)).run(&w);
        assert!(!rep.truncated, "warmed seed {seed}");
        assert_eq!(rep.aggregate.apps_completed, 20);
        assert_eq!(rep.crashes, 1, "warmed seed {seed}");
        warmed += rep.aggregate.counters.prefill_tokens_saved;
        let rep = ClusterEngine::new(crash_cfg(seed, false)).run(&w);
        assert!(!rep.truncated, "cold seed {seed}");
        assert_eq!(rep.aggregate.apps_completed, 20);
        assert_eq!(rep.crashes, 1, "cold seed {seed}");
        cold += rep.aggregate.counters.prefill_tokens_saved;
    }
    assert!(
        warmed > cold,
        "replica-warmed recovery must save more re-prefill tokens \
         than no-replica recovery ({warmed} vs {cold})"
    );
}

/// Aggregate rollup is the sum of the shard bundles.
#[test]
fn aggregate_is_sum_of_shards() {
    let rep = ClusterEngine::new(cfg(
        4,
        PlacementPolicy::LeastLoaded,
        0.08,
        3,
    ))
    .run(&mixed(1.0, 12));
    let apps: u64 = rep.shards.iter().map(|m| m.apps_completed).sum();
    assert_eq!(rep.aggregate.apps_completed, apps);
    let toks: u64 = rep
        .shards
        .iter()
        .map(|m| m.counters.tokens_generated)
        .sum();
    assert_eq!(rep.aggregate.counters.tokens_generated, toks);
    let lat_n: usize = rep.shards.iter().map(|m| m.latency.len()).sum();
    assert_eq!(rep.aggregate.latency.len(), lat_n);
}

// ---------------------------------------------------------------------
// Multi-tenant QoS: graceful degradation under a Batch flood
// ---------------------------------------------------------------------

/// The PR-8 tentpole scenario: a sustained Batch flood must not be able
/// to push Interactive p99 past its SLO. Same seeded workload twice —
/// QoS off, then the admission gate + SLO-headroom victim biasing on:
///
/// * Interactive p99 with QoS on beats the ungated run and stays
///   inside its SLO target.
/// * Nobody starves: every deferred arrival admitted or shed, per-tier
///   arrivals == admitted + shed, every admitted app completes.
/// * Graceful, not collapsed: aggregate effective utilization drops by
///   no more than the shed fraction (plus slack) — the gate trades
///   Batch *admission* for Interactive latency, it does not idle the
///   fleet.
#[test]
fn tiered_burst_protects_interactive() {
    use tokencake::qos::Tier;

    let workload = || {
        ClusterWorkload::mixed(
            &[
                (templates::code_writer(), 1.0),
                (templates::deep_research(), 5.0),
            ],
            6.0,
            24,
        )
        .with_dataset(Dataset::D1)
        .with_tiers(&[Tier::Interactive, Tier::Batch])
    };

    // Ungated baseline: the flood queues inside the shards, in front
    // of the Interactive apps. (Tier *attribution* follows the
    // workload labels even with the gate off, so the report's per-tier
    // p99 is comparable across the two runs.)
    let rep_off = ClusterEngine::new(cfg(
        2,
        PlacementPolicy::AgentAffinity,
        0.05,
        17,
    ))
    .run(&workload());
    assert!(!rep_off.truncated);
    assert!(!rep_off.qos_enabled);
    assert!(
        rep_off.aggregate.tier_latency[Tier::Interactive.index()]
            .len()
            > 0,
        "ungated run must still attribute Interactive latency"
    );

    // Gated run: a starvation-proof trickle for Batch, open door for
    // Interactive, and a 60 s Interactive SLO driving victim choices.
    let mut qcfg = cfg(2, PlacementPolicy::AgentAffinity, 0.05, 17);
    qcfg.qos.enabled = true;
    qcfg.qos.rate_per_s = [50.0, 4.0, 0.25];
    qcfg.qos.burst = [8, 4, 1];
    qcfg.qos.slo_us = [60_000_000, 120_000_000, 600_000_000];
    qcfg.qos.age_promote_us = 4_000_000;
    let rep_on = ClusterEngine::new(qcfg).run(&workload());
    assert!(!rep_on.truncated);
    assert!(rep_on.qos_enabled);
    assert_eq!(rep_on.qos_starved, 0, "gate starved a request");
    let mut admitted = 0u64;
    for i in 0..tokencake::qos::TIERS {
        assert_eq!(
            rep_on.qos_arrivals[i],
            rep_on.qos_admitted[i] + rep_on.qos_shed[i],
            "tier {i} accounting broken"
        );
        admitted += rep_on.qos_admitted[i];
    }
    assert_eq!(rep_on.aggregate.apps_completed, admitted);

    // Protection: gated Interactive p99 beats the flood baseline and
    // honors the SLO.
    let (p99_on, p99_off) =
        (rep_on.tier_p99_us[0], rep_off.tier_p99_us[0]);
    assert!(
        p99_on < p99_off,
        "QoS did not protect Interactive: p99 {p99_on}us gated vs \
         {p99_off}us ungated"
    );
    assert!(
        p99_on <= rep_on.qos_slo_us[0],
        "Interactive p99 {p99_on}us exceeds its {}us SLO",
        rep_on.qos_slo_us[0]
    );

    // Graceful degradation: utilization gives up at most the shed
    // fraction (plus 10% slack for batching-shape noise).
    let shed: u64 = rep_on.qos_shed.iter().sum();
    let arrivals: u64 = rep_on.qos_arrivals.iter().sum();
    let shed_frac = shed as f64 / arrivals as f64;
    assert!(
        rep_on.effective_util()
            >= rep_off.effective_util() * (1.0 - shed_frac) - 0.10,
        "utilization collapsed: {} gated vs {} ungated with only \
         {shed} of {arrivals} shed",
        rep_on.effective_util(),
        rep_off.effective_util()
    );
}
