//! Obs-layer integration tests: the trace auditor must pass on real
//! engine and cluster runs (1/2/4 shards), fail on a corrupted
//! timeline, and the Chrome export must round-trip losslessly through
//! its own parser.

use tokencake::cluster::ClusterEngine;
use tokencake::config::{
    ClusterConfig, Mode, PlacementPolicy, ServeConfig,
};
use tokencake::engine::sim::SimEngine;
use tokencake::graph::templates;
use tokencake::obs::export::parse_chrome_trace;
use tokencake::obs::{export_chrome_trace, TraceAuditor};
use tokencake::workload::{ClusterWorkload, Dataset, WorkloadSpec};

/// Tight memory so offloads, preemptions, and prefix traffic all fire.
fn engine_run_trace(seed: u64) -> String {
    let cfg = ServeConfig::default()
        .with_mode(Mode::TokenCake)
        .with_seed(seed)
        .with_gpu_mem_frac(0.05);
    let g = templates::code_writer();
    let spec = WorkloadSpec::poisson(&g, 1.0, 10)
        .with_dataset(Dataset::D1)
        .with_tool_noise(0.25);
    let mut eng = SimEngine::new(cfg);
    eng.enable_trace();
    let rep = eng.run_workload(&spec);
    assert!(!rep.truncated);
    eng.export_trace()
}

fn cluster_run_trace(shards: usize, seed: u64) -> String {
    let serve = ServeConfig::default()
        .with_mode(Mode::TokenCake)
        .with_seed(seed)
        .with_gpu_mem_frac(0.05);
    let cfg = ClusterConfig::default()
        .with_serve(serve)
        .with_shards(shards)
        .with_placement(PlacementPolicy::AgentAffinity);
    let w = ClusterWorkload::mixed(
        &[
            (templates::code_writer(), 2.0),
            (templates::deep_research(), 1.0),
        ],
        2.0,
        16,
    )
    .with_dataset(Dataset::D1)
    .with_tool_noise(0.25);
    let mut eng = ClusterEngine::new(cfg);
    eng.enable_trace();
    let rep = eng.run(&w);
    assert!(!rep.truncated);
    eng.export_trace()
}

/// A real single-worker run satisfies every ordering invariant, and the
/// audit actually covered work (requests finished, transfers paired).
#[test]
fn auditor_passes_single_worker_run() {
    let doc = engine_run_trace(41);
    let s = TraceAuditor::audit_chrome_trace(&doc)
        .expect("clean run must audit clean");
    assert!(s.records > 0);
    assert_eq!(s.shards, 1);
    assert!(s.finished_requests > 0, "no request span ever closed");
    assert!(s.transfers > 0, "tight memory should force transfers");
}

/// Cluster runs at 1/2/4 shards (migration + prefix directory in play)
/// audit clean too — the CI trace smoke in test form.
#[test]
fn auditor_passes_cluster_runs() {
    for shards in [1usize, 2, 4] {
        let doc = cluster_run_trace(shards, 42);
        let s = TraceAuditor::audit_chrome_trace(&doc)
            .unwrap_or_else(|e| {
                panic!("{shards}-shard trace failed audit: {e}")
            });
        assert!(s.records > 0, "{shards}-shard trace is empty");
        assert!(s.finished_requests > 0);
    }
}

/// Negative test: the auditor must actually reject a bad timeline. A
/// duplicated record re-uses a sequence number on its shard, violating
/// the strictly-increasing-seq clock invariant.
#[test]
fn auditor_rejects_corrupted_trace() {
    let doc = cluster_run_trace(2, 42);
    let mut records =
        parse_chrome_trace(&doc).expect("export must parse");
    assert!(!records.is_empty());
    records.push(records[0]);
    let err = TraceAuditor::audit(&records)
        .expect_err("duplicate seq must fail the audit");
    assert!(
        err.message.contains("seq"),
        "unexpected failure mode: {err}"
    );
}

/// The exporter and its parser are inverses on real traces: parse the
/// document back to records, re-export, and the bytes match. (Derived
/// lines — process metadata, counter tracks — are regenerated, which
/// only works if nothing lossy hides in the embedded records.)
#[test]
fn chrome_export_round_trips_losslessly() {
    let doc = cluster_run_trace(2, 42);
    let records = parse_chrome_trace(&doc).expect("export must parse");
    assert_eq!(export_chrome_trace(&records), doc);
}

/// End-to-end attribution parity under the hard cases — QoS deferral
/// and a planned mid-run crash: every finished request's phase ledger
/// conserves (Σ phases == end-to-end latency, exactly), and replaying
/// the exported trace through `obs::attrib::reconstruct` reproduces
/// the live ledgers byte-for-byte. This is `--assert-attrib` in test
/// form.
#[test]
fn analyze_from_trace_matches_live_ledger() {
    use tokencake::obs::attrib;
    use tokencake::qos::Tier;

    let serve = ServeConfig::default()
        .with_mode(Mode::TokenCake)
        .with_seed(43)
        .with_gpu_mem_frac(0.05);
    let mut cfg = ClusterConfig::default()
        .with_serve(serve)
        .with_shards(4)
        .with_placement(PlacementPolicy::AgentAffinity);
    cfg.faults.enabled = true;
    cfg.faults.crash_schedule = "1@3000".into();
    cfg.qos.enabled = true;
    let w = ClusterWorkload::mixed(
        &[
            (templates::code_writer(), 2.0),
            (templates::deep_research(), 1.0),
        ],
        2.0,
        16,
    )
    .with_dataset(Dataset::D1)
    .with_tool_noise(0.25)
    .with_tiers(&[Tier::Interactive, Tier::Batch]);

    let mut eng = ClusterEngine::new(cfg);
    eng.enable_trace();
    let rep = eng.run(&w);
    assert!(!rep.truncated);
    assert!(rep.crashes > 0, "planned crash must have executed");

    // Conservation + live-vs-trace byte equality, engine-checked.
    eng.check_attrib().expect("attribution check must pass");

    // And verified independently through the public pipeline.
    let live = eng.render_ledgers();
    assert!(!live.is_empty(), "no finished ledgers to compare");
    let records = parse_chrome_trace(&eng.export_trace())
        .expect("export must parse");
    let recon = attrib::reconstruct(&records);
    let from_trace = attrib::render_ledgers(&recon.finished());
    assert_eq!(live, from_trace, "trace replay diverged from live");

    // The same trace satisfies auditor rule 9 (phase conservation),
    // and critical paths come out deterministic and non-empty.
    let s = TraceAuditor::audit(&records)
        .expect("attribution trace must audit clean");
    assert!(s.phase_conserved > 0, "rule 9 audited no ledgers");
    let paths = attrib::critical_paths(&recon);
    assert!(!paths.is_empty());
    assert!(paths.iter().all(|p| p.makespan_us > 0));

    // Aggregates derived from the ledger flow into the report.
    assert!(rep.aggregate.stall_hidden_frac() >= 0.0);
    assert!(rep.aggregate.queue_wait_us_p99() > 0 || rep.qos_enabled);
    let prom = rep.prometheus_text();
    assert!(prom.contains("tokencake_phase_us{phase=\"decode\"}"));
    assert!(prom.contains("tokencake_stall_hidden_frac_milli"));
}

/// With tracing never enabled, a run records nothing: the export holds
/// no events (zero-capture is the default, not a filtered view).
#[test]
fn disabled_sink_records_nothing() {
    let cfg = ServeConfig::default()
        .with_mode(Mode::TokenCake)
        .with_seed(41)
        .with_gpu_mem_frac(0.05);
    let g = templates::code_writer();
    let spec = WorkloadSpec::poisson(&g, 1.0, 5).with_dataset(Dataset::D1);
    let mut eng = SimEngine::new(cfg);
    eng.run_workload(&spec);
    let records = parse_chrome_trace(&eng.export_trace())
        .expect("empty export must still parse");
    assert!(records.is_empty());
}
