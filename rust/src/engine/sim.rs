//! The discrete-event serving engine.
//!
//! Mirrors a vLLM-style continuous-batching loop: each engine iteration
//! runs one scheduling step (§3.2's four phases), then executes chunked
//! prefill plus one decode token for every running sequence, advancing the
//! simulated clock by the calibrated iteration time. Arrivals, tool
//! completions, standalone func-node delays, and block transfers are
//! events; everything the schedulers decide flows through the exact same
//! code paths the real PJRT engine uses.
//!
//! The inner loop is allocation-free on the steady state: the decode
//! batch snapshot reuses a scratch buffer, batch membership updates are
//! O(1) `BatchQueue` removals (never `Vec::retain` scans), and the
//! scheduler phases iterate incremental indices instead of cloning lists
//! or walking every request ever created.

use crate::config::ServeConfig;
use crate::coordination::{
    self, Action, AppId, ReqState, RequestId, ServeState,
};
use crate::graph::{AppGraph, NodeId, NodeKind};
use crate::kvcache::{AllocOutcome, TransferId};
use crate::metrics::MetricsBundle;
use crate::obs;
use crate::sim::{Clock, Event, EventQueue, Rng};
use crate::spatial;
use crate::temporal;
use crate::workload::{SampledLengths, ToolSim, WorkloadSpec};

/// Engine event alphabet.
#[derive(Debug, Clone, PartialEq, Eq)]
enum Ev {
    AppArrival { seq: u32 },
    ToolFinish { rid: RequestId },
    NodeDelayDone { app: AppId, node: NodeId },
    TransferDone { xfer: TransferId },
}

/// A `ToolFinish` whose request no longer lives on this worker — it was
/// migrated to another shard while the tool was running. The cluster
/// driver re-delivers it to the request's new home; standalone runs never
/// produce one.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OrphanedToolFinish {
    pub rid: RequestId,
    pub at_us: u64,
}

/// Result of a workload run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub mode: &'static str,
    pub metrics: MetricsBundle,
    /// True if the engine hit the safety iteration cap before finishing.
    pub truncated: bool,
}

impl RunReport {
    /// Re-export the headline numbers (see `MetricsBundle::summary`).
    pub fn summary(&self) -> String {
        format!("[{}] {}", self.mode, self.metrics.summary())
    }

    /// Canonical integer-only digest (determinism regression contract).
    pub fn digest(&self) -> String {
        format!(
            "mode={} truncated={}\n{}",
            self.mode,
            self.truncated,
            self.metrics.digest_line("run")
        )
    }
}

/// Discrete-event serving engine over [`ServeState`].
pub struct SimEngine {
    pub st: ServeState,
    clock: Clock,
    events: EventQueue<Ev>,
    rng: Rng,
    /// Safety valve against policy deadlocks in experimental configs.
    max_iterations: u64,
    /// Reusable decode-batch snapshot (the loop mutates `running`).
    scratch_batch: Vec<RequestId>,
    /// Reusable prefill-promotion list.
    scratch_promoted: Vec<RequestId>,
}

impl SimEngine {
    pub fn new(cfg: ServeConfig) -> Self {
        let seed = cfg.seed;
        Self {
            st: ServeState::new(cfg),
            clock: Clock::new(),
            events: EventQueue::new(),
            rng: Rng::new(seed),
            max_iterations: 3_000_000,
            scratch_batch: Vec::new(),
            scratch_promoted: Vec::new(),
        }
    }

    /// Current simulated time (µs).
    pub fn now_us(&self) -> u64 {
        self.clock.now_us()
    }

    // ------------------------------------------------------------------
    // Observability (see `crate::obs`)
    // ------------------------------------------------------------------

    /// Turn on full structured trace capture (`--trace`).
    pub fn enable_trace(&mut self) {
        self.st.trace.enable();
    }

    /// Arm only the bounded flight recorder (`--assert-*` runs) so
    /// failures dump recent context without full-capture cost.
    pub fn arm_flight(&mut self) {
        self.st.trace.arm_flight();
    }

    /// Export everything captured as a Chrome/Perfetto `trace_event`
    /// JSON document (byte-identical across same-seed reruns).
    pub fn export_trace(&self) -> String {
        obs::export_chrome_trace(self.st.trace.records())
    }

    /// Human-readable dump of the flight recorder's ring.
    pub fn flight_dump(&self) -> String {
        self.st.trace.flight_dump()
    }

    /// Run a complete workload to completion; returns the metric bundle.
    pub fn run_workload(&mut self, spec: &WorkloadSpec) -> RunReport {
        let template = self.st.register_graph(&spec.graph);
        let mut arr_rng = self.rng.fold(1);
        let arrivals = spec.arrivals(&mut arr_rng);
        for (i, t) in arrivals.iter().enumerate() {
            self.events.push(*t, Ev::AppArrival { seq: i as u32 });
        }
        let tool_sim = ToolSim::new(spec.tool_noise);
        let total_apps = spec.num_apps as u64;

        let mut iters: u64 = 0;
        let mut truncated = false;
        loop {
            // 1. Apply all events due at the current time.
            while let Some(ev) = self.events.pop_due(self.clock.now_us()) {
                match ev.payload {
                    Ev::AppArrival { seq } => {
                        let mut rng = self.rng.fold(1000 + seq as u64);
                        let scales = spec.dataset.sample(&mut rng);
                        self.inject_app(template, scales, &tool_sim);
                    }
                    other => {
                        // Standalone runs never migrate requests away, so
                        // an orphaned tool finish here is impossible.
                        let orphan =
                            self.apply_runtime_event(other, &tool_sim);
                        debug_assert!(orphan.is_none());
                    }
                }
            }

            if self.st.metrics.apps_completed >= total_apps {
                break;
            }

            // 2. One scheduling step (§3.2 four phases).
            coordination::step(&mut self.st, self.clock.now_us());
            self.drain_outbox();

            // 3. Execute an iteration, or idle-skip to the next event.
            if !self.st.prefilling.is_empty() || !self.st.running.is_empty()
            {
                let dt = self.execute_iteration(&tool_sim);
                self.clock.advance_by(dt);
                self.st.trace.advance(self.clock.now_us());
            } else {
                match self.events.peek_time() {
                    Some(t) => {
                        self.clock.advance_to(t.max(self.clock.now_us()));
                        self.st.trace.advance(self.clock.now_us());
                    }
                    None => {
                        // No events, no batch: either done or deadlocked
                        // (e.g. waiting-with-KV requests hold all blocks
                        // while offloaded ones can't reserve an upload).
                        if self.rescue_deadlock() {
                            continue;
                        }
                        truncated = !self.st.waiting.is_empty();
                        break;
                    }
                }
            }

            self.st.sample_metrics(self.clock.now_us());
            iters += 1;
            if iters % 500_000 == 0
                && std::env::var_os("TOKENCAKE_TRACE").is_some()
            {
                eprintln!(
                    "[trace] iter={} t={:.0}s apps={}/{} run={} wait={} \
                     preempt={} free={}",
                    iters,
                    self.clock.now_s(),
                    self.st.metrics.apps_completed,
                    self.st.apps.len(),
                    self.st.running.len(),
                    self.st.waiting.len(),
                    self.st.metrics.counters.preemptions,
                    self.st.gpu.free_blocks(),
                );
            }
            if iters >= self.max_iterations {
                truncated = true;
                break;
            }
        }

        self.st.metrics.makespan_us = self.clock.now_us();
        self.st.metrics.swap_volume_blocks =
            self.st.ledger.swap_volume_blocks();
        // Take-on-finalize: hand the bundle (latency samples + time
        // series) to the report without cloning it; the engine keeps a
        // fresh default in its place.
        RunReport {
            mode: self.st.cfg.mode.name(),
            metrics: std::mem::take(&mut self.st.metrics),
            truncated,
        }
    }

    fn drain_outbox(&mut self) {
        // In-place drain (Action is Copy): preserves issue order — the
        // event queue breaks time ties FIFO — without reallocating.
        for i in 0..self.st.outbox.len() {
            let Action::TransferIssued { xfer, completes_us } =
                self.st.outbox[i];
            self.events.push(completes_us, Ev::TransferDone { xfer });
        }
        self.st.outbox.clear();
    }

    /// Apply a non-arrival event at the current clock time. Returns the
    /// event back as an orphan when it is a `ToolFinish` for a request
    /// that left this worker (cluster migration).
    fn apply_runtime_event(
        &mut self,
        ev: Ev,
        tool_sim: &ToolSim,
    ) -> Option<OrphanedToolFinish> {
        let now = self.clock.now_us();
        match ev {
            Ev::AppArrival { .. } => {
                unreachable!("arrivals are owned by the workload driver")
            }
            Ev::ToolFinish { rid } => {
                // The request may have been preempted/restructured; only
                // FC-stalled requests receive the event. A request that
                // is *gone* migrated to another worker — hand the event
                // back for forwarding.
                match self.st.reqs.get(&rid).map(|r| r.state.is_fc_stalled())
                {
                    Some(true) => {
                        temporal::call_finish(&mut self.st, rid, now);
                        self.drain_outbox();
                    }
                    Some(false) => {}
                    None => {
                        return Some(OrphanedToolFinish { rid, at_us: now })
                    }
                }
            }
            Ev::NodeDelayDone { app, node } => {
                let (funcs, _) = self.st.complete_node(app, node, now);
                for n in funcs {
                    self.schedule_func_node(app, n, tool_sim);
                }
            }
            Ev::TransferDone { xfer } => {
                temporal::on_transfer_done(&mut self.st, xfer, now);
                self.drain_outbox();
            }
        }
        None
    }

    // ------------------------------------------------------------------
    // Cluster-driver API: a `cluster::ClusterEngine` owns the arrival
    // schedule and a shared clock, and drives each worker shard through
    // these methods instead of `run_workload`.
    // ------------------------------------------------------------------

    /// Register a graph template on this worker. Cluster deployments must
    /// register the same templates in the same order on every shard so
    /// template indices and agent-type ids agree across workers.
    pub fn register_template(&mut self, g: &AppGraph) -> usize {
        self.st.register_graph(g)
    }

    /// Give this worker a disjoint id range (see
    /// [`ServeState::set_id_base`]).
    pub fn set_id_base(&mut self, base: u64) {
        self.st.set_id_base(base);
    }

    /// Spawn an application instance at the current clock time, scheduling
    /// any standalone func-node roots.
    pub fn inject_app(
        &mut self,
        template: usize,
        scales: SampledLengths,
        tool_sim: &ToolSim,
    ) -> AppId {
        let now = self.clock.now_us();
        let (app, funcs) = self.st.spawn_app(template, scales, now);
        for node in funcs {
            self.schedule_func_node(app, node, tool_sim);
        }
        app
    }

    /// Earliest pending local event (tool finishes, func-node delays,
    /// transfer completions), if any.
    pub fn next_local_event_us(&self) -> Option<u64> {
        self.events.peek_time()
    }

    /// Advance this worker's clock to the (global) time `t_us` and apply
    /// every local event that came due. Returns tool finishes addressed to
    /// requests that migrated away — the caller forwards them.
    ///
    /// Shard-local by construction — this method touches only this
    /// worker's own state, so the cluster driver may run it for many
    /// shards concurrently (the parallel phase of the concurrency
    /// contract). The returned orphans are this phase's outbox: the
    /// driver merges them across shards at the barrier in
    /// `(time, shard, seq)` order before forwarding.
    pub fn advance_shard_to(
        &mut self,
        t_us: u64,
        tool_sim: &ToolSim,
    ) -> Vec<OrphanedToolFinish> {
        if t_us > self.clock.now_us() {
            self.clock.advance_to(t_us);
            self.st.trace.advance(t_us);
        }
        let mut orphans = Vec::new();
        while let Some(ev) = self.events.pop_due(self.clock.now_us()) {
            if let Some(o) = self.apply_runtime_event(ev.payload, tool_sim)
            {
                orphans.push(o);
            }
        }
        orphans
    }

    /// Deliver a forwarded tool finish to a request now living on this
    /// worker (or buffered here after a migration landed).
    pub fn deliver_tool_finish(&mut self, rid: RequestId) {
        let now = self.clock.now_us();
        if self
            .st
            .reqs
            .get(&rid)
            .map(|r| r.state.is_fc_stalled())
            .unwrap_or(false)
        {
            temporal::call_finish(&mut self.st, rid, now);
            self.drain_outbox();
        }
    }

    /// Does this worker currently have admitted work to iterate on?
    pub fn has_batch(&self) -> bool {
        !self.st.prefilling.is_empty() || !self.st.running.is_empty()
    }

    /// One cluster-driven engine step at the current clock time: run the
    /// §3.2 scheduling step, then — if a batch formed — execute one
    /// iteration and return its duration (µs). The caller advances the
    /// shared clock and re-enters when the iteration completes.
    ///
    /// Shard-local like [`Self::advance_shard_to`]: safe to run
    /// concurrently across shards; the returned duration is pushed
    /// onto the shared event queue by the driver at the barrier, in
    /// shard index order.
    pub fn step_once(&mut self, tool_sim: &ToolSim) -> Option<u64> {
        coordination::step(&mut self.st, self.clock.now_us());
        self.drain_outbox();
        if !self.has_batch() {
            return None;
        }
        let dt = self.execute_iteration(tool_sim);
        self.st.sample_metrics(self.clock.now_us());
        Some(dt)
    }

    /// Expose deadlock rescue to the cluster driver (a fully idle cluster
    /// with waiting work left applies the same demotion rules per shard).
    pub fn try_rescue(&mut self) -> bool {
        self.rescue_deadlock()
    }

    /// End-of-run settlement: complete every in-flight block transfer
    /// immediately. The cluster driver calls this when the workload
    /// finishes while copies are still on the wire (e.g. a drain
    /// evacuation's D2H leg) — mid-wire state at shutdown is
    /// bookkeeping to close, not a leak, and pools must end consistent.
    /// At normal completion only `TransferDone` events can remain (a
    /// pending tool finish or node delay would imply an unfinished
    /// app); anything else is dropped.
    pub fn settle_transfers(&mut self) {
        self.drain_outbox();
        while let Some(ev) = self.events.pop() {
            if let Ev::TransferDone { xfer } = ev.payload {
                let now = self.clock.now_us();
                temporal::on_transfer_done(&mut self.st, xfer, now);
                self.drain_outbox();
            }
        }
    }

    /// Crash-time settlement (see `cluster::faults`): complete every
    /// in-flight block transfer at the current instant — the wire no
    /// longer exists, so mid-flight ledger entries close now and the
    /// per-request quiesce that follows reclaims whatever they landed —
    /// while *keeping* every pending tool finish and func-node delay at
    /// its original time. Unlike [`Self::settle_transfers`], dropping
    /// those events here would strangle re-queued apps: their tools are
    /// still running and must orphan-forward to the new home shard.
    pub fn crash_settle_transfers(&mut self) {
        self.drain_outbox();
        let mut keep: Vec<Event<Ev>> = Vec::new();
        while let Some(ev) = self.events.pop() {
            match ev.payload {
                Ev::TransferDone { xfer } => {
                    let now = self.clock.now_us();
                    temporal::on_transfer_done(&mut self.st, xfer, now);
                    self.drain_outbox();
                }
                _ => keep.push(ev),
            }
        }
        // Re-queue survivors in their original (time, seq) order so
        // FIFO tie-breaks replay identically.
        keep.sort_by_key(|e| (e.at_us, e.seq));
        for e in keep {
            self.events.push(e.at_us, e.payload);
        }
    }

    /// Finalize this worker's metric bundle at the end of a cluster run,
    /// *taking* it out of the engine (no clone of latency samples / time
    /// series; the engine keeps a fresh default). Swap volume comes from
    /// the migration ledger, so cross-worker migration traffic is
    /// included alongside D2H/H2D offload traffic.
    pub fn finalize_metrics(&mut self, end_us: u64) -> MetricsBundle {
        // Close the utilization time series at the cluster end time:
        // cluster shards sample only on executed iterations, so without
        // this a shard that went idle early would report its busy-window
        // utilization as if it held for the whole run.
        self.st.sample_metrics_quiet(end_us);
        self.st.metrics.makespan_us = end_us;
        self.st.metrics.swap_volume_blocks =
            self.st.ledger.swap_volume_blocks();
        std::mem::take(&mut self.st.metrics)
    }

    /// Standalone (non-LLM) func node: a pure delay.
    fn schedule_func_node(
        &mut self,
        app: AppId,
        node: NodeId,
        tool_sim: &ToolSim,
    ) {
        let template = self.st.apps.template_of(&app);
        let call = match &self.st.graphs[template].node(node).kind {
            NodeKind::Func(c) => c.clone(),
            NodeKind::Agent(_) => unreachable!("agent scheduled as func"),
        };
        let mut rng = self.rng.fold(0x5EED ^ (app.0 << 8) ^ node.0 as u64);
        let exec = tool_sim.sample(&call, &mut rng);
        self.events.push(
            self.clock.now_us() + exec.duration_us,
            Ev::NodeDelayDone { app, node },
        );
    }

    /// One engine iteration: chunked prefill + one decode token per
    /// running sequence. Returns the iteration duration (µs).
    fn execute_iteration(&mut self, tool_sim: &ToolSim) -> u64 {
        let now = self.clock.now_us();
        let prefill_us_per_token = self.st.cfg.profile.prefill_us_per_token;
        let decode_base_us = self.st.cfg.profile.decode_base_us;

        // ---- Chunked prefill (the list itself is not mutated here). ----
        let mut prefill_budget = self.st.cfg.max_prefill_tokens;
        let mut prefill_tokens: u32 = 0;
        let mut promoted = std::mem::take(&mut self.scratch_promoted);
        promoted.clear();
        for i in 0..self.st.prefilling.raw_len() {
            if prefill_budget == 0 {
                break;
            }
            let Some(rid) = self.st.prefilling.raw_get(i) else {
                continue;
            };
            let r = self.st.reqs.get_mut(&rid).unwrap();
            if r.prefix_xfer.is_some() {
                // A CPU/remote prefix hit's H2D debt is still in flight:
                // the saved prefill isn't real until the blocks land.
                continue;
            }
            let chunk = r.remaining_prefill.min(prefill_budget);
            r.remaining_prefill -= chunk;
            prefill_budget -= chunk;
            prefill_tokens += chunk;
            if r.remaining_prefill == 0 {
                // Prefilling → Running: neither state is index-tracked.
                r.state = ReqState::Running;
                promoted.push(rid);
            }
        }
        // Promote finished prefills into the decode batch (queue order).
        for &rid in &promoted {
            self.st.prefilling.remove(rid);
            self.st.running.push(rid);
            self.st.note_direct_transition(rid, obs::state::RUNNING);
        }
        promoted.clear();
        self.scratch_promoted = promoted;

        // ---- Decode one token per running sequence. ----
        // Snapshot into the reusable scratch: the loop body preempts /
        // stalls / finishes entries of `running` while iterating.
        let mut batch = std::mem::take(&mut self.scratch_batch);
        batch.clear();
        batch.extend(self.st.running.iter());
        let mut decoded: u32 = 0;
        for &rid in &batch {
            // May have been preempted by an earlier grower this iteration.
            if self.st.reqs.get(&rid).map(|r| r.state)
                != Some(ReqState::Running)
            {
                continue;
            }
            if self.st.reqs[&rid].prefix_xfer.is_some() {
                continue; // prefix upload debt gates the first decode
            }
            if !self.ensure_growth_block(rid) {
                continue; // self-preempted
            }
            decoded += 1;
            let (phase_done, has_call, is_last) = {
                let r = self.st.reqs.get_mut(&rid).unwrap();
                r.context_tokens += 1;
                r.tokens_generated += 1;
                r.gen_in_phase += 1;
                let p = &r.phases[r.cur_phase];
                (
                    r.gen_in_phase >= p.gen_tokens,
                    p.call.is_some(),
                    r.cur_phase + 1 >= r.phases.len(),
                )
            };
            if !phase_done {
                continue;
            }
            if has_call {
                self.start_function_call(rid, tool_sim);
            } else if is_last {
                self.finish_request(rid, tool_sim);
            } else {
                let r = self.st.reqs.get_mut(&rid).unwrap();
                r.cur_phase += 1;
                r.gen_in_phase = 0;
            }
        }
        batch.clear();
        self.scratch_batch = batch;

        // ---- Iteration timing. ----
        let prefill_us =
            (prefill_us_per_token * prefill_tokens as f64) as u64;
        let decode_us =
            self.st.cfg.profile.decode_iter_us(decoded as usize);
        // A zero-progress iteration (pure preemption churn) still burns a
        // full iteration's time on real hardware.
        let floor = if decoded == 0 && prefill_tokens == 0 {
            decode_base_us as u64
        } else {
            0
        };
        let dt = (prefill_us + decode_us).max(floor).max(1_000);
        self.st
            .throughput
            .record_iteration(decoded, dt.max(1));
        // Execution time charged below drifts the agent-type score's
        // H_a input, so an executed iteration is a spatial input change
        // — the windowed replan stays live whenever the engine runs and
        // skips only genuinely idle windows.
        self.st.epochs.spatial += 1;
        self.st.metrics.counters.decode_iterations += 1;
        self.st.metrics.counters.tokens_generated += decoded as u64;
        // Charge execution time (H_a input) — in place, no list clone.
        for i in 0..self.st.running.raw_len() {
            if let Some(rid) = self.st.running.raw_get(i) {
                if let Some(r) = self.st.reqs.get_mut(&rid) {
                    r.exec_time_us += dt;
                }
            }
        }
        for i in 0..self.st.prefilling.raw_len() {
            if let Some(rid) = self.st.prefilling.raw_get(i) {
                if let Some(r) = self.st.reqs.get_mut(&rid) {
                    r.exec_time_us += dt;
                }
            }
        }
        let _ = now;
        dt
    }

    /// Ensure the request has a block for its next token, preempting if
    /// necessary. Returns false if the request itself got preempted.
    fn ensure_growth_block(&mut self, rid: RequestId) -> bool {
        let block_tokens = self.st.cfg.profile.block_tokens;
        let (needs, route) = {
            let r = &self.st.reqs[&rid];
            let capacity = r.blocks.len() * block_tokens;
            (
                r.context_tokens + 1 > capacity,
                spatial::route_for(&self.st, rid),
            )
        };
        if !needs {
            return true;
        }
        loop {
            match self.st.gpu.alloc(1, route) {
                AllocOutcome::Granted {
                    blocks,
                    reserved_charged,
                } => {
                    let r = self.st.reqs.get_mut(&rid).unwrap();
                    r.blocks.absorb(blocks);
                    r.reserved_charged += reserved_charged;
                    return true;
                }
                AllocOutcome::Deferred => {
                    // The prefix cache yields before any live request is
                    // preempted: drop the LRU cached prefix (immediate
                    // free) and retry the growth allocation.
                    if spatial::drop_prefix_gpu_lru(&mut self.st) {
                        continue;
                    }
                    let Some(victim) = self.pick_preemption_victim(rid)
                    else {
                        // Nothing to preempt but self.
                        self.preempt(rid, rid);
                        return false;
                    };
                    self.preempt(victim, rid);
                    if victim == rid {
                        return false;
                    }
                }
            }
        }
    }

    /// vLLM preempts the most recently arrived running sequence; the
    /// agent-aware modes preempt the lowest-priority one. Only *running /
    /// prefilling* requests are candidates — stalled caches are invisible
    /// to the engine-level preemption exactly as in vLLM (that blindness
    /// is the temporal-underutilization problem).
    fn pick_preemption_victim(&self, grower: RequestId) -> Option<RequestId> {
        let cands = self
            .st
            .running
            .iter()
            .chain(self.st.prefilling.iter())
            .filter(|&rid| !self.st.reqs[&rid].blocks.is_empty());
        if self.st.cfg.mode.agent_aware() {
            // Strict-priority preemption: only victims with strictly lower
            // priority than the grower are eligible (otherwise the grower
            // self-preempts). Combined with the preemption ladder this
            // guarantees convergence — the top-priority request is never
            // evicted and runs to completion. Non-critical victims first.
            let g_prio = self.st.reqs[&grower].priority;
            let cands: Vec<RequestId> = cands
                .filter(|rid| {
                    *rid != grower && self.st.reqs[rid].priority < g_prio
                })
                .collect();
            // With QoS on, SLO distance leads: the victim whose app has
            // the *most* SLO headroom is the safest to evict (milli
            // fixed-point; neutral zero when disabled).
            let now_us = self.clock.now_us();
            let headroom = |rid: &RequestId| -> i64 {
                if !self.st.qos.enabled {
                    return 0;
                }
                let app_id = self.st.reqs[rid].app_id;
                let age = now_us
                    .saturating_sub(self.st.apps[&app_id].arrival_us);
                self.st
                    .qos
                    .headroom_milli(self.st.apps.template_of(&app_id), age)
            };
            let pick = |pool: &[RequestId]| {
                pool.iter()
                    .copied()
                    .min_by(|a, b| {
                        let ra = &self.st.reqs[a];
                        let rb = &self.st.reqs[b];
                        headroom(b)
                            .cmp(&headroom(a))
                            .then(ra.priority.total_cmp(&rb.priority))
                            .then(ra.context_tokens.cmp(&rb.context_tokens))
                    })
            };
            let non_critical: Vec<RequestId> = cands
                .iter()
                .copied()
                .filter(|rid| !self.st.reqs[rid].critical_path)
                .collect();
            pick(&non_critical).or_else(|| pick(&cands))
        } else {
            // FCFS: evict the most recent arrival (vLLM recompute policy).
            // LIFO victims give the oldest request a progress guarantee.
            cands.max_by_key(|&rid| self.st.reqs[&rid].created_us)
        }
    }

    /// Memory deadlock resolution (mirrors vLLM's demote-to-recompute):
    /// when nothing can run and no event is pending, (1) demote the
    /// lowest-priority waiting request that still holds KV blocks to a
    /// full recompute, or (2) release a partial upload reservation so the
    /// blocks can serve admission. Returns true if it made progress.
    fn rescue_deadlock(&mut self) -> bool {
        // (0) Cached prefixes are the cheapest thing to sacrifice: a
        // pinned prefix extent must never hold live work hostage.
        if spatial::drop_prefix_gpu_lru(&mut self.st) {
            return true;
        }
        // (1) Waiting-with-KV demotion.
        let victim = self
            .st
            .waiting
            .iter()
            .copied()
            .filter(|rid| !self.st.reqs[rid].blocks.is_empty())
            .min_by(|a, b| {
                self.st.reqs[a]
                    .priority
                    .total_cmp(&self.st.reqs[b].priority)
            });
        if let Some(rid) = victim {
            self.st.release_gpu(rid);
            let r = self.st.reqs.get_mut(&rid).unwrap();
            r.remaining_prefill = r.context_tokens;
            self.st.metrics.counters.recomputes += 1;
            self.st.metrics.counters.recompute_tokens +=
                self.st.reqs[&rid].context_tokens as u64;
            return true;
        }
        // (2) Strand-breaking: release a partial upload reservation.
        // The offloaded index iterates in id order, and the id also
        // breaks priority ties, so the victim never depends on storage
        // order.
        let stranded = self
            .st
            .offloaded_ids
            .iter()
            .copied()
            .filter(|rid| {
                let r = &self.st.reqs[rid];
                r.state == ReqState::Offloaded
                    && !r.upload_reserved.is_empty()
            })
            .min_by(|a, b| {
                self.st.reqs[a]
                    .priority
                    .total_cmp(&self.st.reqs[b].priority)
                    .then(a.cmp(b))
            });
        if let Some(rid) = stranded {
            let r = self.st.reqs.get_mut(&rid).unwrap();
            let blocks = r.upload_reserved.take();
            let charged = std::mem::take(&mut r.upload_reserved_charged);
            let t = r.type_id;
            self.st.gpu.free(blocks, charged, Some(t));
            // The broken reservation must be rebuilt from scratch — wake
            // the epoch-gated planner.
            self.st.epochs.temporal += 1;
            return true;
        }
        false
    }

    /// Evict a request: free its blocks, schedule a full recompute.
    fn preempt(&mut self, victim: RequestId, grower: RequestId) {
        let now = self.clock.now_us();
        let (v_critical, v_type) = {
            let r = &self.st.reqs[&victim];
            (r.critical_path, r.type_id)
        };
        let g_critical = self.st.reqs[&grower].critical_path;
        self.st.metrics.counters.preemptions += 1;
        if v_critical && !g_critical && victim != grower {
            self.st.metrics.counters.critical_inversions += 1;
        }
        self.st.types.note_preempt(v_type);
        self.st.epochs.spatial += 1; // preempt counters feed S_a
        if victim == grower {
            // Hit the growth wall with no eligible victim: next admission
            // must be all-or-nothing.
            self.st.reqs.get_mut(&victim).unwrap().admit_full = true;
        }

        // An in-flight prefix upload into the victim's blocks is void:
        // retire the ledger entry and unpin the source.
        self.st.cancel_prefix_upload(victim);
        self.st.release_gpu(victim);
        let r = self.st.reqs.get_mut(&victim).unwrap();
        // Running/Prefilling → Waiting: neither end is index-tracked.
        r.state = ReqState::Waiting;
        r.remaining_prefill = r.context_tokens; // full recompute
        r.queue_enter_us = now;
        r.preempt_count += 1;
        self.st.metrics.counters.recomputes += 1;
        self.st.metrics.counters.recompute_tokens +=
            r.context_tokens as u64;
        self.st.trace.preempt(victim.0, grower.0);
        self.st.note_direct_transition(victim, obs::state::WAITING);
        self.st.running.remove(victim);
        self.st.prefilling.remove(victim);
        self.st.waiting.push_back(victim);
    }

    /// Phase boundary with a call: fire `call_start` and schedule the
    /// tool's completion.
    fn start_function_call(&mut self, rid: RequestId, tool_sim: &ToolSim) {
        let now = self.clock.now_us();
        let (call, result_tokens) = {
            let r = &self.st.reqs[&rid];
            let call = r.phases[r.cur_phase].call.clone().unwrap();
            (call, r.phases[r.cur_phase].result_tokens)
        };
        self.st.running.remove(rid);
        temporal::call_start(
            &mut self.st,
            rid,
            &call.kind.name().to_string(),
            call.predict_time_us,
            result_tokens,
            now,
        );
        // Sample the *actual* tool duration (the scheduler only sees the
        // prediction).
        let mut rng = self.rng.fold(0x70_01 ^ rid.0.wrapping_mul(0x9E37));
        let exec = tool_sim.sample(&call, &mut rng);
        self.events
            .push(now + exec.duration_us, Ev::ToolFinish { rid });
    }

    /// Final phase complete: release memory, advance the DAG.
    fn finish_request(&mut self, rid: RequestId, tool_sim: &ToolSim) {
        let now = self.clock.now_us();
        spatial::record_prefix(&mut self.st, rid, now);
        self.st.release_gpu(rid);
        self.st.release_cpu(rid);
        let (app, node, created) = {
            let r = self.st.reqs.get_mut(&rid).unwrap();
            r.state = ReqState::Finished;
            r.finished_us = Some(now);
            (r.app_id, r.node, r.created_us)
        };
        self.st.reindex_request(rid, ReqState::Finished);
        self.st
            .metrics
            .request_latency
            .record_us(now - created);
        self.st.running.remove(rid);
        let (funcs, _done) = self.st.complete_node(app, node, now);
        for n in funcs {
            self.schedule_func_node(app, n, tool_sim);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Mode;
    use crate::graph::templates;

    fn run(mode: Mode, qps: f64, apps: usize, frac: f64) -> RunReport {
        let cfg = ServeConfig::default()
            .with_mode(mode)
            .with_seed(7)
            .with_gpu_mem_frac(frac);
        let g = templates::code_writer();
        let spec = WorkloadSpec::poisson(&g, qps, apps);
        SimEngine::new(cfg).run_workload(&spec)
    }

    #[test]
    fn completes_small_workload_all_modes() {
        for mode in [
            Mode::TokenCake,
            Mode::Vllm,
            Mode::VllmPrefix,
            Mode::Mooncake,
            Mode::Parrot,
            Mode::AgentOnly,
            Mode::OffloadOnly,
            Mode::Infercept,
        ] {
            let rep = run(mode, 0.5, 3, 1.0);
            assert!(!rep.truncated, "{mode:?} truncated");
            assert_eq!(rep.metrics.apps_completed, 3, "{mode:?}");
            assert!(rep.metrics.latency.mean_s() > 0.0);
            assert!(rep.metrics.counters.tokens_generated > 0);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(Mode::TokenCake, 0.5, 4, 1.0);
        let b = run(Mode::TokenCake, 0.5, 4, 1.0);
        assert_eq!(
            a.metrics.latency.mean_us(),
            b.metrics.latency.mean_us()
        );
        assert_eq!(a.metrics.offload_count, b.metrics.offload_count);
        assert_eq!(
            a.metrics.counters.preemptions,
            b.metrics.counters.preemptions
        );
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn memory_pressure_causes_preemptions_in_vllm() {
        // A small pool + several concurrent apps must trigger evictions
        // under FCFS (the Fig 3a phenomenon).
        let rep = run(Mode::Vllm, 2.0, 10, 0.02);
        assert!(
            rep.metrics.counters.preemptions > 0,
            "expected preemptions, got {:?}",
            rep.metrics.counters
        );
    }

    #[test]
    fn tokencake_offloads_under_pressure() {
        let rep = run(Mode::TokenCake, 2.0, 10, 0.02);
        assert!(
            rep.metrics.offload_count > 0,
            "temporal scheduler never offloaded: {}",
            rep.summary()
        );
        assert_eq!(rep.metrics.offload_count, rep.metrics.upload_count);
    }

    #[test]
    fn vllm_never_offloads() {
        let rep = run(Mode::Vllm, 2.0, 8, 0.02);
        assert_eq!(rep.metrics.offload_count, 0);
        assert_eq!(rep.metrics.swap_volume_blocks, 0);
    }

    #[test]
    fn block_accounting_conserves() {
        let cfg = ServeConfig::default()
            .with_mode(Mode::TokenCake)
            .with_gpu_mem_frac(0.05);
        let g = templates::deep_research();
        let spec = WorkloadSpec::poisson(&g, 1.0, 5);
        let mut e = SimEngine::new(cfg);
        let _ = e.run_workload(&spec);
        // After the run every block is either free or pinned by the
        // prefix index (TokenCake caches shared prefixes across apps);
        // nothing is leaked to dead requests or stuck pending-free.
        assert_eq!(
            e.st.gpu.free_blocks() + e.st.prefix.resident_gpu_blocks(),
            e.st.gpu.total()
        );
        assert_eq!(e.st.gpu.pending_free_blocks(), 0);
        assert_eq!(
            e.st.cpu.used_blocks(),
            e.st.prefix.resident_cpu_blocks()
        );
        // Lifecycle indices drained with the requests.
        assert!(e.st.stalled_ids.is_empty());
        assert!(e.st.offloaded_ids.is_empty());
        assert_eq!(e.st.reqs.live_len(), 0);
        // Dropping the cache returns the pool to one coalesced run.
        while crate::spatial::drop_prefix_gpu_lru(&mut e.st) {}
        assert_eq!(e.st.gpu.free_blocks(), e.st.gpu.total());
        assert_eq!(e.st.gpu.free_extents().len(), 1);
    }

    #[test]
    fn utilization_series_populated() {
        let rep = run(Mode::TokenCake, 1.0, 4, 0.05);
        assert!(rep.metrics.gpu_usage.len() > 2);
        assert!(rep.metrics.gpu_usage.max() <= 1.0 + 1e-9);
        assert!(rep.metrics.effective_usage.time_weighted_mean() >= 0.0);
    }
}
