//! Execution engines.
//!
//! Two engines drive the same coordinator ([`crate::coordination`]):
//!
//! * [`sim::SimEngine`] — the discrete-event engine the paper-scale
//!   experiments run on: calibrated iteration/transfer/tool timings with
//!   exact block-level KV accounting (DESIGN.md §3).
//! * [`real::RealEngine`] — the wall-clock engine for the end-to-end
//!   example: drives the TinyQwen PJRT artifacts through the same
//!   scheduling step, with real tokens and real host-memory offload.

#[cfg(feature = "pjrt")]
pub mod real;
pub mod sim;
