//! The wall-clock serving engine over the real TinyQwen PJRT executables.
//!
//! Proves the three layers compose end to end: the same coordinator
//! (pressure snapshot → reservations → temporal phase → admission) that
//! drives the simulator here schedules *real* prefill/decode executions of
//! the AOT artifacts, real host-memory offload (the slot's KV image is
//! copied out of the batched cache and back), and tool calls that elapse
//! in real time.
//!
//! Mapping: one KV block = one decode slot (see
//! `ModelProfile::tinyqwen_cpu`), so `BlockId(s)` *is* slot `s` of the
//! batched cache and the coordinator's block accounting is exact.

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{Mode, ServeConfig};
use crate::coordination::{
    self, Action, AppId, ReqState, RequestId, ServeState,
};
use crate::graph::{AppGraph, NodeId, NodeKind};
use crate::metrics::MetricsBundle;
use crate::runtime::TinyQwen;
use crate::sim::Rng;
use crate::temporal;
use crate::workload::{Dataset, ToolSim};

/// An offloaded slot image in host memory (the "CPU block pool" payload).
struct HostImage {
    k: Vec<f32>,
    v: Vec<f32>,
    len: usize,
}

/// Per-request generation bookkeeping the coordinator doesn't track.
#[derive(Default)]
struct GenState {
    /// Tokens queued for teacher-forced injection (pending last generated
    /// token + tool results after an FC resume).
    forced: VecDeque<i32>,
    /// Next decode input token.
    next_input: i32,
    /// All generated token ids (the actual output).
    output: Vec<i32>,
    /// Tokens actually present in the slot's KV cache.
    cache_len: usize,
}

/// Report from a real-engine run.
pub struct RealRunReport {
    pub metrics: MetricsBundle,
    pub wall_s: f64,
    pub decode_steps: u64,
    pub tokens_generated: u64,
    /// Per-app generated token counts (for verification).
    pub outputs: Vec<(RequestId, usize)>,
}

impl RealRunReport {
    pub fn summary(&self) -> String {
        format!(
            "wall={:.1}s apps={} avg_lat={:.2}s p90={:.2}s steps={} \
             tokens={} tok/s={:.1} offloads={} uploads={}",
            self.wall_s,
            self.metrics.apps_completed,
            self.metrics.latency.mean_s(),
            self.metrics.latency.percentile_s(90.0),
            self.decode_steps,
            self.tokens_generated,
            self.tokens_generated as f64 / self.wall_s.max(1e-9),
            self.metrics.offload_count,
            self.metrics.upload_count,
        )
    }
}

/// Wall-clock engine: TinyQwen + coordinator.
pub struct RealEngine {
    pub st: ServeState,
    model: TinyQwen,
    /// slot → owning request (slot s == BlockId(s)).
    slots: Vec<Option<RequestId>>,
    /// Host-side image of the batched KV cache fed to each decode step.
    k: Vec<f32>,
    v: Vec<f32>,
    host_store: HashMap<RequestId, HostImage>,
    gen: HashMap<RequestId, GenState>,
    tool_deadlines: Vec<(u64, RequestId)>,
    func_deadlines: Vec<(u64, AppId, NodeId)>,
    start: Instant,
    rng: Rng,
    tool_sim: ToolSim,
    decode_steps: u64,
    /// Scale factor applied to sampled tool durations (to keep examples
    /// fast while preserving relative magnitudes).
    pub tool_time_scale: f64,
}

impl RealEngine {
    pub fn new(mut cfg: ServeConfig, artifacts: &std::path::Path) -> Result<Self> {
        cfg.profile = crate::config::ModelProfile::tinyqwen_cpu();
        let model = TinyQwen::load(artifacts)
            .context("loading TinyQwen artifacts")?;
        cfg.max_batch = model.decode_batch;
        let seed = cfg.seed;
        let n_slots = model.decode_batch;
        let cache_len = model.cache_len();
        Ok(Self {
            st: ServeState::new(cfg),
            model,
            slots: vec![None; n_slots],
            k: vec![0f32; cache_len],
            v: vec![0f32; cache_len],
            host_store: HashMap::new(),
            gen: HashMap::new(),
            tool_deadlines: Vec::new(),
            func_deadlines: Vec::new(),
            start: Instant::now(),
            rng: Rng::new(seed),
            tool_sim: ToolSim::new(0.0),
            decode_steps: 0,
            tool_time_scale: 1.0,
        })
    }

    fn now_us(&self) -> u64 {
        self.start.elapsed().as_micros() as u64
    }

    /// Serve `num_apps` instances of `graph` arriving `gap_us` apart.
    /// Token counts are clamped so every agent fits one 256-token slot.
    pub fn serve(
        &mut self,
        graph: &AppGraph,
        num_apps: usize,
        gap_us: u64,
    ) -> Result<RealRunReport> {
        let template = self.st.register_graph(graph);
        let mut next_arrival: u64 = 0;
        let mut submitted = 0usize;

        loop {
            let now = self.now_us();

            // ---- Arrivals. ----
            while submitted < num_apps && now >= next_arrival {
                let mut rng = self.rng.fold(7_000 + submitted as u64);
                let mut scales = Dataset::D1.sample(&mut rng);
                // Keep contexts inside one slot.
                scales.prompt_scale = scales.prompt_scale.min(1.0);
                scales.gen_scale = scales.gen_scale.min(1.0);
                let (app, funcs) =
                    self.st.spawn_app(template, scales, now);
                self.clamp_new_requests();
                for node in funcs {
                    self.schedule_func_node(app, node);
                }
                submitted += 1;
                next_arrival += gap_us;
            }

            // ---- Tool / func-node completions. ----
            self.fire_deadlines(now);
            // Children spawned by completed nodes need clamping too.
            self.clamp_new_requests();

            if self.st.metrics.apps_completed as usize >= num_apps {
                break;
            }

            // ---- Scheduling step (same §3.2 four phases as the sim). ----
            coordination::step(&mut self.st, now);
            self.assign_slots_to_admitted();
            self.realize_transfers(now)?;

            // ---- Real execution. ----
            let did_prefill = self.run_prefills()?;
            let did_decode = self.run_decode_step()?;

            if !did_prefill && !did_decode {
                // Idle: wait for the next deadline or arrival.
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            self.st.sample_metrics(self.now_us());
        }

        self.st.metrics.makespan_us = self.now_us();
        self.st.metrics.swap_volume_blocks =
            self.st.ledger.swap_volume_blocks();
        let outputs = self
            .gen
            .iter()
            .map(|(&rid, g)| (rid, g.output.len()))
            .collect();
        // Take-on-finalize (same contract as the sim engine): no clone of
        // the latency samples / time series.
        let metrics = std::mem::take(&mut self.st.metrics);
        Ok(RealRunReport {
            wall_s: self.start.elapsed().as_secs_f64(),
            decode_steps: self.decode_steps,
            tokens_generated: metrics.counters.tokens_generated,
            metrics,
            outputs,
        })
    }

    /// Shrink any newly spawned request so prompt + generation + results
    /// fit one slot (≤ max_len tokens) and the prompt fits prefill_len.
    fn clamp_new_requests(&mut self) {
        let max_prompt = self.model.prefill_len as u32;
        let budget = self.model.max_len as u32 - 2;
        for r in self.st.reqs.values_mut() {
            if r.state != ReqState::Waiting || !r.blocks.is_empty() {
                continue;
            }
            if r.prompt_tokens > max_prompt {
                r.prompt_tokens = max_prompt;
                r.context_tokens = max_prompt;
                r.remaining_prefill = max_prompt;
            }
            // Scale generation phases into the remaining budget.
            let mut used = r.prompt_tokens;
            for p in r.phases.iter_mut() {
                p.result_tokens = p.result_tokens.min(8);
                let remaining = budget.saturating_sub(used + p.result_tokens);
                p.gen_tokens = p.gen_tokens.clamp(1, remaining.max(1) / 2);
                used += p.gen_tokens + p.result_tokens;
            }
        }
    }

    fn fire_deadlines(&mut self, now: u64) {
        let due_tools: Vec<RequestId> = {
            let (due, rest): (Vec<_>, Vec<_>) = self
                .tool_deadlines
                .drain(..)
                .partition(|&(t, _)| t <= now);
            self.tool_deadlines = rest;
            due.into_iter().map(|(_, rid)| rid).collect()
        };
        for rid in due_tools {
            if self
                .st
                .reqs
                .get(&rid)
                .map(|r| r.state.is_fc_stalled())
                .unwrap_or(false)
            {
                temporal::call_finish(&mut self.st, rid, now);
            }
        }
        let due_funcs: Vec<(AppId, NodeId)> = {
            let (due, rest): (Vec<_>, Vec<_>) = self
                .func_deadlines
                .drain(..)
                .partition(|&(t, _, _)| t <= now);
            self.func_deadlines = rest;
            due.into_iter().map(|(_, a, n)| (a, n)).collect()
        };
        for (app, node) in due_funcs {
            let (funcs, _) = self.st.complete_node(app, node, now);
            for n in funcs {
                self.schedule_func_node(app, n);
            }
        }
    }

    fn schedule_func_node(&mut self, app: AppId, node: NodeId) {
        let template = self.st.apps.template_of(&app);
        let call = match &self.st.graphs[template].node(node).kind {
            NodeKind::Func(c) => c.clone(),
            NodeKind::Agent(_) => unreachable!(),
        };
        let mut rng = self.rng.fold(0xF00D ^ (app.0 << 8) ^ node.0 as u64);
        let exec = self.tool_sim.sample(&call, &mut rng);
        let dur = (exec.duration_us as f64 * self.tool_time_scale) as u64;
        self.func_deadlines.push((self.now_us() + dur, app, node));
    }

    /// Newly admitted requests hold BlockIds; mirror that in the slot map.
    fn assign_slots_to_admitted(&mut self) {
        let ids: Vec<RequestId> = self
            .st
            .prefilling
            .iter()
            .chain(self.st.running.iter())
            .collect();
        for rid in ids {
            let r = &self.st.reqs[&rid];
            debug_assert_eq!(r.blocks.len(), 1, "one block == one slot");
            let slot = r.blocks.first().unwrap().0 as usize;
            if self.slots[slot] != Some(rid) {
                self.slots[slot] = Some(rid);
            }
        }
    }

    /// Perform the actual memcpys for transfers the temporal scheduler
    /// issued, then complete them (host copies are microseconds — no
    /// asynchrony needed for correctness).
    fn realize_transfers(&mut self, now: u64) -> Result<()> {
        let actions = std::mem::take(&mut self.st.outbox);
        for a in actions {
            let Action::TransferIssued { xfer, .. } = a;
            let t = self
                .st
                .ledger
                .get(xfer)
                .context("unknown transfer")?
                .clone();
            let rid = RequestId(t.req_id);
            match t.dir {
                crate::kvcache::Direction::D2H => {
                    let slot = t.gpu_blocks.first().unwrap().0 as usize;
                    let img = self.extract_slot(slot, &rid);
                    self.host_store.insert(rid, img);
                    self.slots[slot] = None;
                }
                crate::kvcache::Direction::H2D => {
                    let slot = t.gpu_blocks.first().unwrap().0 as usize;
                    let img = self
                        .host_store
                        .remove(&rid)
                        .context("upload without host image")?;
                    self.restore_slot(slot, &img);
                    self.slots[slot] = Some(rid);
                }
            }
            temporal::on_transfer_done(&mut self.st, xfer, now);
        }
        Ok(())
    }

    fn extract_slot(&mut self, slot: usize, rid: &RequestId) -> HostImage {
        let stride = self.model.slot_stride();
        let b = self.model.decode_batch;
        let len = self.st.reqs[rid].context_tokens as usize;
        let mut k = vec![0f32; self.model.n_layers * stride];
        let mut v = vec![0f32; self.model.n_layers * stride];
        for l in 0..self.model.n_layers {
            let src = (l * b + slot) * stride;
            let dst = l * stride;
            k[dst..dst + stride]
                .copy_from_slice(&self.k[src..src + stride]);
            v[dst..dst + stride]
                .copy_from_slice(&self.v[src..src + stride]);
            // Zero the vacated slot (slot reuse hygiene).
            self.k[src..src + stride].fill(0.0);
            self.v[src..src + stride].fill(0.0);
        }
        HostImage { k, v, len }
    }

    fn restore_slot(&mut self, slot: usize, img: &HostImage) {
        let stride = self.model.slot_stride();
        let b = self.model.decode_batch;
        for l in 0..self.model.n_layers {
            let dst = (l * b + slot) * stride;
            let src = l * stride;
            self.k[dst..dst + stride]
                .copy_from_slice(&img.k[src..src + stride]);
            self.v[dst..dst + stride]
                .copy_from_slice(&img.v[src..src + stride]);
        }
        let _ = img.len;
    }

    /// Run real prefills for freshly admitted requests (whole prompt in
    /// one shot — TinyQwen's prefill artifact covers ≤128 tokens).
    fn run_prefills(&mut self) -> Result<bool> {
        // Fresh = never executed here (no generation state yet). A resumed
        // request keeps its GenState across FC/offload round trips.
        let fresh: Vec<RequestId> = self
            .st
            .prefilling
            .iter()
            .filter(|rid| !self.gen.contains_key(rid))
            .collect();
        let mut any = false;
        for rid in fresh {
            any = true;
            let (slot, prompt) = {
                let r = &self.st.reqs[&rid];
                let slot = r.blocks.first().unwrap().0 as usize;
                // Deterministic synthetic prompt token ids.
                let mut rng = self.rng.fold(0xBEEF ^ rid.0);
                let prompt: Vec<i32> = (0..r.prompt_tokens)
                    .map(|_| {
                        rng.range_u64(1, self.model.vocab as u64 - 1) as i32
                    })
                    .collect();
                (slot, prompt)
            };
            let out = self.model.prefill(&prompt)?;
            // Scatter prompt KV into the slot.
            let stride = self.model.slot_stride();
            let b = self.model.decode_batch;
            let row = self.model.n_heads * self.model.head_dim;
            for l in 0..self.model.n_layers {
                for t in 0..prompt.len() {
                    let src = (l * self.model.prefill_len + t) * row;
                    let dst = (l * b + slot) * stride + t * row;
                    self.k[dst..dst + row]
                        .copy_from_slice(&out.k[src..src + row]);
                    self.v[dst..dst + row]
                        .copy_from_slice(&out.v[src..src + row]);
                }
            }
            let first = self.model.argmax(&out.logits);
            let n_prompt = prompt.len();
            let g = self.gen.entry(rid).or_default();
            g.next_input = first;
            g.cache_len = n_prompt;
            let r = self.st.reqs.get_mut(&rid).unwrap();
            r.remaining_prefill = 0;
            r.state = ReqState::Running;
        }
        // Resumed-from-FC requests: their "prefill debt" is the tool
        // result, injected via teacher forcing in the decode loop.
        let resumed: Vec<RequestId> = self
            .st
            .prefilling
            .iter()
            .filter(|rid| self.st.reqs[rid].state == ReqState::Prefilling)
            .collect();
        for rid in resumed {
            let (n_forced, seedmix) = {
                let r = &self.st.reqs[&rid];
                (r.remaining_prefill, rid.0 ^ 0xA11CE)
            };
            let mut rng = self.rng.fold(seedmix);
            let g = self.gen.entry(rid).or_default();
            // The phase's final sampled token never entered the cache
            // before the FC; feed it first, then the tool result tokens.
            g.forced.push_back(g.next_input);
            for _ in 0..n_forced {
                g.forced.push_back(
                    rng.range_u64(1, self.model.vocab as u64 - 1) as i32,
                );
            }
            let r = self.st.reqs.get_mut(&rid).unwrap();
            // The forced tokens are consumed by decode; account now.
            r.remaining_prefill = 0;
            r.state = ReqState::Running;
        }
        // Promote into the running list (O(1) removals, order kept).
        let promoted: Vec<RequestId> = self
            .st
            .prefilling
            .iter()
            .filter(|rid| self.st.reqs[rid].state == ReqState::Running)
            .collect();
        for &rid in &promoted {
            self.st.prefilling.remove(rid);
            self.st.running.push(rid);
        }
        Ok(any)
    }

    /// One real batched decode step across all running slots.
    fn run_decode_step(&mut self) -> Result<bool> {
        let batch: Vec<RequestId> = self.st.running.iter().collect();
        if batch.is_empty() {
            return Ok(false);
        }
        let b = self.model.decode_batch;
        let max_len = self.model.max_len;
        let mut tokens = vec![0i32; b];
        let mut lens = vec![0i32; b];
        let mut active: Vec<(usize, RequestId, bool)> = Vec::new();
        let mut overflow: Vec<RequestId> = Vec::new();
        for rid in batch {
            let r = &self.st.reqs[&rid];
            let slot = r.blocks.first().unwrap().0 as usize;
            let g = self.gen.entry(rid).or_default();
            if g.cache_len + 1 >= max_len {
                overflow.push(rid); // slot exhausted: finish early
                continue;
            }
            let forced = !g.forced.is_empty();
            let tok = if forced {
                *g.forced.front().unwrap()
            } else {
                g.next_input
            };
            tokens[slot] = tok;
            lens[slot] = g.cache_len as i32;
            active.push((slot, rid, forced));
        }
        let now = self.now_us();
        for rid in overflow {
            self.finish_request(rid, now);
        }
        if active.is_empty() {
            return Ok(false);
        }

        let out = self.model.decode(&tokens, &self.k, &self.v, &lens)?;
        self.k = out.k;
        self.v = out.v;
        self.decode_steps += 1;
        self.st.metrics.counters.decode_iterations += 1;

        let now = self.now_us();
        for (slot, rid, forced) in active {
            let logits = &out.logits
                [slot * self.model.vocab..(slot + 1) * self.model.vocab];
            let next = self.model.argmax(logits);
            let g = self.gen.get_mut(&rid).unwrap();
            g.cache_len += 1; // the input token entered the cache
            if forced {
                g.forced.pop_front();
                if g.forced.is_empty() {
                    // Last injected token: its logits start the next phase.
                    g.next_input = next;
                }
                continue; // injection consumes the step; no generation
            }
            g.output.push(next);
            g.next_input = next;
            self.st.metrics.counters.tokens_generated += 1;
            let (phase_done, has_call, is_last) = {
                let r = self.st.reqs.get_mut(&rid).unwrap();
                r.tokens_generated += 1;
                r.gen_in_phase += 1;
                let p = &r.phases[r.cur_phase];
                (
                    r.gen_in_phase >= p.gen_tokens,
                    p.call.is_some(),
                    r.cur_phase + 1 >= r.phases.len(),
                )
            };
            if !phase_done {
                continue;
            }
            if has_call {
                self.start_function_call(rid, now);
            } else if is_last {
                self.finish_request(rid, now);
            } else {
                let r = self.st.reqs.get_mut(&rid).unwrap();
                r.cur_phase += 1;
                r.gen_in_phase = 0;
            }
        }
        Ok(true)
    }

    fn start_function_call(&mut self, rid: RequestId, now: u64) {
        let (call, result_tokens) = {
            let r = &self.st.reqs[&rid];
            (
                r.phases[r.cur_phase].call.clone().unwrap(),
                r.phases[r.cur_phase].result_tokens,
            )
        };
        self.st.running.remove(rid);
        temporal::call_start(
            &mut self.st,
            rid,
            call.kind.name(),
            call.predict_time_us
                .map(|t| (t as f64 * self.tool_time_scale) as u64),
            result_tokens,
            now,
        );
        let mut rng = self.rng.fold(0x7001 ^ rid.0.wrapping_mul(31));
        let exec = self.tool_sim.sample(&call, &mut rng);
        let dur = (exec.duration_us as f64 * self.tool_time_scale) as u64;
        self.tool_deadlines.push((now + dur, rid));
    }

    fn finish_request(&mut self, rid: RequestId, now: u64) {
        // No prefix recording here: the prefix index pins real block
        // extents carved from the finishing request, but this engine's
        // one-block-per-slot layout cannot give up its slot block to the
        // cache (the slot must recycle). Recording a backing-less entry
        // would recreate the stale-residency bug the owned-backing index
        // exists to prevent; a host-staged prefix copy is future work.
        // Clear the slot.
        if let Some(crate::kvcache::BlockId(s)) =
            self.st.reqs[&rid].blocks.first()
        {
            self.slots[s as usize] = None;
        }
        self.st.release_gpu(rid);
        self.st.release_cpu(rid);
        self.host_store.remove(&rid);
        let (app, node, created) = {
            let r = self.st.reqs.get_mut(&rid).unwrap();
            r.state = ReqState::Finished;
            r.finished_us = Some(now);
            (r.app_id, r.node, r.created_us)
        };
        self.st.reindex_request(rid, ReqState::Finished);
        self.st.metrics.request_latency.record_us(now - created);
        self.st.running.remove(rid);
        let (funcs, _) = self.st.complete_node(app, node, now);
        for n in funcs {
            self.schedule_func_node(app, n);
        }
    }
}

/// Convenience: default config for the real engine.
pub fn real_engine_config(mode: Mode, seed: u64) -> ServeConfig {
    let mut cfg = ServeConfig::default().with_mode(mode).with_seed(seed);
    cfg.profile = crate::config::ModelProfile::tinyqwen_cpu();
    // Small pool: pressure appears with > 8 concurrent agents.
    cfg.policy.offload_usage_threshold = 0.5;
    cfg.policy.pressure_watermark = 0.05;
    cfg
}
