//! Latency sample recorder with percentile queries.

/// Collects latency samples (µs) and answers mean / percentile queries.
///
/// Percentiles sort lazily with a dirty flag — recording is O(1), queries
/// amortize the sort.
#[derive(Debug, Clone, Default)]
pub struct LatencyRecorder {
    samples_us: Vec<u64>,
    sorted: Vec<u64>,
    dirty: bool,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_us(&mut self, us: u64) {
        self.samples_us.push(us);
        self.dirty = true;
    }

    pub fn len(&self) -> usize {
        self.samples_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_us.is_empty()
    }

    pub fn mean_us(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<u64>() as f64
            / self.samples_us.len() as f64
    }

    pub fn mean_s(&self) -> f64 {
        self.mean_us() / 1e6
    }

    pub fn sum_s(&self) -> f64 {
        self.samples_us.iter().sum::<u64>() as f64 / 1e6
    }

    pub fn max_us(&self) -> u64 {
        self.samples_us.iter().copied().max().unwrap_or(0)
    }

    /// Sum of all samples in µs (integer — digest-friendly).
    pub fn total_us(&self) -> u64 {
        self.samples_us.iter().sum()
    }

    /// Fold another recorder's samples into this one (cluster rollups).
    pub fn merge(&mut self, other: &LatencyRecorder) {
        self.samples_us.extend_from_slice(&other.samples_us);
        self.dirty = true;
    }

    fn ensure_sorted(&mut self) {
        if self.dirty {
            self.sorted = self.samples_us.clone();
            self.sorted.sort_unstable();
            self.dirty = false;
        }
    }

    /// Nearest-rank percentile, p ∈ (0, 100].
    pub fn percentile_us(&mut self, p: f64) -> u64 {
        if self.samples_us.is_empty() {
            return 0;
        }
        self.ensure_sorted();
        let n = self.sorted.len();
        let rank = ((p / 100.0) * n as f64).ceil() as usize;
        self.sorted[rank.clamp(1, n) - 1]
    }

    /// Percentile in seconds (non-mutating convenience for reports — sorts
    /// a copy if needed).
    pub fn percentile_s(&self, p: f64) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        let mut sorted = self.samples_us.clone();
        sorted.sort_unstable();
        let n = sorted.len();
        let rank = ((p / 100.0) * n as f64).ceil() as usize;
        sorted[rank.clamp(1, n) - 1] as f64 / 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_recorder_is_zero() {
        let mut r = LatencyRecorder::new();
        assert_eq!(r.mean_us(), 0.0);
        assert_eq!(r.percentile_us(99.0), 0);
        assert!(r.is_empty());
    }

    #[test]
    fn mean_and_percentiles() {
        let mut r = LatencyRecorder::new();
        for v in 1..=100u64 {
            r.record_us(v);
        }
        assert_eq!(r.mean_us(), 50.5);
        assert_eq!(r.percentile_us(50.0), 50);
        assert_eq!(r.percentile_us(90.0), 90);
        assert_eq!(r.percentile_us(100.0), 100);
        assert_eq!(r.percentile_us(1.0), 1);
        assert_eq!(r.max_us(), 100);
    }

    #[test]
    fn percentile_after_interleaved_records() {
        let mut r = LatencyRecorder::new();
        r.record_us(10);
        assert_eq!(r.percentile_us(50.0), 10);
        r.record_us(20);
        r.record_us(30);
        assert_eq!(r.percentile_us(100.0), 30);
        assert!((r.percentile_s(100.0) - 30e-6).abs() < 1e-12);
    }
}
