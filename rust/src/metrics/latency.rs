//! Latency sample recorder with percentile queries.

/// Nearest-rank percentile over a pre-sorted slice, p ∈ (0, 100].
/// The single rank implementation every percentile query routes
/// through — mutable (cached-sort) and shared (sort-once batch) paths
/// must never disagree on rank math.
fn nearest_rank(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let n = sorted.len();
    let rank = ((p / 100.0) * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// Collects latency samples (µs) and answers mean / percentile queries.
///
/// Percentiles sort lazily with a dirty flag — recording is O(1), queries
/// amortize the sort. Report paths that only hold `&self` use the batch
/// queries ([`Self::percentiles_us`] / [`Self::percentiles_s`]), which
/// read the cached sort when it is clean and otherwise sort one copy for
/// *all* requested ranks — never once per percentile.
#[derive(Debug, Clone, Default)]
pub struct LatencyRecorder {
    samples_us: Vec<u64>,
    sorted: Vec<u64>,
    dirty: bool,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_us(&mut self, us: u64) {
        self.samples_us.push(us);
        self.dirty = true;
    }

    pub fn len(&self) -> usize {
        self.samples_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_us.is_empty()
    }

    pub fn mean_us(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<u64>() as f64
            / self.samples_us.len() as f64
    }

    pub fn mean_s(&self) -> f64 {
        self.mean_us() / 1e6
    }

    pub fn sum_s(&self) -> f64 {
        self.samples_us.iter().sum::<u64>() as f64 / 1e6
    }

    pub fn max_us(&self) -> u64 {
        self.samples_us.iter().copied().max().unwrap_or(0)
    }

    /// Sum of all samples in µs (integer — digest-friendly).
    pub fn total_us(&self) -> u64 {
        self.samples_us.iter().sum()
    }

    /// Fold another recorder's samples into this one (cluster rollups).
    pub fn merge(&mut self, other: &LatencyRecorder) {
        self.samples_us.extend_from_slice(&other.samples_us);
        self.dirty = true;
    }

    fn ensure_sorted(&mut self) {
        if self.dirty {
            self.sorted = self.samples_us.clone();
            self.sorted.sort_unstable();
            self.dirty = false;
        }
    }

    /// Nearest-rank percentile, p ∈ (0, 100].
    pub fn percentile_us(&mut self, p: f64) -> u64 {
        self.ensure_sorted();
        nearest_rank(&self.sorted, p)
    }

    /// Batch percentile query (µs) for `&self` report/digest paths:
    /// answers every rank from one sorted view — the cached sort when
    /// clean, otherwise a single freshly sorted copy shared by all `N`
    /// ranks.
    pub fn percentiles_us<const N: usize>(
        &self,
        ps: [f64; N],
    ) -> [u64; N] {
        if !self.dirty {
            return ps.map(|p| nearest_rank(&self.sorted, p));
        }
        let mut sorted = self.samples_us.clone();
        sorted.sort_unstable();
        ps.map(|p| nearest_rank(&sorted, p))
    }

    /// Batch percentile query in seconds (see [`Self::percentiles_us`]).
    pub fn percentiles_s<const N: usize>(&self, ps: [f64; N]) -> [f64; N] {
        self.percentiles_us(ps).map(|us| us as f64 / 1e6)
    }

    /// Single percentile in seconds (non-mutating convenience). Callers
    /// needing several percentiles should batch them through
    /// [`Self::percentiles_s`] — this sorts per call when dirty.
    pub fn percentile_s(&self, p: f64) -> f64 {
        self.percentiles_s([p])[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_recorder_is_zero() {
        let mut r = LatencyRecorder::new();
        assert_eq!(r.mean_us(), 0.0);
        assert_eq!(r.percentile_us(99.0), 0);
        assert_eq!(r.percentile_s(99.0), 0.0);
        assert_eq!(r.percentiles_us([50.0, 99.0]), [0, 0]);
        assert!(r.is_empty());
    }

    #[test]
    fn mean_and_percentiles() {
        let mut r = LatencyRecorder::new();
        for v in 1..=100u64 {
            r.record_us(v);
        }
        assert_eq!(r.mean_us(), 50.5);
        assert_eq!(r.percentile_us(50.0), 50);
        assert_eq!(r.percentile_us(90.0), 90);
        assert_eq!(r.percentile_us(100.0), 100);
        assert_eq!(r.percentile_us(1.0), 1);
        assert_eq!(r.max_us(), 100);
    }

    #[test]
    fn percentile_after_interleaved_records() {
        let mut r = LatencyRecorder::new();
        r.record_us(10);
        assert_eq!(r.percentile_us(50.0), 10);
        r.record_us(20);
        r.record_us(30);
        assert_eq!(r.percentile_us(100.0), 30);
        assert!((r.percentile_s(100.0) - 30e-6).abs() < 1e-12);
    }

    /// The batch path must agree with the cached mutable path exactly,
    /// both while dirty and after the cache is warm.
    #[test]
    fn batch_and_cached_paths_agree() {
        let mut r = LatencyRecorder::new();
        for v in [7u64, 3, 99, 14, 1, 250, 42] {
            r.record_us(v);
        }
        let ps = [50.0, 90.0, 95.0, 99.0, 99.9];
        let batch_dirty = r.percentiles_us(ps); // dirty: sorts a copy
        let cached: Vec<u64> =
            ps.iter().map(|&p| r.percentile_us(p)).collect();
        assert_eq!(batch_dirty.to_vec(), cached);
        let batch_clean = r.percentiles_us(ps); // clean: cached sort
        assert_eq!(batch_clean, batch_dirty);
        // Seconds variant is the same ranks scaled.
        let secs = r.percentiles_s(ps);
        for (s, us) in secs.iter().zip(batch_dirty) {
            assert!((s - us as f64 / 1e6).abs() < 1e-12);
        }
    }
}
