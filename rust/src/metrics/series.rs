//! Step-function time series for utilization tracking (Fig 2a, Fig 10).

/// A right-continuous step function sampled at irregular times: the value
/// holds from each sample until the next. Supports time-weighted averages —
/// the correct way to report "utilization over a run".
#[derive(Debug, Clone, Default)]
pub struct TimeSeries {
    points: Vec<(u64, f64)>,
}

impl TimeSeries {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record the value from `t_us` onward. Out-of-order samples are
    /// rejected (engine bug) — equal timestamps overwrite.
    pub fn record(&mut self, t_us: u64, value: f64) {
        if let Some(&mut (last_t, ref mut last_v)) = self.points.last_mut() {
            assert!(t_us >= last_t, "time series going backwards");
            if t_us == last_t {
                *last_v = value;
                return;
            }
            // Skip redundant points to bound memory on long runs.
            if (*last_v - value).abs() < 1e-12 {
                return;
            }
        }
        self.points.push((t_us, value));
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn last_value(&self) -> f64 {
        self.points.last().map(|&(_, v)| v).unwrap_or(0.0)
    }

    /// Time-weighted mean over the recorded span.
    pub fn time_weighted_mean(&self) -> f64 {
        if self.points.len() < 2 {
            return self.last_value();
        }
        let mut acc = 0.0;
        let mut span = 0.0;
        for w in self.points.windows(2) {
            let dt = (w[1].0 - w[0].0) as f64;
            acc += w[0].1 * dt;
            span += dt;
        }
        if span == 0.0 {
            self.last_value()
        } else {
            acc / span
        }
    }

    /// Time-weighted mean restricted to [t0, t1] — used to report
    /// steady-state utilization excluding ramp-up/drain (Fig 10).
    pub fn time_weighted_mean_between(&self, t0: u64, t1: u64) -> f64 {
        if self.points.is_empty() || t1 <= t0 {
            return 0.0;
        }
        let mut acc = 0.0;
        let mut span = 0.0;
        for w in self.points.windows(2) {
            let (a, va) = w[0];
            let (b, _) = w[1];
            let lo = a.max(t0);
            let hi = b.min(t1);
            if hi > lo {
                let dt = (hi - lo) as f64;
                acc += va * dt;
                span += dt;
            }
        }
        // Tail segment: last value holds to t1.
        if let Some(&(last_t, last_v)) = self.points.last() {
            let lo = last_t.max(t0);
            if t1 > lo {
                let dt = (t1 - lo) as f64;
                acc += last_v * dt;
                span += dt;
            }
        }
        if span == 0.0 {
            0.0
        } else {
            acc / span
        }
    }

    /// Middle-window mean: drops the first and last `trim` fraction of the
    /// recorded span (steady-state view).
    pub fn steady_state_mean(&self, trim: f64) -> f64 {
        if self.points.len() < 2 {
            return self.last_value();
        }
        let t0 = self.points[0].0;
        let t1 = self.points.last().unwrap().0;
        let span = (t1 - t0) as f64;
        let lo = t0 + (span * trim) as u64;
        let hi = t1 - (span * trim) as u64;
        self.time_weighted_mean_between(lo, hi)
    }

    /// Maximum recorded value.
    pub fn max(&self) -> f64 {
        self.points
            .iter()
            .map(|&(_, v)| v)
            .fold(f64::NEG_INFINITY, f64::max)
            .max(0.0)
    }

    /// Raw points (for CSV dumps / plotting).
    pub fn points(&self) -> &[(u64, f64)] {
        &self.points
    }

    /// Downsample to at most `n` evenly spaced points (for reports).
    pub fn downsample(&self, n: usize) -> Vec<(u64, f64)> {
        if self.points.len() <= n || n == 0 {
            return self.points.clone();
        }
        let stride = self.points.len() as f64 / n as f64;
        (0..n)
            .map(|i| self.points[(i as f64 * stride) as usize])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_weighted_mean_steps() {
        let mut s = TimeSeries::new();
        s.record(0, 0.0);
        s.record(10, 1.0); // value 0.0 held for 10
        s.record(20, 1.0); // value 1.0 held for 10 (skipped as redundant)
        s.record(30, 0.5);
        // spans: [0,10)=0.0, [10,30)=1.0 -> mean = (0*10 + 1*20)/30
        let m = s.time_weighted_mean();
        assert!((m - 20.0 / 30.0).abs() < 1e-9, "{m}");
    }

    #[test]
    fn equal_timestamp_overwrites() {
        let mut s = TimeSeries::new();
        s.record(5, 0.3);
        s.record(5, 0.7);
        assert_eq!(s.last_value(), 0.7);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn max_and_empty() {
        let mut s = TimeSeries::new();
        assert_eq!(s.time_weighted_mean(), 0.0);
        assert_eq!(s.max(), 0.0);
        s.record(0, 0.2);
        s.record(1, 0.9);
        s.record(2, 0.1);
        assert_eq!(s.max(), 0.9);
    }

    #[test]
    fn downsample_bounds() {
        let mut s = TimeSeries::new();
        for i in 0..100 {
            s.record(i, (i % 7) as f64); // avoid redundant skips
        }
        let d = s.downsample(10);
        assert_eq!(d.len(), 10);
        assert_eq!(d[0].0, 0);
    }

    #[test]
    fn windowed_mean() {
        let mut s = TimeSeries::new();
        s.record(0, 0.0);
        s.record(100, 1.0);
        s.record(200, 0.0);
        // Whole span: 0 for [0,100), 1 for [100,200), 0 after.
        assert!((s.time_weighted_mean_between(0, 200) - 0.5).abs() < 1e-9);
        // Only the middle.
        assert!(
            (s.time_weighted_mean_between(100, 200) - 1.0).abs() < 1e-9
        );
        // Tail extension: value 0 holds beyond 200.
        assert!(s.time_weighted_mean_between(200, 400) < 1e-9);
        assert_eq!(s.time_weighted_mean_between(50, 50), 0.0);
        // Steady-state trim: [90,110] straddles the step at 100 → 0.5.
        assert!((s.steady_state_mean(0.45) - 0.5).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "backwards")]
    fn rejects_backwards_time() {
        let mut s = TimeSeries::new();
        s.record(10, 1.0);
        s.record(5, 1.0);
    }
}
