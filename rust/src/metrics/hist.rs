//! Log-bucketed duration histogram: O(1) record, O(1) merge, integer
//! percentiles.
//!
//! Buckets are powers of two over µs values: a sample lands in the
//! bucket indexed by its bit width (`0 -> 0`, `1 -> 1`, `2..3 -> 2`,
//! `4..7 -> 3`, ...), capped at [`BUCKETS`]` - 1`. A percentile query
//! answers with the bucket's inclusive upper bound — an integer, so
//! digest lines built from it stay byte-comparable across reruns — with
//! at most 2× relative error, plenty for the stall / wire / queue-delay
//! distributions it summarizes. Merging is bucket-wise addition, which
//! makes it commutative and associative: shard bundles can be absorbed
//! in any order (the metrics-merge proptest pins this).

/// Number of power-of-two buckets (bit widths of a `u64`, plus zero).
pub const BUCKETS: usize = 64;

/// Fixed-size log₂ histogram of µs durations.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LogHistogram {
    buckets: [u64; BUCKETS],
    count: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self {
            buckets: [0; BUCKETS],
            count: 0,
        }
    }
}

impl LogHistogram {
    fn bucket_of(v_us: u64) -> usize {
        ((u64::BITS - v_us.leading_zeros()) as usize).min(BUCKETS - 1)
    }

    /// Inclusive upper bound of bucket `i` (µs).
    fn bucket_upper(i: usize) -> u64 {
        if i == 0 {
            0
        } else if i >= 63 {
            u64::MAX
        } else {
            (1u64 << i) - 1
        }
    }

    #[inline]
    pub fn record(&mut self, v_us: u64) {
        self.buckets[Self::bucket_of(v_us)] += 1;
        self.count += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Bucket-wise merge (commutative/associative — order-insensitive).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
    }

    /// Nearest-rank percentile, answered as the owning bucket's upper
    /// bound (µs). 0 on an empty histogram.
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil() as u64;
        let rank = rank.clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return Self::bucket_upper(i);
            }
        }
        Self::bucket_upper(BUCKETS - 1)
    }

    /// Compact integer digest fragment: `(count,p50,p999)` — stable
    /// across reruns, used by `MetricsBundle::digest_line`.
    pub fn digest_triplet(&self) -> (u64, u64, u64) {
        (
            self.count,
            self.percentile_us(50.0),
            self.percentile_us(99.9),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_bit_widths() {
        assert_eq!(LogHistogram::bucket_of(0), 0);
        assert_eq!(LogHistogram::bucket_of(1), 1);
        assert_eq!(LogHistogram::bucket_of(2), 2);
        assert_eq!(LogHistogram::bucket_of(3), 2);
        assert_eq!(LogHistogram::bucket_of(4), 3);
        assert_eq!(LogHistogram::bucket_of(1023), 10);
        assert_eq!(LogHistogram::bucket_of(1024), 11);
        assert_eq!(LogHistogram::bucket_of(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn percentile_returns_bucket_upper_bound() {
        let mut h = LogHistogram::default();
        for v in [1u64, 2, 3, 100, 1000, 100_000] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        // p50 (rank 3) lands in bucket of 3 -> upper bound 3.
        assert_eq!(h.percentile_us(50.0), 3);
        // p100 lands in the 100_000 bucket (bit width 17 -> 131071).
        assert_eq!(h.percentile_us(100.0), (1u64 << 17) - 1);
        // Within 2x of the true value.
        assert!(h.percentile_us(100.0) >= 100_000);
        assert!(h.percentile_us(100.0) < 200_000 + 62_144);
    }

    #[test]
    fn empty_histogram_answers_zero() {
        let h = LogHistogram::default();
        assert_eq!(h.percentile_us(99.0), 0);
        assert_eq!(h.digest_triplet(), (0, 0, 0));
    }

    #[test]
    fn merge_is_order_insensitive() {
        let mut parts: Vec<LogHistogram> = Vec::new();
        for k in 0..4u64 {
            let mut h = LogHistogram::default();
            for i in 0..50 {
                h.record(k * 1_000 + i * 37);
            }
            parts.push(h);
        }
        let mut fwd = LogHistogram::default();
        for p in &parts {
            fwd.merge(p);
        }
        let mut rev = LogHistogram::default();
        for p in parts.iter().rev() {
            rev.merge(p);
        }
        assert_eq!(fwd, rev);
        assert_eq!(fwd.digest_triplet(), rev.digest_triplet());
    }
}
