//! Metrics: latency distributions, utilization time-series, and event
//! counters — everything §7 reports (avg/P90/P95 latency, GPU KV-cache
//! utilization, preemption / critical-inversion / offload counts, swap
//! volume).

mod hist;
mod latency;
mod series;

pub use hist::LogHistogram;
pub use latency::LatencyRecorder;
pub use series::TimeSeries;

use crate::obs::attrib::{self, Phase, NPHASES};

/// Event counters accumulated over a run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Counters {
    /// Requests preempted (evicted while running) — Fig 3a.
    pub preemptions: u64,
    /// Preemptions where a non-critical request displaced a critical one
    /// ("critical inversion", §5).
    pub critical_inversions: u64,
    /// Contexts recomputed after eviction.
    pub recomputes: u64,
    /// Tokens re-prefilled due to recomputation.
    pub recompute_tokens: u64,
    /// Offloads vetoed by the opportunistic gate.
    pub offloads_rejected: u64,
    /// Uploads triggered early because a tool returned before prediction.
    pub early_returns: u64,
    /// Prefix-cache hits (GPU-, CPU-, and remote-resident).
    pub prefix_hits_gpu: u64,
    pub prefix_hits_cpu: u64,
    /// Hits on remote pointers seeded by the cluster prefix directory
    /// (the H2D debt is priced at the interconnect factor).
    pub prefix_hits_remote: u64,
    /// Fresh admissions that consulted the prefix index (hit-rate
    /// denominator).
    pub prefix_lookups: u64,
    /// Prefill tokens removed from admission debt by prefix hits.
    pub prefill_tokens_saved: u64,
    /// Prefix entries dropped outright under reclaim pressure.
    pub prefix_evictions: u64,
    /// Prefix entries demoted Gpu → Cpu under reclaim pressure.
    pub prefix_demotions: u64,
    /// Requests admitted through the reserved pool.
    pub reserved_admissions: u64,
    /// Requests deferred by admission control.
    pub deferrals: u64,
    /// Decode iterations executed.
    pub decode_iterations: u64,
    /// Total tokens generated.
    pub tokens_generated: u64,
    /// Scheduling steps run.
    pub sched_steps: u64,
    /// Requests aborted because their demand can never fit the pool.
    pub aborted: u64,
    /// Temporal-planner phase executions that passed the epoch gate
    /// (TokenCake/offload run_phase, Mooncake reactive phase).
    pub planner_runs: u64,
    /// Ticks on which the epoch gate skipped the temporal planner.
    pub planner_skips: u64,
    /// Spatial reservation replans executed at window expiry.
    pub spatial_plans: u64,
    /// Window expiries skipped because the plan's inputs were unchanged.
    pub spatial_plan_skips: u64,
    /// Multi-victim *local D2H offload* batches issued by the temporal
    /// planner (cross-worker migration batches are counted separately
    /// on `cluster::ClusterReport`).
    pub offload_batches: u64,
    /// Victims across those batches (mean batch = victims / batches).
    pub offload_batch_victims: u64,
    /// Function-call lifetime observations recorded (one per FC finish)
    /// — the KV-lifetime predictor's input stream.
    pub fc_lifetime_obs: u64,
}

impl Counters {
    /// Field-wise accumulation (cluster rollups).
    pub fn absorb(&mut self, o: &Counters) {
        self.preemptions += o.preemptions;
        self.critical_inversions += o.critical_inversions;
        self.recomputes += o.recomputes;
        self.recompute_tokens += o.recompute_tokens;
        self.offloads_rejected += o.offloads_rejected;
        self.early_returns += o.early_returns;
        self.prefix_hits_gpu += o.prefix_hits_gpu;
        self.prefix_hits_cpu += o.prefix_hits_cpu;
        self.prefix_hits_remote += o.prefix_hits_remote;
        self.prefix_lookups += o.prefix_lookups;
        self.prefill_tokens_saved += o.prefill_tokens_saved;
        self.prefix_evictions += o.prefix_evictions;
        self.prefix_demotions += o.prefix_demotions;
        self.reserved_admissions += o.reserved_admissions;
        self.deferrals += o.deferrals;
        self.decode_iterations += o.decode_iterations;
        self.tokens_generated += o.tokens_generated;
        self.sched_steps += o.sched_steps;
        self.aborted += o.aborted;
        self.planner_runs += o.planner_runs;
        self.planner_skips += o.planner_skips;
        self.spatial_plans += o.spatial_plans;
        self.spatial_plan_skips += o.spatial_plan_skips;
        self.offload_batches += o.offload_batches;
        self.offload_batch_victims += o.offload_batch_victims;
        self.fc_lifetime_obs += o.fc_lifetime_obs;
    }

    /// Planner executions per 1000 scheduling steps — the epoch-gating
    /// effectiveness headline (steady-state ticks skip the planner).
    pub fn planner_runs_per_1k_ticks(&self) -> f64 {
        if self.sched_steps == 0 {
            return 0.0;
        }
        self.planner_runs as f64 * 1000.0 / self.sched_steps as f64
    }

    /// Fraction of prefix lookups answered by a *local* tier (GPU or
    /// this shard's CPU copy).
    pub fn prefix_hit_rate_local(&self) -> f64 {
        if self.prefix_lookups == 0 {
            return 0.0;
        }
        (self.prefix_hits_gpu + self.prefix_hits_cpu) as f64
            / self.prefix_lookups as f64
    }

    /// Fraction of prefix lookups answered by a remote pointer.
    pub fn prefix_hit_rate_remote(&self) -> f64 {
        if self.prefix_lookups == 0 {
            return 0.0;
        }
        self.prefix_hits_remote as f64 / self.prefix_lookups as f64
    }
}

/// A complete run's metric bundle.
#[derive(Debug, Clone, Default)]
pub struct MetricsBundle {
    /// End-to-end application latency (submission → final response).
    pub latency: LatencyRecorder,
    /// Per-request latency (for tail analysis).
    pub request_latency: LatencyRecorder,
    /// App latency split by QoS tier (Interactive/Standard/Batch,
    /// index-aligned with `qos::Tier`). Always recorded — templates
    /// without an assigned tier land in Standard — so per-tier p99 is
    /// available whether or not the admission gate is on.
    pub tier_latency: [LatencyRecorder; crate::qos::TIERS],
    /// GPU KV pool occupancy over time ∈ [0,1].
    pub gpu_usage: TimeSeries,
    /// Fraction of occupied blocks belonging to *stalled* agents (Fig 2a).
    pub stalled_fraction: TimeSeries,
    /// Effective utilization: occupied ∧ owned by active requests (Fig 10).
    pub effective_usage: TimeSeries,
    pub counters: Counters,
    /// Stall durations (µs) — one sample per function-call lifetime
    /// observation (decode pause while the agent waits on its tool).
    pub stall_hist: LogHistogram,
    /// Transfer wire times (µs) — one sample per settled ledger
    /// transfer, D2H and H2D alike.
    pub wire_hist: LogHistogram,
    /// Admission queue delays (µs) — submission → admission grant.
    pub queue_hist: LogHistogram,
    /// Swap volume in blocks (both directions), from the ledger.
    pub swap_volume_blocks: u64,
    pub offload_count: u64,
    pub upload_count: u64,
    /// Apps completed.
    pub apps_completed: u64,
    /// Wall-clock span of the run (µs, simulated).
    pub makespan_us: u64,
    /// Σ phase time across finished requests (`obs::attrib` order) —
    /// the latency-attribution headline. Folded at request finish.
    pub phase_us: [u64; NPHASES],
    /// Per-phase distribution over finished requests (one sample per
    /// request per phase, zeros included so percentiles rank the whole
    /// population).
    pub phase_hist: [LogHistogram; NPHASES],
    /// Phase sums split by QoS tier (Interactive/Standard/Batch).
    pub tier_phase_us: [[u64; NPHASES]; crate::qos::TIERS],
    /// Phase sums split by graph template (index = registration order).
    pub tpl_phase_us: Vec<[u64; NPHASES]>,
    /// Gauge sampler series (fixed sim-clock cadence, per-shard only —
    /// not merged by [`Self::absorb`], like the utilization series).
    pub sched_running: TimeSeries,
    pub sched_stalled: TimeSeries,
    pub sched_offloaded: TimeSeries,
    /// Waiting-queue depth per QoS tier.
    pub queue_depth: [TimeSeries; crate::qos::TIERS],
}

impl MetricsBundle {
    /// Fold one worker shard's bundle into a cluster-wide aggregate:
    /// latency samples merge, counters and volumes add, makespan takes
    /// the max. Per-shard utilization *time series* are deliberately not
    /// merged — occupancy fractions of different pools don't concatenate;
    /// read them per shard (the cluster report keeps every shard bundle).
    pub fn absorb(&mut self, o: &MetricsBundle) {
        self.latency.merge(&o.latency);
        self.request_latency.merge(&o.request_latency);
        for (mine, theirs) in
            self.tier_latency.iter_mut().zip(&o.tier_latency)
        {
            mine.merge(theirs);
        }
        self.counters.absorb(&o.counters);
        self.stall_hist.merge(&o.stall_hist);
        self.wire_hist.merge(&o.wire_hist);
        self.queue_hist.merge(&o.queue_hist);
        self.swap_volume_blocks += o.swap_volume_blocks;
        self.offload_count += o.offload_count;
        self.upload_count += o.upload_count;
        self.apps_completed += o.apps_completed;
        self.makespan_us = self.makespan_us.max(o.makespan_us);
        for (a, b) in self.phase_us.iter_mut().zip(&o.phase_us) {
            *a += b;
        }
        for (a, b) in self.phase_hist.iter_mut().zip(&o.phase_hist) {
            a.merge(b);
        }
        for (at, bt) in
            self.tier_phase_us.iter_mut().zip(&o.tier_phase_us)
        {
            for (a, b) in at.iter_mut().zip(bt) {
                *a += b;
            }
        }
        if self.tpl_phase_us.len() < o.tpl_phase_us.len() {
            self.tpl_phase_us
                .resize(o.tpl_phase_us.len(), [0u64; NPHASES]);
        }
        for (at, bt) in self.tpl_phase_us.iter_mut().zip(&o.tpl_phase_us)
        {
            for (a, b) in at.iter_mut().zip(bt) {
                *a += b;
            }
        }
    }

    /// Fold one finished request's phase ledger into the per-run,
    /// per-tier, and per-template attribution aggregates. Called from
    /// `ServeState`'s finish transition, once per request.
    pub fn fold_phase_ledger(
        &mut self,
        accum: &[u64; NPHASES],
        template: usize,
        tier: usize,
    ) {
        if self.tpl_phase_us.len() <= template {
            self.tpl_phase_us.resize(template + 1, [0u64; NPHASES]);
        }
        for (i, &v) in accum.iter().enumerate() {
            self.phase_us[i] += v;
            self.phase_hist[i].record(v);
            self.tier_phase_us[tier][i] += v;
            self.tpl_phase_us[template][i] += v;
        }
    }

    /// Fraction of total function-call stall time hidden behind the
    /// tool (offload wire + off-GPU residency before the tool
    /// returned). 0 with temporal scheduling off — every stall µs is
    /// `fc_stall_held` — and > 0 when offload/predictive-upload
    /// overlap wire time with the call.
    pub fn stall_hidden_frac(&self) -> f64 {
        let hidden = self.phase_us[Phase::OffloadWire as usize]
            + self.phase_us[Phase::FcStallHidden as usize];
        let total = hidden
            + self.phase_us[Phase::FcStallHeld as usize]
            + self.phase_us[Phase::FcStallExposed as usize];
        if total == 0 {
            0.0
        } else {
            hidden as f64 / total as f64
        }
    }

    /// p99 of per-request exposed stall time (tool returned, request
    /// still waiting on upload wire / resume).
    pub fn exposed_upload_us_p99(&self) -> u64 {
        self.phase_hist[Phase::FcStallExposed as usize]
            .percentile_us(99.0)
    }

    /// p99 of per-request queue wait (admission gating).
    pub fn queue_wait_us_p99(&self) -> u64 {
        self.phase_hist[Phase::Queued as usize].percentile_us(99.0)
    }

    /// Canonical integer-only serialization of everything the scheduler
    /// decided. Two runs with the same seed and config must produce
    /// byte-identical lines — the determinism contract both the cluster
    /// digest and the single-engine regression tests assert.
    pub fn digest_line(&self, tag: &str) -> String {
        let [lat_p50, lat_p999] =
            self.latency.percentiles_us([50.0, 99.9]);
        let (st_n, st_p50, st_p999) = self.stall_hist.digest_triplet();
        let (wi_n, wi_p50, wi_p999) = self.wire_hist.digest_triplet();
        let (qu_n, qu_p50, qu_p999) = self.queue_hist.digest_triplet();
        let tier = |i: usize| {
            let r = &self.tier_latency[i];
            let [p50, p99] = r.percentiles_us([50.0, 99.0]);
            format!("{}/{}/{p50}/{p99}", r.len(), r.total_us())
        };
        let (t0, t1, t2) = (tier(0), tier(1), tier(2));
        let join = |a: &[u64]| {
            a.iter()
                .map(|v| v.to_string())
                .collect::<Vec<_>>()
                .join(",")
        };
        let ph = join(&self.phase_us);
        let pht = self
            .tier_phase_us
            .iter()
            .map(|t| join(t))
            .collect::<Vec<_>>()
            .join("|");
        let tpl = self
            .tpl_phase_us
            .iter()
            .map(|t| join(t))
            .collect::<Vec<_>>()
            .join("|");
        let hidm = attrib::stall_hidden_frac_milli(&self.phase_us);
        format!(
            "{tag}: apps={} lat_sum={} lat_n={} req_sum={} req_n={} \
             makespan={} swap={} off={} up={} preempt={} inv={} \
             recomp={} recomp_tok={} rej={} early={} pfx_gpu={} \
             pfx_cpu={} pfx_rem={} pfx_look={} pfx_saved={} \
             pfx_evict={} pfx_demote={} resv={} defer={} iters={} \
             toks={} aborts={} plan={} pskip={} splan={} sskip={} \
             obatch={} ovict={} fclt={} lat_p50={lat_p50} \
             lat_p999={lat_p999} stall={st_n}/{st_p50}/{st_p999} \
             wire={wi_n}/{wi_p50}/{wi_p999} \
             queue={qu_n}/{qu_p50}/{qu_p999} \
             tierI={t0} tierS={t1} tierB={t2} \
             ph=[{ph}] phT=[{pht}] phTpl=[{tpl}] hidm={hidm} \
             expp99={} qwp99={}\n",
            self.apps_completed,
            self.latency.total_us(),
            self.latency.len(),
            self.request_latency.total_us(),
            self.request_latency.len(),
            self.makespan_us,
            self.swap_volume_blocks,
            self.offload_count,
            self.upload_count,
            self.counters.preemptions,
            self.counters.critical_inversions,
            self.counters.recomputes,
            self.counters.recompute_tokens,
            self.counters.offloads_rejected,
            self.counters.early_returns,
            self.counters.prefix_hits_gpu,
            self.counters.prefix_hits_cpu,
            self.counters.prefix_hits_remote,
            self.counters.prefix_lookups,
            self.counters.prefill_tokens_saved,
            self.counters.prefix_evictions,
            self.counters.prefix_demotions,
            self.counters.reserved_admissions,
            self.counters.deferrals,
            self.counters.decode_iterations,
            self.counters.tokens_generated,
            self.counters.aborted,
            self.counters.planner_runs,
            self.counters.planner_skips,
            self.counters.spatial_plans,
            self.counters.spatial_plan_skips,
            self.counters.offload_batches,
            self.counters.offload_batch_victims,
            self.counters.fc_lifetime_obs,
            self.exposed_upload_us_p99(),
            self.queue_wait_us_p99(),
        )
    }

    /// Throughput in completed apps per second.
    pub fn throughput(&self) -> f64 {
        if self.makespan_us == 0 {
            return 0.0;
        }
        self.apps_completed as f64 / (self.makespan_us as f64 / 1e6)
    }

    /// One-line summary used by examples and benches.
    pub fn summary(&self) -> String {
        let [p50, p90, p95, p999] =
            self.latency.percentiles_s([50.0, 90.0, 95.0, 99.9]);
        format!(
            "apps={} avg={:.1}s p50={:.1}s p90={:.1}s p95={:.1}s \
             p99.9={:.1}s total={:.1}s \
             thpt={:.4}req/s gpu_util={:.1}% eff_util={:.1}% \
             offloads={} swap_blocks={} preempt={} inversions={} \
             stall_hidden={:.3} exposed_p99={:.3}s queue_p99={:.3}s",
            self.apps_completed,
            self.latency.mean_s(),
            p50,
            p90,
            p95,
            p999,
            self.makespan_us as f64 / 1e6,
            self.throughput(),
            self.gpu_usage.time_weighted_mean() * 100.0,
            self.effective_usage.time_weighted_mean() * 100.0,
            self.offload_count,
            self.swap_volume_blocks,
            self.counters.preemptions,
            self.counters.critical_inversions,
            self.stall_hidden_frac(),
            self.exposed_upload_us_p99() as f64 / 1e6,
            self.queue_wait_us_p99() as f64 / 1e6,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_computes() {
        let m = MetricsBundle {
            apps_completed: 10,
            makespan_us: 5_000_000,
            ..Default::default()
        };
        assert!((m.throughput() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn digest_line_is_stable_and_tagged() {
        let mut m = MetricsBundle::default();
        m.apps_completed = 3;
        m.counters.preemptions = 2;
        m.stall_hist.record(1_500);
        let a = m.digest_line("shard0");
        assert!(a.starts_with("shard0: apps=3"));
        assert!(a.contains("preempt=2"));
        assert!(a.contains("lat_p50="));
        assert!(a.contains("stall=1/"));
        assert!(a.contains("queue=0/0/0"));
        assert_eq!(a, m.digest_line("shard0"));
    }

    #[test]
    fn digest_line_carries_per_tier_latency() {
        let mut m = MetricsBundle::default();
        m.tier_latency[0].record_us(1_000);
        m.tier_latency[2].record_us(9_000);
        let d = m.digest_line("run");
        assert!(d.contains("tierI=1/1000/1000/1000"), "{d}");
        assert!(d.contains("tierS=0/0/0/0"), "{d}");
        assert!(d.contains("tierB=1/9000/9000/9000"), "{d}");

        let mut agg = MetricsBundle::default();
        agg.absorb(&m);
        agg.absorb(&m);
        assert_eq!(agg.tier_latency[0].len(), 2);
        assert_eq!(agg.tier_latency[2].len(), 2);
    }

    #[test]
    fn phase_attribution_folds_and_digests() {
        let mut m = MetricsBundle::default();
        let mut accum = [0u64; NPHASES];
        accum[Phase::Queued as usize] = 100;
        accum[Phase::Decode as usize] = 900;
        accum[Phase::FcStallHeld as usize] = 100;
        accum[Phase::FcStallHidden as usize] = 300;
        m.fold_phase_ledger(&accum, 1, 0);
        assert_eq!(m.phase_us[Phase::Decode as usize], 900);
        assert_eq!(m.tpl_phase_us.len(), 2);
        assert_eq!(m.tier_phase_us[0][Phase::Queued as usize], 100);
        assert!((m.stall_hidden_frac() - 0.75).abs() < 1e-9);
        let d = m.digest_line("x");
        assert!(d.contains("hidm=750"), "{d}");
        assert!(d.contains("ph=[100,0,0,0,900,100,0,300,0,0]"), "{d}");
        assert_eq!(d, m.digest_line("x"));
        // Aggregation is field-wise and order-insensitive.
        let mut agg = MetricsBundle::default();
        agg.absorb(&m);
        agg.absorb(&m);
        assert_eq!(agg.phase_us[Phase::Decode as usize], 1800);
        assert_eq!(agg.phase_hist[Phase::Queued as usize].count(), 2);
        assert_eq!(agg.tpl_phase_us[1][Phase::Decode as usize], 1800);
    }

    #[test]
    fn summary_contains_key_fields() {
        let m = MetricsBundle::default();
        let s = m.summary();
        assert!(s.contains("apps=0"));
        assert!(s.contains("inversions=0"));
    }

    #[test]
    fn absorb_accumulates_across_shards() {
        let mut a = MetricsBundle::default();
        a.latency.record_us(1_000_000);
        a.apps_completed = 1;
        a.makespan_us = 5_000_000;
        a.counters.preemptions = 2;
        a.swap_volume_blocks = 10;
        let mut b = MetricsBundle::default();
        b.latency.record_us(3_000_000);
        b.apps_completed = 2;
        b.makespan_us = 9_000_000;
        b.counters.preemptions = 1;
        b.swap_volume_blocks = 5;
        a.wire_hist.record(100);
        b.wire_hist.record(9_000);
        a.absorb(&b);
        assert_eq!(a.wire_hist.count(), 2);
        assert_eq!(a.apps_completed, 3);
        assert_eq!(a.makespan_us, 9_000_000);
        assert_eq!(a.counters.preemptions, 3);
        assert_eq!(a.swap_volume_blocks, 15);
        assert_eq!(a.latency.len(), 2);
        assert!((a.latency.mean_s() - 2.0).abs() < 1e-9);
        assert_eq!(a.latency.total_us(), 4_000_000);
    }
}
