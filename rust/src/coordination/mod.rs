//! Shared coordination layer (§3.2).
//!
//! The Temporal and Spatial Schedulers optimize different dimensions but
//! compete for the same GPU memory. They coordinate through (a) a shared
//! [`PressureSnapshot`] so both act on one notion of pressure, and (b) a
//! fixed four-phase execution order within each scheduling step:
//!
//! 1. refresh application metadata, build the pressure snapshot;
//! 2. update the Spatial Scheduler's reservation plan (window expiry);
//! 3. Temporal Scheduler: reserve blocks for imminent uploads, start
//!    ready uploads, evaluate newly stalled requests for offload;
//! 4. Spatial Scheduler: form the next batch under agent-aware admission
//!    control (shared / reserved / defer).
//!
//! [`ServeState`] owns every piece of state both schedulers read or write;
//! the schedulers themselves are free functions over it (`temporal::*`,
//! `spatial::*`), and both engines (sim and PJRT-real) drive the same
//! [`step`] entry point.

mod arena;
mod request;
mod state;

pub use arena::{AppArena, BatchQueue, IdHasher, IdMap, RequestArena};
pub use request::{
    AppId, AppInst, FcRt, PhaseRt, ReqState, Request, RequestId,
};
pub use state::{
    MigratedApp, SchedScratch, ServeState, ThroughputEstimator,
    TypeRegistry,
};

use crate::kvcache::TransferId;

/// Side effects the schedulers emit for the engine to realize (the engine
/// owns the event clock; schedulers stay engine-agnostic). `Copy` so the
/// engine's outbox drain never clones or reallocates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// A block migration was issued; fire `TransferDone(xfer)` at
    /// `completes_us`.
    TransferIssued {
        xfer: TransferId,
        completes_us: u64,
    },
}

use crate::config::Mode;

/// The shared pressure snapshot (§3.2): "GPU and CPU block availability,
/// per-agent-type reserved capacity, waiting demand, offloadable stalled
/// blocks, and pending upload debt."
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PressureSnapshot {
    pub gpu_total: u32,
    pub gpu_free: u32,
    pub gpu_pending_free: u32,
    pub shared_free: u32,
    pub reserved_outstanding: u32,
    pub cpu_free: u32,
    /// Blocks demanded by all waiting requests.
    pub waiting_demand: u32,
    /// Blocks demanded by waiting requests of critical types (D_critical).
    pub critical_demand: u32,
    /// Blocks held by stalled (offloadable) requests.
    pub offloadable_stalled: u32,
    /// Blocks of in-flight H2D uploads (upload debt).
    pub upload_debt: u32,
    /// Number of waiting requests.
    pub waiting_count: u32,
    /// GPU occupancy in [0,1] (pending-free counts as occupied).
    pub usage: f64,
}

impl PressureSnapshot {
    /// Waiting demand as a fraction of the pool — the quantity the
    /// Fig 16 "spatial pressure watermark" gates on.
    pub fn waiting_pressure(&self) -> f64 {
        if self.gpu_total == 0 {
            return 0.0;
        }
        self.waiting_demand as f64 / self.gpu_total as f64
    }
}

/// One full scheduling step (the §3.2 fixed order). Both engines call this
/// once per engine iteration.
pub fn step(st: &mut ServeState, now_us: u64) {
    st.metrics.counters.sched_steps += 1;

    // Phase 1: refresh metadata + snapshot.
    st.refresh_priorities(now_us);
    let snap = st.snapshot();

    // Phase 2: reservation plan (TokenCake / agent-only).
    if st.cfg.mode.reserves_memory() {
        crate::spatial::maybe_update_reservations(st, now_us);
    }

    // Phase 3: temporal scheduler.
    match st.cfg.mode {
        Mode::TokenCake | Mode::OffloadOnly | Mode::Infercept => {
            crate::temporal::run_phase(st, &snap, now_us);
        }
        Mode::Mooncake => {
            crate::baselines::mooncake_reactive_phase(st, &snap, now_us);
        }
        _ => {}
    }

    // Phase 4: admission control.
    crate::spatial::admit(st, now_us);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;
    use crate::graph::templates;

    #[test]
    fn snapshot_reflects_pool_state() {
        let cfg = ServeConfig::default();
        let mut st = ServeState::new(cfg);
        let g = templates::code_writer();
        st.register_graph(&g);
        let snap = st.snapshot();
        assert_eq!(snap.gpu_free, snap.gpu_total);
        assert_eq!(snap.waiting_demand, 0);
        assert_eq!(snap.usage, 0.0);
        assert_eq!(snap.waiting_pressure(), 0.0);
    }

    #[test]
    fn step_runs_all_phases_without_work() {
        let mut st = ServeState::new(ServeConfig::default());
        let g = templates::rag();
        st.register_graph(&g);
        step(&mut st, 1000);
        assert_eq!(st.metrics.counters.sched_steps, 1);
    }
}
