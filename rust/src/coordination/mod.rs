//! Shared coordination layer (§3.2).
//!
//! The Temporal and Spatial Schedulers optimize different dimensions but
//! compete for the same GPU memory. They coordinate through (a) a shared
//! [`PressureSnapshot`] so both act on one notion of pressure, and (b) a
//! fixed four-phase execution order within each scheduling step:
//!
//! 1. refresh application metadata, note the O(1) pressure-band delta;
//! 2. update the Spatial Scheduler's reservation plan (window expiry,
//!    replanned only when its inputs' epochs moved);
//! 3. Temporal Scheduler: reserve blocks for imminent uploads, start
//!    ready uploads, evaluate newly stalled requests for offload —
//!    *epoch-gated*: skipped entirely unless a temporal event landed or
//!    a predictive-upload deadline arrived since the last plan;
//! 4. Spatial Scheduler: form the next batch under agent-aware admission
//!    control (shared / reserved / defer) — every tick.
//!
//! The scheduler is event-driven by construction: every mutation that can
//! change a scheduling decision bumps a per-subsystem epoch in
//! [`SchedEpochs`] (see its docs for the bump map), planners record the
//! epochs they consumed, and a steady-state decode tick — no arrival, no
//! stall, no tool return, no transfer, no pressure-band crossing — does
//! only the snapshot delta plus admission. The full pressure snapshot is
//! built lazily *inside* the planner gates, so skipped ticks never pay
//! for it.
//!
//! [`ServeState`] owns every piece of state both schedulers read or write;
//! the schedulers themselves are free functions over it (`temporal::*`,
//! `spatial::*`), and both engines (sim and PJRT-real) drive the same
//! [`step`] entry point.

mod arena;
mod request;
mod state;

pub use arena::{AppArena, BatchQueue, IdHasher, IdMap, RequestArena};
pub use request::{
    AppId, AppInst, FcRt, PhaseRt, ReqState, Request, RequestId,
};
pub use state::{
    MigratedApp, PrefixEvent, SchedEpochs, SchedScratch, ServeState,
    ThroughputEstimator, TypeRegistry,
};
pub(crate) use state::state_code;

use crate::kvcache::TransferId;

/// Side effects the schedulers emit for the engine to realize (the engine
/// owns the event clock; schedulers stay engine-agnostic). `Copy` so the
/// engine's outbox drain never clones or reallocates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// A block migration was issued; fire `TransferDone(xfer)` at
    /// `completes_us`.
    TransferIssued {
        xfer: TransferId,
        completes_us: u64,
    },
}

use crate::config::Mode;

/// The shared pressure snapshot (§3.2): "GPU and CPU block availability,
/// per-agent-type reserved capacity, waiting demand, offloadable stalled
/// blocks, and pending upload debt."
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PressureSnapshot {
    pub gpu_total: u32,
    pub gpu_free: u32,
    pub gpu_pending_free: u32,
    pub shared_free: u32,
    pub reserved_outstanding: u32,
    pub cpu_free: u32,
    /// Blocks demanded by all waiting requests.
    pub waiting_demand: u32,
    /// Blocks demanded by waiting requests of critical types (D_critical).
    pub critical_demand: u32,
    /// Blocks held by stalled (offloadable) requests.
    pub offloadable_stalled: u32,
    /// CPU blocks held by offloaded requests — KV parked off-GPU that
    /// will return as demand when its tool finishes. The autoscale
    /// controller counts it as near-term resumption load so the fleet
    /// is not drained out from under work that is about to resume.
    pub offloaded_blocks: u32,
    /// Blocks of in-flight H2D uploads (upload debt).
    pub upload_debt: u32,
    /// Number of waiting requests.
    pub waiting_count: u32,
    /// GPU occupancy in [0,1] (pending-free counts as occupied).
    pub usage: f64,
}

impl PressureSnapshot {
    /// Waiting demand as a fraction of the pool — the quantity the
    /// Fig 16 "spatial pressure watermark" gates on.
    pub fn waiting_pressure(&self) -> f64 {
        if self.gpu_total == 0 {
            return 0.0;
        }
        self.waiting_demand as f64 / self.gpu_total as f64
    }
}

/// One full scheduling step (the §3.2 fixed order). Both engines call this
/// once per engine iteration. Planner phases are epoch-gated: a tick on
/// which no scheduling-relevant event landed runs only the O(1) pressure
/// delta, the priority refresh, and admission.
pub fn step(st: &mut ServeState, now_us: u64) {
    st.metrics.counters.sched_steps += 1;

    // Snapshot delta: crossing a pressure watermark band is an event.
    st.note_pressure_band();

    // Phase 1: refresh metadata.
    st.refresh_priorities(now_us);

    // Phase 2: reservation plan (TokenCake / agent-only) — window plus
    // epoch gated inside.
    if st.cfg.mode.reserves_memory() {
        crate::spatial::maybe_update_reservations(st, now_us);
    }

    // Phase 3: temporal scheduler, behind the epoch/deadline gate. The
    // pressure snapshot is built lazily inside the gate.
    match st.cfg.mode {
        Mode::TokenCake | Mode::OffloadOnly | Mode::Infercept => {
            crate::temporal::maybe_run_phase(st, now_us);
        }
        Mode::Mooncake => {
            crate::baselines::maybe_mooncake_phase(st, now_us);
        }
        _ => {}
    }

    // Phase 4: admission control — every tick.
    crate::spatial::admit(st, now_us);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;
    use crate::graph::templates;

    #[test]
    fn snapshot_reflects_pool_state() {
        let cfg = ServeConfig::default();
        let mut st = ServeState::new(cfg);
        let g = templates::code_writer();
        st.register_graph(&g);
        let snap = st.snapshot();
        assert_eq!(snap.gpu_free, snap.gpu_total);
        assert_eq!(snap.waiting_demand, 0);
        assert_eq!(snap.usage, 0.0);
        assert_eq!(snap.waiting_pressure(), 0.0);
    }

    #[test]
    fn step_runs_all_phases_without_work() {
        let mut st = ServeState::new(ServeConfig::default());
        let g = templates::rag();
        st.register_graph(&g);
        step(&mut st, 1000);
        assert_eq!(st.metrics.counters.sched_steps, 1);
    }

    #[test]
    fn steady_ticks_are_epoch_gated() {
        // No arrival, no stall, no transfer, no pressure crossing: every
        // tick after the first skips the temporal planner, and window
        // expiries skip the spatial replan.
        let mut st = ServeState::new(ServeConfig::default());
        let g = templates::rag();
        st.register_graph(&g);
        for i in 0..20u64 {
            step(&mut st, 1_000_000 * (i + 1)); // one adjust window apart
        }
        let c = &st.metrics.counters;
        assert_eq!(c.sched_steps, 20);
        assert_eq!(c.planner_runs, 0, "no temporal event ever landed");
        assert_eq!(c.planner_skips, 20);
        assert_eq!(
            c.spatial_plans, 0,
            "no spatial input ever changed"
        );
        assert!(c.spatial_plan_skips > 0);
    }

    #[test]
    fn gated_ticks_account_every_step() {
        // Gate bookkeeping: in a gated mode every scheduling step either
        // runs or skips the temporal planner, never both, never neither.
        let mut st = ServeState::new(ServeConfig::default());
        let g = templates::code_writer();
        let t = st.register_graph(&g);
        let scales = crate::workload::SampledLengths {
            prompt_scale: 1.0,
            gen_scale: 1.0,
        };
        st.spawn_app(t, scales, 0);
        for i in 0..50u64 {
            step(&mut st, 1_000 * (i + 1));
        }
        let c = &st.metrics.counters;
        assert_eq!(c.planner_runs + c.planner_skips, c.sched_steps);
    }
}
