//! [`ServeState`]: the single source of truth both schedulers and both
//! engines operate on — pools, queues, request/app tables, forecaster,
//! throughput estimate, reservation state, metrics.
//!
//! Storage is deterministic by construction: requests and apps live in
//! dense [`RequestArena`] / [`AppArena`] slabs (insertion-order
//! iteration, identity-hash id index), batch membership is the O(1)
//! [`BatchQueue`], and the function-call lifecycle maintains ordered
//! incremental indices ([`ServeState::stalled_ids`] /
//! [`ServeState::offloaded_ids`]) so no scheduler phase ever scans every
//! request that existed or sorts a `HashMap`'s iteration order away.

use std::collections::BTreeSet;
use std::collections::HashMap;
use std::collections::VecDeque;

use super::arena::{AppArena, BatchQueue, RequestArena};
use super::request::{
    result_tokens, AppId, AppInst, PhaseRt, ReqState, Request, RequestId,
};
use super::PressureSnapshot;
use crate::config::ServeConfig;
use crate::graph::{AppGraph, NodeId, NodeKind};
use crate::kvcache::{
    AgentTypeId, BlockSet, CpuBlockPool, GpuPool, MigrationLedger,
    PrefixIndex, PrefixKey, PrefixLocation, TransferKind,
};
use crate::metrics::MetricsBundle;
use crate::obs::{self, TraceSink};
use crate::temporal::Forecaster;
use crate::workload::SampledLengths;

/// Trace code for a lifecycle state (see [`obs::state`] — the codes
/// mirror [`ReqState`]'s declaration order).
pub(crate) fn state_code(s: ReqState) -> u8 {
    match s {
        ReqState::Waiting => obs::state::WAITING,
        ReqState::Prefilling => obs::state::PREFILLING,
        ReqState::Running => obs::state::RUNNING,
        ReqState::Stalled => obs::state::STALLED,
        ReqState::PendingOffload => obs::state::PENDING_OFFLOAD,
        ReqState::Offloaded => obs::state::OFFLOADED,
        ReqState::PendingUpload => obs::state::PENDING_UPLOAD,
        ReqState::Uploaded => obs::state::UPLOADED,
        ReqState::Finished => obs::state::FINISHED,
    }
}

/// Interns agent-type names and accumulates per-type counters used by the
/// agent-type score S_a (Eq. 6): preemptions weigh KV-capacity loss,
/// waiting counts weigh unserved demand.
#[derive(Debug, Clone, Default)]
pub struct TypeRegistry {
    names: Vec<String>,
    by_name: HashMap<String, AgentTypeId>,
    pub preempts: Vec<f64>,
    pub waits: Vec<f64>,
}

impl TypeRegistry {
    pub fn intern(&mut self, name: &str) -> AgentTypeId {
        if let Some(&id) = self.by_name.get(name) {
            return id;
        }
        let id = self.names.len() as AgentTypeId;
        self.names.push(name.to_string());
        self.by_name.insert(name.to_string(), id);
        self.preempts.push(0.0);
        self.waits.push(0.0);
        id
    }

    pub fn name(&self, id: AgentTypeId) -> &str {
        &self.names[id as usize]
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    pub fn note_preempt(&mut self, id: AgentTypeId) {
        self.preempts[id as usize] += 1.0;
    }

    pub fn note_wait(&mut self, id: AgentTypeId) {
        self.waits[id as usize] += 1.0;
    }

    /// Exponential decay so urgency reflects *recent* failures to serve.
    pub fn decay(&mut self, factor: f64) {
        for v in self.preempts.iter_mut().chain(self.waits.iter_mut()) {
            *v *= factor;
        }
    }
}

/// Observed decode throughput v_throughput (Algorithm 1) as an EWMA of
/// tokens/second across engine iterations.
#[derive(Debug, Clone)]
pub struct ThroughputEstimator {
    tokens_per_sec: f64,
    seeded: bool,
}

impl Default for ThroughputEstimator {
    fn default() -> Self {
        Self {
            // Conservative prior until the first iteration lands.
            tokens_per_sec: 500.0,
            seeded: false,
        }
    }
}

impl ThroughputEstimator {
    pub fn record_iteration(&mut self, tokens: u32, dt_us: u64) {
        if dt_us == 0 {
            return;
        }
        let inst = tokens as f64 / (dt_us as f64 / 1e6);
        if self.seeded {
            self.tokens_per_sec = 0.9 * self.tokens_per_sec + 0.1 * inst;
        } else {
            self.tokens_per_sec = inst;
            self.seeded = true;
        }
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens_per_sec.max(1.0)
    }
}

/// An application lifted out of one worker's [`ServeState`] for
/// cross-worker migration (see `cluster::ClusterEngine`). Carries the DAG
/// progress plus every request the app ever spawned — finished requests
/// included, because child prompt inheritance reads the parent request's
/// `tokens_generated` at spawn time.
#[derive(Debug, Clone)]
pub struct MigratedApp {
    /// Graph template index — only valid when source and destination
    /// registered the same templates in the same order (the cluster layer
    /// guarantees this at startup).
    pub template: usize,
    pub app: AppInst,
    /// All requests of the app, in id order.
    pub requests: Vec<Request>,
}

/// Per-subsystem dirty epochs — the event-driven scheduling contract.
///
/// Every mutation that can change a scheduling decision bumps the epoch
/// of the subsystem whose inputs it touched; planners record the epochs
/// they consumed ([`ServeState::planned`]) and a tick whose epochs match
/// the watermarks skips the corresponding phase entirely. The bump map:
///
/// * `temporal` — FC stall (`call_start`), tool return (`call_finish`),
///   transfer completion (`on_transfer_done`), any lifecycle reindex
///   through the stalled/offloaded sets, a broken upload reservation
///   (deadlock rescue), and app extract/implant (cluster migration).
///   Plain block frees deliberately do not bump it — a budget-starved
///   upload retries on the planner's bounded backoff instead, so
///   preemption storms cannot re-open the gate every tick.
/// * `spatial` — request spawn (arrival), admission grants/deferrals,
///   preemption, request finish, app extract/implant, and every
///   executed engine iteration (execution-time charging drifts the
///   agent-type score's H_a input) — everything the agent-type score
///   S_a and the reservation plan read.
/// * `pressure` — the GPU free list crossing a policy threshold
///   (low/offload/high/emergency watermark band), detected O(1) per
///   tick by [`ServeState::note_pressure_band`].
///
/// Prefix-cache lifecycle mutations (insert at request finish, LRU
/// eviction, Gpu↔Cpu relocation, remote-pointer seeding) bump *both*
/// the temporal and spatial epochs via
/// [`ServeState::note_prefix_mutation`]: they move pinned blocks the
/// planners' snapshots count and change what the next admission's
/// prefix lookup will see.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SchedEpochs {
    pub spatial: u64,
    pub temporal: u64,
    pub pressure: u64,
}

/// One prefix-index lifecycle mutation, published for the cluster prefix
/// directory (see `cluster::prefix_dir`). Recording is off by default —
/// standalone engines pay nothing; the cluster driver flips
/// [`ServeState::publish_prefix_events`] and drains the log after every
/// shard step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PrefixEvent {
    /// A new (or displaced-and-replaced) entry with local backing.
    Inserted {
        key: PrefixKey,
        blocks: u32,
        tokens: u32,
        location: PrefixLocation,
    },
    /// Residency changed (Gpu → Cpu demotion today).
    Relocated {
        key: PrefixKey,
        location: PrefixLocation,
    },
    /// Entry dropped; its backing returned to the pools.
    Removed { key: PrefixKey },
    /// An admission hit a remote pointer (replication-policy signal).
    RemoteHit { key: PrefixKey },
}

/// Spatial Scheduler mutable state (ρ, critical set, adjustment window).
#[derive(Debug, Clone)]
pub struct SpatialState {
    /// Current reserved-pool fraction ρ (Algorithm 2 step 1).
    pub rho: f64,
    pub last_adjust_us: u64,
    /// Currently designated critical agent types (Algorithm 2 step 2).
    pub critical_types: Vec<AgentTypeId>,
}

/// Reusable scheduler scratch buffers: the admission phase runs every
/// engine tick and must not allocate on the steady state.
#[derive(Debug, Default)]
pub struct SchedScratch {
    /// Admission candidate order (resumed segment, then fresh segment).
    pub order: Vec<RequestId>,
    /// Requests admitted this phase (drained back into the scratch).
    pub admitted: Vec<RequestId>,
}

/// The complete serving state shared by schedulers and engines.
pub struct ServeState {
    pub cfg: ServeConfig,
    pub gpu: GpuPool,
    pub cpu: CpuBlockPool,
    pub prefix: PrefixIndex,
    pub ledger: MigrationLedger,
    pub graphs: Vec<AppGraph>,
    /// Request slab: id-indexed, deterministic iteration, live list.
    pub reqs: RequestArena,
    /// App slab (owns each app's graph-template index).
    pub apps: AppArena,
    /// Waiting queue in arrival order (schedulers may scan by priority).
    pub waiting: VecDeque<RequestId>,
    /// Requests currently in the decode batch.
    pub running: BatchQueue,
    /// Requests admitted but still prefilling (chunked).
    pub prefilling: BatchQueue,
    /// Ordered incremental index: requests in [`ReqState::Stalled`].
    /// Maintained on lifecycle transitions (see
    /// [`ServeState::reindex_request`]); iteration is id-ordered, which
    /// is exactly the order the seed obtained by sorting per tick.
    pub stalled_ids: BTreeSet<RequestId>,
    /// Ordered incremental index: requests in [`ReqState::Offloaded`].
    pub offloaded_ids: BTreeSet<RequestId>,
    pub types: TypeRegistry,
    pub forecaster: Forecaster,
    pub throughput: ThroughputEstimator,
    pub spatial: SpatialState,
    pub metrics: MetricsBundle,
    /// Per-shard QoS view: template → [`crate::qos::Tier`] plus SLO
    /// targets, used for SLO-headroom victim biasing and per-tier
    /// latency recording. Disabled ([`crate::qos::ShardQos::off`]) by
    /// default so legacy single-tenant runs are bit-identical.
    pub qos: crate::qos::ShardQos,
    /// Scheduler-emitted side effects the engine drains each step.
    pub outbox: Vec<super::Action>,
    /// Hot-path scratch buffers (admission ordering).
    pub scratch: SchedScratch,
    /// Dirty epochs: bumped by every scheduling-relevant mutation.
    pub epochs: SchedEpochs,
    /// Watermarks: the epochs each planner consumed on its last run.
    pub planned: SchedEpochs,
    /// Next absolute time (µs) the temporal planner has deadline work
    /// (predictive-upload lead windows); `u64::MAX` when none. Derived
    /// state, recomputed after every planner run.
    pub temporal_next_due_us: u64,
    /// Prefix-index lifecycle log for the cluster prefix directory
    /// (recorded only when [`Self::publish_prefix_events`] is set).
    /// One of the per-shard *outboxes* of the cluster concurrency
    /// contract: appended freely during the shard-local (possibly
    /// parallel) phase, drained by the cluster driver only at a
    /// serial barrier in shard order — never read cross-shard
    /// mid-phase.
    pub prefix_events: Vec<PrefixEvent>,
    /// Cluster driver flips this so prefix mutations are published.
    pub publish_prefix_events: bool,
    /// Observed `(template, stall µs)` pairs, one per finished function
    /// call — the input of the cluster autoscaler's per-template
    /// KV-lifetime predictor (Continuum-style: lifetime ≈ the
    /// template's tool-call profile × observed stall durations).
    /// Recorded only when [`Self::publish_lifetime_obs`] is set.
    /// Like [`Self::prefix_events`], a per-shard outbox: the
    /// autoscale controller drains it at the barrier, in shard order.
    pub fc_lifetime_obs: Vec<(usize, u64)>,
    /// Cluster autoscaler flips this so FC lifetimes are published.
    pub publish_lifetime_obs: bool,
    /// Structured trace sink (see [`crate::obs`]); one branch per emit
    /// when disabled. The owning engine advances its clock stamp.
    pub trace: TraceSink,
    /// Skip-counter values at each planner's previous traced run
    /// (index = [`obs::planner`] code) — the PlannerGate event carries
    /// the delta, i.e. gated steps since that planner last ran.
    traced_planner_skips: [u64; 2],
    /// Last observed pressure band (see [`Self::note_pressure_band`]).
    last_pressure_band: u8,
    /// QoS gate wait staged for the next `spawn_app`'s root requests
    /// (see [`Self::stage_qos_wait`]).
    qos_wait_pending_us: u64,
    /// Next gauge-sample boundary (see [`Self::maybe_sample_gauges`]).
    next_gauge_us: u64,
    next_req: u64,
    next_app: u64,
}

/// Fixed sim-clock cadence of the scheduler gauge sampler (µs).
pub const GAUGE_CADENCE_US: u64 = 50_000;

impl ServeState {
    pub fn new(cfg: ServeConfig) -> Self {
        let gpu = GpuPool::new(cfg.gpu_blocks());
        let cpu = CpuBlockPool::new(cfg.profile.cpu_blocks);
        let forecaster = Forecaster::new(
            cfg.policy.forecast_alpha_user,
            cfg.policy.forecast_ewma,
            cfg.policy.forecast_default_us,
        );
        let rho = cfg.policy.reserve_init;
        Self {
            cfg,
            gpu,
            cpu,
            prefix: PrefixIndex::new(),
            ledger: MigrationLedger::new(),
            graphs: Vec::new(),
            reqs: RequestArena::new(),
            apps: AppArena::new(),
            waiting: VecDeque::new(),
            running: BatchQueue::new(),
            prefilling: BatchQueue::new(),
            stalled_ids: BTreeSet::new(),
            offloaded_ids: BTreeSet::new(),
            types: TypeRegistry::default(),
            forecaster,
            throughput: ThroughputEstimator::default(),
            spatial: SpatialState {
                rho,
                last_adjust_us: 0,
                critical_types: Vec::new(),
            },
            metrics: MetricsBundle::default(),
            qos: crate::qos::ShardQos::off(),
            outbox: Vec::new(),
            scratch: SchedScratch::default(),
            epochs: SchedEpochs::default(),
            planned: SchedEpochs::default(),
            temporal_next_due_us: u64::MAX,
            prefix_events: Vec::new(),
            publish_prefix_events: false,
            fc_lifetime_obs: Vec::new(),
            publish_lifetime_obs: false,
            trace: TraceSink::default(),
            traced_planner_skips: [0; 2],
            last_pressure_band: 0,
            qos_wait_pending_us: 0,
            next_gauge_us: 0,
            next_req: 0,
            next_app: 0,
        }
    }

    // ------------------------------------------------------------------
    // Dirty-epoch maintenance
    // ------------------------------------------------------------------

    /// Classify GPU occupancy against the policy watermarks. A band
    /// transition is exactly when a threshold-gated decision (ρ drift,
    /// offload gate, emergency override) can flip.
    fn pressure_band(&self) -> u8 {
        let u = self.gpu.usage();
        let p = &self.cfg.policy;
        if u >= p.emergency_usage {
            4
        } else if u >= p.high_watermark {
            3
        } else if u >= p.offload_usage_threshold {
            2
        } else if u >= p.low_watermark {
            1
        } else {
            0
        }
    }

    /// O(1) snapshot delta, run once per tick: bump the pressure epoch
    /// when the free list crossed a watermark band since the last tick.
    pub fn note_pressure_band(&mut self) {
        let band = self.pressure_band();
        if band != self.last_pressure_band {
            self.last_pressure_band = band;
            self.epochs.pressure += 1;
            self.trace.pressure_band(band, self.gpu.free_blocks());
        }
    }

    /// Trace an epoch-gated planner run ([`obs::planner`] code),
    /// carrying the number of gated skips since that planner's previous
    /// run — the epoch-gating effectiveness signal, one event per run
    /// instead of one per skipped tick.
    pub fn trace_planner_run(&mut self, planner: u8) {
        if !self.trace.active() {
            return;
        }
        let cur = if planner == obs::planner::TEMPORAL {
            self.metrics.counters.planner_skips
        } else {
            self.metrics.counters.spatial_plan_skips
        };
        let idx = (planner as usize).min(1);
        let skipped = cur - self.traced_planner_skips[idx];
        self.traced_planner_skips[idx] = cur;
        self.trace.planner_gate(planner, skipped);
    }

    /// Every prefix-cache lifecycle mutation (insert/evict/relocate/
    /// remote seed) lands here: pinned blocks shifted in or out of the
    /// pools are planner input, so both the temporal and spatial epochs
    /// bump (see [`SchedEpochs`]).
    pub fn note_prefix_mutation(&mut self) {
        self.epochs.temporal += 1;
        self.epochs.spatial += 1;
    }

    /// Bump the prefix epochs and publish the event when a cluster
    /// directory is listening.
    pub fn push_prefix_event(&mut self, ev: PrefixEvent) {
        self.note_prefix_mutation();
        match ev {
            PrefixEvent::Inserted { key, blocks, .. } => {
                self.trace.prefix(key.0, obs::prefix::INSERT, blocks)
            }
            PrefixEvent::Relocated { key, .. } => {
                self.trace.prefix(key.0, obs::prefix::DEMOTE, 0)
            }
            PrefixEvent::Removed { key } => {
                self.trace.prefix(key.0, obs::prefix::EVICT, 0)
            }
            PrefixEvent::RemoteHit { key } => {
                self.trace.prefix(key.0, obs::prefix::HIT_REMOTE, 0)
            }
        }
        if self.publish_prefix_events {
            self.prefix_events.push(ev);
        }
    }

    /// Hand the accumulated prefix events to the cluster driver.
    pub fn drain_prefix_events(&mut self) -> Vec<PrefixEvent> {
        std::mem::take(&mut self.prefix_events)
    }

    /// Record one finished function call's observed stall duration
    /// against the request's graph template. Every FC finish lands here
    /// (from `temporal::call_finish` and the cluster's buffered-finish
    /// replay); the observation itself is published only when an
    /// autoscaler is listening — standalone engines pay one counter
    /// bump.
    pub fn note_fc_lifetime(&mut self, rid: RequestId, stall_us: u64) {
        self.metrics.counters.fc_lifetime_obs += 1;
        self.metrics.stall_hist.record(stall_us);
        if self.publish_lifetime_obs {
            let template =
                self.apps.template_of(&self.reqs[&rid].app_id);
            self.fc_lifetime_obs.push((template, stall_us));
        }
    }

    /// Hand the accumulated lifetime observations to the autoscaler.
    pub fn drain_lifetime_obs(&mut self) -> Vec<(usize, u64)> {
        std::mem::take(&mut self.fc_lifetime_obs)
    }

    /// Cancel a request's in-flight prefix H2D debt (preemption): the
    /// destination blocks are about to be freed, so the ledger entry is
    /// retired early and the source entry unpinned. The already-queued
    /// completion event becomes a no-op (`ledger.complete` → None).
    pub fn cancel_prefix_upload(&mut self, rid: RequestId) {
        let Some(x) = self
            .reqs
            .get_mut(&rid)
            .and_then(|r| r.prefix_xfer.take())
        else {
            return;
        };
        if let Some(t) = self.ledger.complete(x) {
            self.trace.transfer_end(x.0, rid.0, false);
            if let TransferKind::PrefixHit { key, pinned: true } = t.kind
            {
                self.prefix.unpin(key);
            }
        }
    }

    /// Offset the app/request id counters. Cluster deployments give every
    /// worker a disjoint id range so requests stay uniquely addressable
    /// after cross-worker migration. Panics if ids were already handed out
    /// past the new base.
    pub fn set_id_base(&mut self, base: u64) {
        assert!(
            self.next_req <= base && self.next_app <= base,
            "id base {base} below already-issued ids"
        );
        self.next_req = base;
        self.next_app = base;
    }

    // ------------------------------------------------------------------
    // Lifecycle index maintenance
    // ------------------------------------------------------------------

    /// Set a request's lifecycle state *and* keep the scheduler indices
    /// (live list, stalled/offloaded sets) consistent. Production code
    /// and tests must route every transition involving
    /// `Stalled`/`Offloaded`/`Finished` through this (or call
    /// [`Self::reindex_request`] after a direct field write); transitions
    /// between unindexed states may write the field directly.
    pub fn set_req_state(&mut self, rid: RequestId, to: ReqState) {
        self.reqs
            .get_mut(&rid)
            .expect("set_req_state: unknown request")
            .state = to;
        self.reindex_request(rid, to);
    }

    /// Re-register `rid` under its (already written) new state. Every
    /// FC-lifecycle transition lands here, so this is also the central
    /// epoch bump for the temporal planner (and the spatial one: the
    /// per-type GPU residency the agent-type score reads shifts too) —
    /// and the central phase-ledger driver: the attribution transition
    /// runs in lockstep with the trace emit, on the same clock stamp.
    pub fn reindex_request(&mut self, rid: RequestId, to: ReqState) {
        self.epochs.temporal += 1;
        self.epochs.spatial += 1;
        let code = state_code(to);
        self.ledger_transition(rid, code);
        self.trace.req_state(rid.0, code);
        self.stalled_ids.remove(&rid);
        self.offloaded_ids.remove(&rid);
        match to {
            ReqState::Stalled => {
                self.stalled_ids.insert(rid);
            }
            ReqState::Offloaded => {
                self.offloaded_ids.insert(rid);
            }
            ReqState::Finished => self.reqs.mark_finished(rid),
            _ => {}
        }
    }

    // ------------------------------------------------------------------
    // Latency-attribution hooks (the only PhaseLedger mutation sites
    // outside `obs/attrib.rs` — CI grep lint)
    // ------------------------------------------------------------------

    /// Drive the request's phase ledger from a traced state code, on
    /// the trace sink's clock stamp — the same instant the matching
    /// `ReqState` record carries, so `analyze --trace` reconstructs
    /// attribution byte-for-byte.
    fn ledger_transition(&mut self, rid: RequestId, code: u8) {
        let now = self.trace.now_us();
        let Some(r) = self.reqs.get_mut(&rid) else { return };
        let already_finished = r.attrib.is_finished();
        let pending = r.prefix_xfer.is_some();
        r.attrib.on_state_code(code, pending, now);
        if code == obs::state::FINISHED && !already_finished {
            let accum = *r.attrib.accum();
            let app_id = r.app_id;
            let template = self.apps.template_of(&app_id);
            let tier = self.qos.tier_of(template).index();
            self.metrics.fold_phase_ledger(&accum, template, tier);
        }
    }

    /// Trace + attribute a transition written directly to the state
    /// field (engine promotion, preemption, spatial admission) — the
    /// sites that historically called `trace.req_state` by hand. Takes
    /// the *traced* code, which may differ from the stored state (a
    /// prefix-gated admission traces `PREFILLING` while the field says
    /// `Running`), so live attribution and trace replay agree.
    pub fn note_direct_transition(&mut self, rid: RequestId, code: u8) {
        self.ledger_transition(rid, code);
        self.trace.req_state(rid.0, code);
    }

    /// The request's pending tool call returned at `at_us` — the
    /// hidden/exposed split point of its stall window. `at_us` may
    /// precede the sink clock when the finish was buffered behind a
    /// mid-wire migration; the mark record carries it so trace replay
    /// splits at the same instant.
    pub fn note_tool_return(&mut self, rid: RequestId, at_us: u64) {
        if let Some(r) = self.reqs.get_mut(&rid) {
            r.attrib.on_tool_return(at_us);
        }
        self.trace.mark(rid.0, obs::mark::FC_RETURN, at_us, 0);
    }

    /// Crash recovery re-queued this request onto a new shard: retag
    /// its just-opened Waiting interval as recompute-after-crash.
    pub fn note_crash_requeue(&mut self, rid: RequestId) {
        let now = self.trace.now_us();
        if let Some(r) = self.reqs.get_mut(&rid) {
            r.attrib.on_crash_requeue(now);
        }
        self.trace.mark(rid.0, obs::mark::CRASH_REQUEUE, 0, 0);
    }

    /// The prefix-hit H2D fetch gating this request landed: an open
    /// `prefix_fetch` interval becomes `prefill`. No trace record —
    /// the `TransferEnd` already in the stream carries the instant.
    pub fn note_prefix_ready(&mut self, rid: RequestId) {
        let now = self.trace.now_us();
        if let Some(r) = self.reqs.get_mut(&rid) {
            r.attrib.on_prefix_ready(now);
        }
    }

    /// Stage the QoS gate wait of the next `spawn_app` call: its root
    /// requests seed the wait into their ledgers' `qos_deferred` phase
    /// (cleared when the spawn completes).
    pub fn stage_qos_wait(&mut self, wait_us: u64) {
        self.qos_wait_pending_us = wait_us;
    }

    /// Lift an application (DAG progress + all of its requests) out of
    /// this state for cross-worker migration. The caller is responsible
    /// for having released or transferred any GPU/CPU blocks the requests
    /// still reference — this method only moves bookkeeping.
    pub fn extract_app(&mut self, app_id: AppId) -> MigratedApp {
        self.epochs.temporal += 1;
        self.epochs.spatial += 1;
        let (app, template) = self
            .apps
            .remove(&app_id)
            .expect("extract_app: unknown app");
        let mut requests: Vec<Request> = Vec::new();
        for rid in app.node_req.iter().flatten() {
            if let Some(r) = self.reqs.remove(rid) {
                self.stalled_ids.remove(rid);
                self.offloaded_ids.remove(rid);
                requests.push(r);
            }
        }
        requests.sort_by_key(|r| r.id);
        self.waiting
            .retain(|rid| !requests.iter().any(|r| r.id == *rid));
        // Live batch membership would mean the app was not quiescent —
        // the migration policy only picks stalled apps, so this is a
        // coordinator bug, not a recoverable condition.
        for r in &requests {
            debug_assert!(
                !self.running.contains(r.id)
                    && !self.prefilling.contains(r.id),
                "extract_app: request {:?} still in the batch",
                r.id
            );
        }
        MigratedApp {
            template,
            app,
            requests,
        }
    }

    /// Install a migrated application into this state. Requests in
    /// `Waiting` state re-enter the waiting queue in id order (arrival
    /// order on the source worker). Block ownership must already point at
    /// this worker's pools.
    pub fn implant_app(&mut self, m: MigratedApp) {
        debug_assert!(
            m.template < self.graphs.len(),
            "implant_app: template {} not registered",
            m.template
        );
        self.epochs.temporal += 1;
        self.epochs.spatial += 1;
        let app_id = m.app.id;
        self.apps.insert(app_id, m.app, m.template);
        for r in m.requests {
            debug_assert!(
                (r.type_id as usize) < self.types.len(),
                "implant_app: unknown agent type {}",
                r.type_id
            );
            let id = r.id;
            let state = r.state;
            self.reqs.insert(id, r);
            match state {
                ReqState::Stalled => {
                    self.stalled_ids.insert(id);
                }
                ReqState::Offloaded => {
                    self.offloaded_ids.insert(id);
                }
                ReqState::Waiting => self.waiting.push_back(id),
                _ => {}
            }
        }
    }

    /// Register an application template; interns its agent types.
    pub fn register_graph(&mut self, g: &AppGraph) -> usize {
        for node in g.nodes() {
            if let NodeKind::Agent(a) = &node.kind {
                self.types.intern(&a.agent_type);
            }
        }
        self.graphs.push(g.clone());
        self.graphs.len() - 1
    }

    pub fn graph_of(&self, app: AppId) -> &AppGraph {
        &self.graphs[self.apps.template_of(&app)]
    }

    /// Create an application instance; roots with zero parents become
    /// ready immediately (agent roots spawn requests into the waiting
    /// queue; standalone func roots are returned for the engine to
    /// schedule as delays).
    pub fn spawn_app(
        &mut self,
        template: usize,
        scales: SampledLengths,
        now_us: u64,
    ) -> (AppId, Vec<NodeId>) {
        let id = AppId(self.next_app);
        self.next_app += 1;
        let g = &self.graphs[template];
        let n = g.len();
        let pending: Vec<u32> =
            (0..n).map(|i| g.in_degree(NodeId(i as u32)) as u32).collect();
        let app = AppInst {
            id,
            arrival_us: now_us,
            pending_parents: pending,
            node_done: vec![false; n],
            nodes_remaining: n as u32,
            scales,
            finished_us: None,
            node_req: vec![None; n],
        };
        self.apps.insert(id, app, template);
        let ready: Vec<NodeId> = self.graphs[template]
            .roots()
            .into_iter()
            .collect();
        let mut func_nodes = Vec::new();
        for node in ready {
            match &self.graphs[template].node(node).kind {
                NodeKind::Agent(_) => {
                    self.spawn_request(id, node, now_us);
                }
                NodeKind::Func(_) => func_nodes.push(node),
            }
        }
        // The staged QoS gate wait applies only to this app's roots —
        // children spawned later (complete_node) never waited in the
        // gate.
        self.qos_wait_pending_us = 0;
        (id, func_nodes)
    }

    /// Create the request for a ready agent node and enqueue it.
    pub fn spawn_request(
        &mut self,
        app_id: AppId,
        node: NodeId,
        now_us: u64,
    ) -> RequestId {
        let template = self.apps.template_of(&app_id);
        let g = &self.graphs[template];
        let spec = match &g.node(node).kind {
            NodeKind::Agent(a) => a.clone(),
            NodeKind::Func(_) => panic!("spawn_request on func node"),
        };
        let scales = self.apps[&app_id].scales;

        // Prompt = shared prefix + own base + inherited parent output.
        let mut inherited = 0u32;
        for &p in g.parents(node) {
            let contrib = match &g.node(p).kind {
                NodeKind::Agent(_) => {
                    let parent_req = self.apps[&app_id].node_req
                        [p.0 as usize]
                        .and_then(|rid| self.reqs.get(&rid));
                    parent_req.map(|r| r.tokens_generated).unwrap_or(0)
                }
                NodeKind::Func(c) => result_tokens(&c.kind),
            };
            inherited += (contrib as f64 * spec.inherit_frac) as u32;
        }
        let prompt_tokens = (spec.shared_prefix
            + scales.scale_prompt(spec.prompt_base)
            + inherited)
            .max(1);

        let phases: Vec<PhaseRt> = spec
            .phases
            .iter()
            .map(|p| PhaseRt {
                gen_tokens: scales.scale_gen(p.gen_tokens),
                call: p.call.clone(),
                result_tokens: p
                    .call
                    .as_ref()
                    .map(|c| result_tokens(&c.kind))
                    .unwrap_or(0),
            })
            .collect();

        let type_id = self.types.intern(&spec.agent_type);
        let id = RequestId(self.next_req);
        self.next_req += 1;
        // An arrival changes waiting demand and the active type set.
        self.epochs.spatial += 1;
        let req = Request {
            id,
            app_id,
            node,
            type_id,
            critical_path: g.is_critical(node),
            static_priority: spec.static_priority,
            f_struct: g.f_struct(node),
            created_us: now_us,
            queue_enter_us: now_us,
            prompt_tokens,
            shared_prefix_tokens: spec.shared_prefix,
            phases,
            cur_phase: 0,
            gen_in_phase: 0,
            context_tokens: prompt_tokens,
            state: ReqState::Waiting,
            blocks: BlockSet::new(),
            reserved_charged: 0,
            cpu_blocks: Vec::new(),
            remaining_prefill: prompt_tokens,
            prefix_xfer: None,
            fc: None,
            offload_evaluated: false,
            migrations: 0,
            preempt_count: 0,
            admit_full: false,
            pulled: false,
            priority: 0.0,
            upload_reserved: BlockSet::new(),
            upload_reserved_charged: 0,
            finished_us: None,
            tokens_generated: 0,
            wait_time_us: 0,
            exec_time_us: 0,
            attrib: crate::obs::attrib::PhaseLedger::open_at(
                self.trace.now_us(),
                self.qos_wait_pending_us,
            ),
        };
        self.apps.get_mut(&app_id).unwrap().node_req[node.0 as usize] =
            Some(id);
        self.reqs.insert(id, req);
        self.waiting.push_back(id);
        // Spawn mark (app/node mapping for critical-path analysis),
        // then the QoS wait if any, then the state record — trace
        // replay re-seeds the ledger in the same order.
        self.trace.mark(id.0, obs::mark::SPAWN, app_id.0, node.0 as u64);
        if self.qos_wait_pending_us > 0 {
            self.trace.mark(
                id.0,
                obs::mark::QOS_WAIT,
                self.qos_wait_pending_us,
                0,
            );
        }
        self.trace.req_state(id.0, obs::state::WAITING);
        id
    }

    /// Mark a node done; returns newly ready agent nodes (spawned
    /// automatically) and func nodes (caller schedules their delay), plus
    /// whether the whole app just completed.
    pub fn complete_node(
        &mut self,
        app_id: AppId,
        node: NodeId,
        now_us: u64,
    ) -> (Vec<NodeId>, bool) {
        let template = self.apps.template_of(&app_id);
        let app = self.apps.get_mut(&app_id).unwrap();
        let ni = node.0 as usize;
        assert!(!app.node_done[ni], "node completed twice");
        app.node_done[ni] = true;
        app.nodes_remaining -= 1;

        let mut ready_funcs = Vec::new();
        let children: Vec<NodeId> =
            self.graphs[template].children(node).to_vec();
        for c in children {
            let app = self.apps.get_mut(&app_id).unwrap();
            app.pending_parents[c.0 as usize] -= 1;
            if app.pending_parents[c.0 as usize] == 0 {
                match &self.graphs[template].node(c).kind {
                    NodeKind::Agent(_) => {
                        self.spawn_request(app_id, c, now_us);
                    }
                    NodeKind::Func(_) => ready_funcs.push(c),
                }
            }
        }

        let app = self.apps.get_mut(&app_id).unwrap();
        let done = app.is_done();
        if done {
            app.finished_us = Some(now_us);
            self.metrics.apps_completed += 1;
            let e2e_us = now_us - app.arrival_us;
            self.metrics.latency.record_us(e2e_us);
            let tier = self.qos.tier_of(template);
            self.metrics.tier_latency[tier.index()].record_us(e2e_us);
        }
        (ready_funcs, done)
    }

    // ------------------------------------------------------------------
    // Pressure snapshot (§3.2)
    // ------------------------------------------------------------------

    /// Blocks a waiting request needs to be admitted right now.
    pub fn admission_demand(&self, r: &Request) -> u32 {
        if r.state == ReqState::Waiting && !r.blocks.is_empty() {
            // Resumed with KV intact: only needs growth for the result.
            let target = r.context_tokens;
            let have = r.blocks.len() * self.cfg.profile.block_tokens;
            self.cfg
                .profile
                .blocks_for_tokens(target.saturating_sub(have))
        } else {
            self.cfg.profile.blocks_for_tokens(r.context_tokens)
        }
    }

    pub fn snapshot(&self) -> PressureSnapshot {
        let mut waiting_demand = 0u32;
        let mut critical_demand = 0u32;
        let mut waiting_count = 0u32;
        for &rid in &self.waiting {
            let r = &self.reqs[&rid];
            let d = self.admission_demand(r);
            waiting_demand += d;
            if self.spatial.critical_types.contains(&r.type_id)
                || r.critical_path
            {
                critical_demand += d;
            }
            waiting_count += 1;
        }
        // The stalled index makes this O(stalled), not O(all requests).
        let mut offloadable_stalled = 0u32;
        for rid in &self.stalled_ids {
            let r = &self.reqs[rid];
            if r.state == ReqState::Stalled {
                offloadable_stalled += r.blocks.len();
            }
        }
        // Parked KV that resumes as demand — O(offloaded) via the index.
        let mut offloaded_blocks = 0u32;
        for rid in &self.offloaded_ids {
            let r = &self.reqs[rid];
            if r.state == ReqState::Offloaded {
                offloaded_blocks += r.cpu_blocks.len() as u32;
            }
        }
        PressureSnapshot {
            gpu_total: self.gpu.total(),
            gpu_free: self.gpu.free_blocks(),
            gpu_pending_free: self.gpu.pending_free_blocks(),
            shared_free: self.gpu.shared_free(),
            reserved_outstanding: self.gpu.outstanding_reserved(),
            cpu_free: self.cpu.free_blocks(),
            waiting_demand,
            critical_demand,
            offloadable_stalled,
            offloaded_blocks,
            upload_debt: self.ledger.inflight_upload_blocks(),
            waiting_count,
            usage: self.gpu.usage(),
        }
    }

    // ------------------------------------------------------------------
    // Per-request priority P_req (Eq. 5)
    // ------------------------------------------------------------------

    /// Synchronization pressure f_sync: at a join point, a lagging branch
    /// is boosted in proportion to how many sibling branches already
    /// completed (prevents the merge node from bottlenecking).
    fn f_sync(&self, r: &Request) -> f64 {
        let g = self.graph_of(r.app_id);
        let app = &self.apps[&r.app_id];
        let mut best: f64 = 0.0;
        for &c in g.children(r.node) {
            let parents = g.parents(c);
            if parents.len() < 2 {
                continue;
            }
            let siblings_done = parents
                .iter()
                .filter(|&&p| p != r.node && app.node_done[p.0 as usize])
                .count();
            let frac =
                siblings_done as f64 / (parents.len() - 1) as f64;
            best = best.max(frac);
        }
        best
    }

    /// Temporal aging f_aging: starvation protection + completion push.
    fn f_aging(&self, r: &Request, now_us: u64) -> f64 {
        let app = &self.apps[&r.app_id];
        let waited = now_us.saturating_sub(r.queue_enter_us) as f64;
        let wait_norm = (waited / 60e6).min(1.0); // saturate at 60 s
        let graph_progress = 1.0 - app.fraction_remaining();
        let completion_pressure = graph_progress * graph_progress;
        0.4 * wait_norm + 0.3 * graph_progress + 0.3 * completion_pressure
    }

    /// Refresh P_req for all live requests (called in step phase 1).
    /// Iterates the arena's live list — O(live), allocation-free — where
    /// the seed collected and walked every request ever created.
    pub fn refresh_priorities(&mut self, now_us: u64) {
        let (a_s, a_y, a_a) = (
            self.cfg.policy.alpha_struct,
            self.cfg.policy.alpha_sync,
            self.cfg.policy.alpha_aging,
        );
        for k in 0..self.reqs.live_len() {
            let slot = self.reqs.live_slot(k);
            let r = self.reqs.slot_ref(slot);
            if r.state == ReqState::Finished {
                continue; // stale live entry (direct state write)
            }
            let fs = r.f_struct;
            let fy = self.f_sync(r);
            let fa = self.f_aging(r, now_us);
            let base = a_s * fs + a_y * fy + a_a * fa;
            // Static priority hints shift the structural term; the
            // preemption ladder guarantees progress under thrash — every
            // eviction raises the victim until it becomes unpreemptable.
            let r = self.reqs.slot_ref(slot);
            let pr = base
                + 0.15 * r.static_priority
                + (0.25 * r.preempt_count as f64).min(5.0);
            self.reqs.slot_mut(slot).priority = pr;
        }
    }

    /// Normalized request importance I ∈ [0,1] for upload ranking (§4.3),
    /// derived from the same priority metric admission uses.
    pub fn importance(&self, r: &Request) -> f64 {
        let crit_boost = if r.critical_path { 0.25 } else { 0.0 };
        (r.priority + crit_boost).clamp(0.0, 1.5) / 1.5
    }

    // ------------------------------------------------------------------
    // Block release helpers
    // ------------------------------------------------------------------

    /// Release all GPU blocks a request holds (eviction or completion).
    /// Deliberately does NOT bump the temporal epoch: preemption storms
    /// would otherwise re-open the planner gate every tick; a
    /// budget-starved upload instead retries on the planner's bounded
    /// backoff (or sooner, via any real temporal event).
    pub fn release_gpu(&mut self, rid: RequestId) {
        let r = self.reqs.get_mut(&rid).unwrap();
        let blocks = r.blocks.take();
        let charged = std::mem::take(&mut r.reserved_charged);
        let t = r.type_id;
        if !blocks.is_empty() || charged > 0 {
            self.gpu.free(blocks, charged, Some(t));
        }
        // Any gradually reserved upload destination is returned too.
        let r = self.reqs.get_mut(&rid).unwrap();
        let ur = r.upload_reserved.take();
        let uc = std::mem::take(&mut r.upload_reserved_charged);
        let t = r.type_id;
        if !ur.is_empty() || uc > 0 {
            self.gpu.free(ur, uc, Some(t));
        }
    }

    /// Release CPU-side blocks (after upload completes or on abandonment).
    pub fn release_cpu(&mut self, rid: RequestId) {
        let r = self.reqs.get_mut(&rid).unwrap();
        let blocks = std::mem::take(&mut r.cpu_blocks);
        if !blocks.is_empty() {
            self.cpu.release(blocks);
        }
    }

    /// Blocks held by requests stalled on function calls — the Fig 2a
    /// "idle KV" measure, including in-flight offloads (still on GPU).
    /// O(live requests) via the arena's live list.
    pub fn stalled_gpu_blocks(&self) -> u32 {
        let mut total = 0u32;
        for k in 0..self.reqs.live_len() {
            let r = self.reqs.live_ref(k);
            if r.state.is_fc_stalled() && r.state.holds_gpu() {
                total += r.blocks.len();
            }
        }
        total
    }

    /// Sample the utilization time-series (engine calls periodically).
    pub fn sample_metrics(&mut self, now_us: u64) {
        self.trace
            .gpu_sample(self.gpu.free_blocks(), self.gpu.total());
        self.sample_metrics_quiet(now_us);
        self.maybe_sample_gauges(now_us);
    }

    /// Fixed-cadence scheduler gauge sampler: batch occupancy by
    /// lifecycle class plus per-tier queue depth, recorded into the
    /// metrics time-series and (when tracing) as a Gauge counter
    /// record. At most one sample per [`GAUGE_CADENCE_US`] boundary —
    /// driven from the same call sites in serial and parallel cluster
    /// modes, so the series and trace stay byte-identical per seed.
    pub fn maybe_sample_gauges(&mut self, now_us: u64) {
        if now_us < self.next_gauge_us {
            return;
        }
        self.next_gauge_us =
            (now_us / GAUGE_CADENCE_US + 1) * GAUGE_CADENCE_US;
        let running = self.running.len() as u32;
        let stalled = self.stalled_ids.len() as u32;
        let offloaded = self.offloaded_ids.len() as u32;
        let mut q = [0u32; crate::qos::TIERS];
        for &rid in &self.waiting {
            let template =
                self.apps.template_of(&self.reqs[&rid].app_id);
            q[self.qos.tier_of(template).index()] += 1;
        }
        self.trace
            .gauge(running, stalled, offloaded, q[0], q[1], q[2]);
        self.metrics.sched_running.record(now_us, running as f64);
        self.metrics.sched_stalled.record(now_us, stalled as f64);
        self.metrics
            .sched_offloaded
            .record(now_us, offloaded as f64);
        for (i, depth) in q.iter().enumerate() {
            self.metrics.queue_depth[i].record(now_us, *depth as f64);
        }
    }

    /// Closing sample at finalize time: records the utilization series
    /// without a trace event — a retired shard's timeline is embargoed
    /// after its `retire` record, and the end-of-run bookkeeping sample
    /// must not violate that.
    pub fn sample_metrics_quiet(&mut self, now_us: u64) {
        let total = self.gpu.total().max(1) as f64;
        let used = (self.gpu.total() - self.gpu.free_blocks()) as f64;
        let stalled = self.stalled_gpu_blocks() as f64
            + self.gpu.pending_free_blocks() as f64;
        self.metrics.gpu_usage.record(now_us, used / total);
        self.metrics
            .stalled_fraction
            .record(now_us, stalled / total);
        self.metrics
            .effective_usage
            .record(now_us, (used - stalled).max(0.0) / total);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::templates;

    fn setup() -> (ServeState, usize) {
        let mut st = ServeState::new(ServeConfig::default());
        let g = templates::code_writer();
        let t = st.register_graph(&g);
        (st, t)
    }

    fn scales() -> SampledLengths {
        SampledLengths {
            prompt_scale: 1.0,
            gen_scale: 1.0,
        }
    }

    #[test]
    fn spawn_app_enqueues_roots() {
        let (mut st, t) = setup();
        let (app, funcs) = st.spawn_app(t, scales(), 0);
        assert!(funcs.is_empty());
        assert_eq!(st.waiting.len(), 1); // planner is the single root
        let rid = *st.waiting.front().unwrap();
        let r = &st.reqs[&rid];
        assert_eq!(r.app_id, app);
        assert!(r.prompt_tokens > 0);
        assert_eq!(r.state, ReqState::Waiting);
    }

    #[test]
    fn complete_node_unlocks_children_with_inherited_prompt() {
        let (mut st, t) = setup();
        let (app, _) = st.spawn_app(t, scales(), 0);
        let root = st.graphs[t].roots()[0];
        // Simulate the root generating 180 tokens then finishing.
        let rid = st.apps[&app].node_req[root.0 as usize].unwrap();
        st.reqs.get_mut(&rid).unwrap().tokens_generated = 180;
        st.set_req_state(rid, ReqState::Finished);
        let before = st.waiting.len();
        let (funcs, done) = st.complete_node(app, root, 1000);
        assert!(funcs.is_empty());
        assert!(!done);
        assert_eq!(st.waiting.len(), before + 1); // architect ready
        let arch_req = st
            .waiting
            .back()
            .map(|rid| &st.reqs[rid])
            .unwrap();
        // Inherited = 180 * 0.5 = 90 extra prompt tokens.
        assert!(arch_req.prompt_tokens >= 90);
    }

    #[test]
    fn app_completes_when_all_nodes_done() {
        let mut st = ServeState::new(ServeConfig::default());
        let g = templates::rag();
        let t = st.register_graph(&g);
        let (app, _) = st.spawn_app(t, scales(), 0);
        let order: Vec<NodeId> = st.graphs[t].topo_order().to_vec();
        let mut done = false;
        for n in order {
            let (_, d) = st.complete_node(app, n, 500);
            done = d;
        }
        assert!(done);
        assert_eq!(st.metrics.apps_completed, 1);
        assert_eq!(st.apps[&app].finished_us, Some(500));
    }

    #[test]
    fn snapshot_counts_waiting_demand() {
        let (mut st, t) = setup();
        st.spawn_app(t, scales(), 0);
        let snap = st.snapshot();
        assert!(snap.waiting_demand > 0);
        assert_eq!(snap.waiting_count, 1);
    }

    #[test]
    fn priorities_increase_with_waiting() {
        let (mut st, t) = setup();
        st.spawn_app(t, scales(), 0);
        st.refresh_priorities(0);
        let rid = *st.waiting.front().unwrap();
        let p0 = st.reqs[&rid].priority;
        st.refresh_priorities(30_000_000); // 30 s later
        let p1 = st.reqs[&rid].priority;
        assert!(p1 > p0, "aging must raise priority: {p0} -> {p1}");
    }

    #[test]
    fn lifecycle_indices_follow_state() {
        let (mut st, t) = setup();
        st.spawn_app(t, scales(), 0);
        let rid = *st.waiting.front().unwrap();
        st.set_req_state(rid, ReqState::Stalled);
        assert!(st.stalled_ids.contains(&rid));
        st.set_req_state(rid, ReqState::Offloaded);
        assert!(!st.stalled_ids.contains(&rid));
        assert!(st.offloaded_ids.contains(&rid));
        st.set_req_state(rid, ReqState::Finished);
        assert!(st.offloaded_ids.is_empty());
        assert_eq!(st.reqs.live_len(), 0);
        assert_eq!(st.reqs.len(), 1);
    }

    #[test]
    fn extract_implant_roundtrip_keeps_indices() {
        let (mut st, t) = setup();
        let (app, _) = st.spawn_app(t, scales(), 0);
        let rid = *st.waiting.front().unwrap();
        st.waiting.retain(|&x| x != rid);
        st.set_req_state(rid, ReqState::Stalled);
        let m = st.extract_app(app);
        assert!(st.stalled_ids.is_empty());
        assert!(st.reqs.get(&rid).is_none());
        st.implant_app(m);
        assert!(st.stalled_ids.contains(&rid));
        assert_eq!(st.reqs[&rid].state, ReqState::Stalled);
    }

    #[test]
    fn throughput_estimator_ewma() {
        let mut t = ThroughputEstimator::default();
        t.record_iteration(100, 100_000); // 1000 tok/s
        assert!((t.tokens_per_sec() - 1000.0).abs() < 1e-6);
        t.record_iteration(0, 100_000);
        assert!(t.tokens_per_sec() < 1000.0);
        assert!(t.tokens_per_sec() > 1.0);
    }

    #[test]
    fn type_registry_interning() {
        let mut tr = TypeRegistry::default();
        let a = tr.intern("programmer");
        let b = tr.intern("programmer");
        let c = tr.intern("reviewer");
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(tr.name(a), "programmer");
        tr.note_preempt(a);
        tr.note_wait(c);
        assert_eq!(tr.preempts[a as usize], 1.0);
        tr.decay(0.5);
        assert_eq!(tr.preempts[a as usize], 0.5);
    }
}
