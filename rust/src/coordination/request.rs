//! Request and application-instance lifecycle state.
//!
//! A *request* is one agent-node execution: prefill its prompt, then
//! alternate generation phases and function calls (`LLM1 → FC → LLM2`,
//! Fig 2b), all against one growing KV cache. An *application instance*
//! tracks a DAG of such requests plus standalone function nodes.

use crate::graph::{CallSpec, FuncKind, NodeId};
use crate::kvcache::{AgentTypeId, BlockSet, CpuBlockId, TransferId};
use crate::obs::attrib::PhaseLedger;
use crate::workload::SampledLengths;

/// Unique request id.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

/// Unique application-instance id.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AppId(pub u64);

/// Request lifecycle. The function-call sub-states are exactly the
/// MCPManager's five states (§6.2): running, pending-offload, offloaded,
/// pending-upload, uploaded — plus the queue states around them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReqState {
    /// In the waiting queue (new, preempted-for-recompute, or resumed).
    Waiting,
    /// Admitted; prompt prefill in progress (chunked).
    Prefilling,
    /// In the decode batch, generating.
    Running,
    /// Function call in flight; KV cache resident on GPU.
    Stalled,
    /// Function call in flight; D2H offload transfer in progress.
    PendingOffload,
    /// KV cache on CPU (function call may or may not have finished).
    Offloaded,
    /// H2D upload transfer in progress.
    PendingUpload,
    /// KV cache back on GPU after upload; awaiting tool finish and/or
    /// re-admission to the batch.
    Uploaded,
    /// All phases complete.
    Finished,
}

impl ReqState {
    /// Is the request currently stalled on a function call (any residency)?
    pub fn is_fc_stalled(&self) -> bool {
        matches!(
            self,
            ReqState::Stalled
                | ReqState::PendingOffload
                | ReqState::Offloaded
                | ReqState::PendingUpload
                | ReqState::Uploaded
        )
    }

    /// Does the request occupy GPU blocks in this state?
    pub fn holds_gpu(&self) -> bool {
        matches!(
            self,
            ReqState::Prefilling
                | ReqState::Running
                | ReqState::Stalled
                | ReqState::Uploaded
        )
    }
}

/// One generation phase at runtime (token counts already corpus-scaled).
#[derive(Debug, Clone)]
pub struct PhaseRt {
    pub gen_tokens: u32,
    pub call: Option<CallSpec>,
    /// Tokens the tool's result appends to the context before the next
    /// phase (drives post-FC block growth — the resume contention source).
    pub result_tokens: u32,
}

/// In-flight function call bookkeeping.
#[derive(Debug, Clone)]
pub struct FcRt {
    /// Function type name (forecasting model key, §4.1).
    pub name: String,
    pub started_us: u64,
    /// The Temporal Scheduler's prediction of completion (Eq. 1 based).
    pub predicted_end_us: u64,
    /// Set true by the call_finish event.
    pub tool_done: bool,
    /// When the tool actually finished (valid once `tool_done`).
    pub finished_us: u64,
    pub result_tokens: u32,
    /// User-supplied estimate carried for forecaster feedback.
    pub user_estimate_us: Option<u64>,
}

/// Result size each tool kind appends to the agent's context.
pub fn result_tokens(kind: &FuncKind) -> u32 {
    match kind {
        FuncKind::FileRead => 320,
        FuncKind::FileWrite => 48,
        FuncKind::WebSearch => 480,
        FuncKind::FileQuery => 256,
        FuncKind::DataAnalysis => 384,
        FuncKind::UserConfirm => 32,
        FuncKind::ExternalTest => 320,
        FuncKind::Git => 96,
        FuncKind::Database => 256,
        FuncKind::AiGeneration => 512,
        FuncKind::Custom { .. } => 128,
    }
}

/// One agent-node execution.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub app_id: AppId,
    pub node: NodeId,
    pub type_id: AgentTypeId,
    pub critical_path: bool,
    pub static_priority: f64,
    /// Structural importance from the DAG (cached at creation).
    pub f_struct: f64,
    /// When the node's dependencies were satisfied.
    pub created_us: u64,
    /// Last time the request (re-)entered the waiting queue.
    pub queue_enter_us: u64,
    /// Prompt length (incl. inherited parent output and shared prefix).
    pub prompt_tokens: u32,
    /// Shared-prefix tokens eligible for prefix-cache reuse.
    pub shared_prefix_tokens: u32,
    pub phases: Vec<PhaseRt>,
    pub cur_phase: usize,
    pub gen_in_phase: u32,
    /// Tokens currently represented in the KV cache.
    pub context_tokens: u32,
    pub state: ReqState,
    /// GPU blocks held (valid when `state.holds_gpu()` or pending
    /// offload), as coalesced extents.
    pub blocks: BlockSet,
    /// How many of `blocks` are charged against the type's reserved quota.
    pub reserved_charged: u32,
    /// CPU blocks holding the offloaded cache.
    pub cpu_blocks: Vec<CpuBlockId>,
    /// Prefill tokens still owed before decode can start.
    pub remaining_prefill: u32,
    /// In-flight H2D debt from a CPU/remote prefix hit: the saved
    /// prefill is only real once the cached blocks land, so the engine
    /// executes nothing for this request until the transfer completes
    /// (cleared by `temporal::on_transfer_done`, cancelled on preempt).
    pub prefix_xfer: Option<TransferId>,
    pub fc: Option<FcRt>,
    /// Has the opportunistic gate already ruled on this stall? (The gate
    /// evaluates *newly* stalled requests once per function call, §3.2.)
    pub offload_evaluated: bool,
    /// Completed offload+upload round trips (churn signal for the gate).
    pub migrations: u32,
    pub preempt_count: u32,
    /// Set after a self-preemption: this request already hit the growth
    /// wall once, so re-admission must reserve its full worst-case need
    /// (prevents admit→grow→fail→self-preempt cycles).
    pub admit_full: bool,
    /// Selected as an offload beneficiary (§4.2): admission considers it
    /// first so the freed blocks become scheduled work. Cleared on admit.
    pub pulled: bool,
    /// Refreshed per-request priority P_req (Eq. 5).
    pub priority: f64,
    /// Blocks gradually pre-reserved for the predictive upload (Eq. 4).
    pub upload_reserved: BlockSet,
    pub upload_reserved_charged: u32,
    pub finished_us: Option<u64>,
    pub tokens_generated: u32,
    /// Cumulative time spent waiting in queue (µs).
    pub wait_time_us: u64,
    /// Total execution time spent running/prefilling (µs) — H_a input.
    pub exec_time_us: u64,
    /// Latency-attribution phase ledger (`obs::attrib`). Lives on the
    /// request so migration and crash requeue carry it along; mutated
    /// only through `ServeState` hooks (CI grep lint).
    pub attrib: PhaseLedger,
}

impl Request {
    /// Total tokens this request will generate across all phases.
    pub fn total_gen_target(&self) -> u32 {
        self.phases.iter().map(|p| p.gen_tokens).sum()
    }

    /// Completion fraction (0 at start, → 1 near finish).
    pub fn progress(&self) -> f64 {
        let t = self.total_gen_target();
        if t == 0 {
            return 1.0;
        }
        self.tokens_generated as f64 / t as f64
    }

    /// Tokens the context will hold when fully resumed (for upload sizing).
    pub fn blocks_held(&self) -> u32 {
        self.blocks.len()
    }

    /// Does the current phase end with a function call?
    pub fn current_call(&self) -> Option<&CallSpec> {
        self.phases.get(self.cur_phase)?.call.as_ref()
    }

    /// Is this the last phase?
    pub fn on_last_phase(&self) -> bool {
        self.cur_phase + 1 >= self.phases.len()
    }
}

/// A live application instance: DAG progress tracking.
#[derive(Debug, Clone)]
pub struct AppInst {
    pub id: AppId,
    pub arrival_us: u64,
    /// Unsatisfied parent count per node.
    pub pending_parents: Vec<u32>,
    pub node_done: Vec<bool>,
    pub nodes_remaining: u32,
    pub scales: SampledLengths,
    pub finished_us: Option<u64>,
    /// Request spawned per node (None for standalone func nodes or
    /// not-yet-ready nodes).
    pub node_req: Vec<Option<RequestId>>,
}

impl AppInst {
    /// Fraction of the graph still unfinished (f_aging input).
    pub fn fraction_remaining(&self) -> f64 {
        if self.node_done.is_empty() {
            return 0.0;
        }
        self.nodes_remaining as f64 / self.node_done.len() as f64
    }

    pub fn is_done(&self) -> bool {
        self.nodes_remaining == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_request() -> Request {
        Request {
            id: RequestId(1),
            app_id: AppId(1),
            node: NodeId(0),
            type_id: 0,
            critical_path: false,
            static_priority: 0.5,
            f_struct: 0.5,
            created_us: 0,
            queue_enter_us: 0,
            prompt_tokens: 100,
            shared_prefix_tokens: 0,
            phases: vec![
                PhaseRt {
                    gen_tokens: 50,
                    call: Some(CallSpec::new(FuncKind::Git)),
                    result_tokens: 96,
                },
                PhaseRt {
                    gen_tokens: 30,
                    call: None,
                    result_tokens: 0,
                },
            ],
            cur_phase: 0,
            gen_in_phase: 0,
            context_tokens: 100,
            state: ReqState::Waiting,
            blocks: BlockSet::new(),
            reserved_charged: 0,
            cpu_blocks: Vec::new(),
            remaining_prefill: 100,
            prefix_xfer: None,
            fc: None,
            offload_evaluated: false,
            migrations: 0,
            preempt_count: 0,
            admit_full: false,
            pulled: false,
            priority: 0.0,
            upload_reserved: BlockSet::new(),
            upload_reserved_charged: 0,
            finished_us: None,
            tokens_generated: 0,
            wait_time_us: 0,
            exec_time_us: 0,
            attrib: PhaseLedger::default(),
        }
    }

    #[test]
    fn progress_and_targets() {
        let mut r = mk_request();
        assert_eq!(r.total_gen_target(), 80);
        assert_eq!(r.progress(), 0.0);
        r.tokens_generated = 40;
        assert!((r.progress() - 0.5).abs() < 1e-9);
        assert!(r.current_call().is_some());
        assert!(!r.on_last_phase());
        r.cur_phase = 1;
        assert!(r.on_last_phase());
        assert!(r.current_call().is_none());
    }

    #[test]
    fn state_predicates() {
        assert!(ReqState::Stalled.is_fc_stalled());
        assert!(ReqState::Offloaded.is_fc_stalled());
        assert!(!ReqState::Running.is_fc_stalled());
        assert!(ReqState::Running.holds_gpu());
        assert!(ReqState::Stalled.holds_gpu());
        assert!(!ReqState::Offloaded.holds_gpu());
        assert!(!ReqState::PendingOffload.holds_gpu(), "pending-free");
    }

    #[test]
    fn result_tokens_cover_all_kinds() {
        for k in [
            FuncKind::FileRead,
            FuncKind::WebSearch,
            FuncKind::AiGeneration,
        ] {
            assert!(result_tokens(&k) > 0);
        }
    }
}
