//! Dense arenas and O(1) membership structures for the serving hot path.
//!
//! The scheduler loop runs millions of times per simulated run; the seed
//! implementation kept requests and app instances in `HashMap`s, which
//! meant (a) SipHash on every id lookup, (b) nondeterministic iteration
//! order that every scan had to sort away, and (c) per-tick scans over
//! every request that *ever* existed (finished ones included). The types
//! here make the loop deterministic by construction instead:
//!
//! * [`RequestArena`] / [`AppArena`] — slab storage with an
//!   identity-hash id index. Iteration order is insertion order, which is
//!   itself deterministic, so no scan needs a defensive sort.
//! * The request arena additionally maintains a **live list** (slots of
//!   non-finished requests) so per-tick scans are O(live), not
//!   O(all-requests-ever).
//! * [`BatchQueue`] — the running/prefilling batch membership structure:
//!   O(1) insert/remove/contains with *order-preserving* iteration
//!   (tombstones + amortized compaction), replacing the
//!   `Vec::retain(|&x| x != victim)` pattern on every preemption, stall,
//!   and completion.

use std::hash::{BuildHasherDefault, Hasher};

use super::request::{AppInst, AppId, ReqState, Request, RequestId};

/// Identity-style hasher for internal u64 ids (request/app ids). The ids
/// are engine-generated (sequential per shard), so there is nothing to
/// defend against and SipHash is pure overhead; a single multiply by a
/// large odd constant spreads the shard-base high bits well enough.
#[derive(Debug, Clone, Copy, Default)]
pub struct IdHasher(u64);

impl Hasher for IdHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        // Generic fallback (derived Hash on newtypes uses write_u64).
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x100_0000_01B3);
        }
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.0 = v.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    }
}

/// HashMap keyed by a raw u64 id with the identity hasher.
pub type IdMap<V> = std::collections::HashMap<u64, V, BuildHasherDefault<IdHasher>>;

const NOT_LIVE: u32 = u32::MAX;

/// Dense slab of [`Request`]s with an id index and a live (non-finished)
/// slot list. Finished requests stay resident — child prompt inheritance
/// reads the parent's `tokens_generated` at spawn time — but the hot-path
/// scans iterate only the live list.
#[derive(Debug, Clone, Default)]
pub struct RequestArena {
    slots: Vec<Request>,
    /// id.0 → slot.
    index: IdMap<u32>,
    /// Slots of non-finished requests (deterministic order; `swap_remove`
    /// on finish/extract).
    live: Vec<u32>,
    /// slot → position in `live` (NOT_LIVE when finished / absent).
    live_pos: Vec<u32>,
}

impl RequestArena {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn contains(&self, id: &RequestId) -> bool {
        self.index.contains_key(&id.0)
    }

    pub fn get(&self, id: &RequestId) -> Option<&Request> {
        self.index
            .get(&id.0)
            .map(|&slot| &self.slots[slot as usize])
    }

    pub fn get_mut(&mut self, id: &RequestId) -> Option<&mut Request> {
        match self.index.get(&id.0) {
            Some(&slot) => Some(&mut self.slots[slot as usize]),
            None => None,
        }
    }

    /// Insert a request under its own id. Joins the live list unless it
    /// arrives already `Finished` (migrated-app payloads carry those).
    pub fn insert(&mut self, id: RequestId, req: Request) {
        debug_assert_eq!(id, req.id, "arena insert under foreign id");
        debug_assert!(
            !self.index.contains_key(&id.0),
            "duplicate request id {id:?}"
        );
        let slot = self.slots.len() as u32;
        let is_live = req.state != ReqState::Finished;
        self.slots.push(req);
        self.index.insert(id.0, slot);
        if is_live {
            self.live_pos.push(self.live.len() as u32);
            self.live.push(slot);
        } else {
            self.live_pos.push(NOT_LIVE);
        }
    }

    /// Remove a request (cross-worker migration). The last slot is moved
    /// into the vacated one; all bookkeeping follows.
    pub fn remove(&mut self, id: &RequestId) -> Option<Request> {
        let slot = self.index.remove(&id.0)? as usize;
        self.unlive(slot as u32);
        let req = self.slots.swap_remove(slot);
        // Keep live_pos parallel to slots.
        self.live_pos.swap_remove(slot);
        if slot < self.slots.len() {
            // The request formerly in the last slot now lives at `slot`.
            let moved_id = self.slots[slot].id;
            self.index.insert(moved_id.0, slot as u32);
            let lp = self.live_pos[slot];
            if lp != NOT_LIVE {
                self.live[lp as usize] = slot as u32;
            }
        }
        Some(req)
    }

    /// Drop a request from the live list (state reached `Finished`).
    /// Idempotent; the request itself stays resident.
    pub fn mark_finished(&mut self, id: RequestId) {
        if let Some(&slot) = self.index.get(&id.0) {
            self.unlive(slot);
        }
    }

    fn unlive(&mut self, slot: u32) {
        let pos = self.live_pos[slot as usize];
        if pos == NOT_LIVE {
            return;
        }
        let pos = pos as usize;
        self.live.swap_remove(pos);
        if pos < self.live.len() {
            let moved_slot = self.live[pos] as usize;
            self.live_pos[moved_slot] = pos as u32;
        }
        self.live_pos[slot as usize] = NOT_LIVE;
    }

    /// Number of live (non-finished) requests.
    pub fn live_len(&self) -> usize {
        self.live.len()
    }

    /// Slot number of the k-th live request (for split-borrow loops).
    pub fn live_slot(&self, k: usize) -> u32 {
        self.live[k]
    }

    /// The k-th live request.
    pub fn live_ref(&self, k: usize) -> &Request {
        &self.slots[self.live[k] as usize]
    }

    /// Direct slot access (pair with [`Self::live_slot`]).
    pub fn slot_ref(&self, slot: u32) -> &Request {
        &self.slots[slot as usize]
    }

    pub fn slot_mut(&mut self, slot: u32) -> &mut Request {
        &mut self.slots[slot as usize]
    }

    /// All requests, finished included, in deterministic insertion order.
    pub fn values(&self) -> impl Iterator<Item = &Request> {
        self.slots.iter()
    }

    pub fn values_mut(&mut self) -> impl Iterator<Item = &mut Request> {
        self.slots.iter_mut()
    }
}

impl std::ops::Index<&RequestId> for RequestArena {
    type Output = Request;

    fn index(&self, id: &RequestId) -> &Request {
        self.get(id).expect("unknown request id")
    }
}

/// Dense slab of application instances plus their graph-template index
/// (subsumes the seed's separate `app_template` map).
#[derive(Debug, Clone, Default)]
pub struct AppArena {
    slots: Vec<(AppInst, usize)>,
    index: IdMap<u32>,
}

impl AppArena {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn contains(&self, id: &AppId) -> bool {
        self.index.contains_key(&id.0)
    }

    pub fn get(&self, id: &AppId) -> Option<&AppInst> {
        self.index.get(&id.0).map(|&s| &self.slots[s as usize].0)
    }

    pub fn get_mut(&mut self, id: &AppId) -> Option<&mut AppInst> {
        match self.index.get(&id.0) {
            Some(&s) => Some(&mut self.slots[s as usize].0),
            None => None,
        }
    }

    /// Graph template index of an app (panics if unknown).
    pub fn template_of(&self, id: &AppId) -> usize {
        let slot = self.index.get(&id.0).expect("unknown app id");
        self.slots[*slot as usize].1
    }

    pub fn insert(&mut self, id: AppId, app: AppInst, template: usize) {
        debug_assert_eq!(id, app.id, "arena insert under foreign id");
        debug_assert!(
            !self.index.contains_key(&id.0),
            "duplicate app id {id:?}"
        );
        let slot = self.slots.len() as u32;
        self.slots.push((app, template));
        self.index.insert(id.0, slot);
    }

    /// Remove an app (cross-worker migration); returns `(inst, template)`.
    pub fn remove(&mut self, id: &AppId) -> Option<(AppInst, usize)> {
        let slot = self.index.remove(&id.0)? as usize;
        let entry = self.slots.swap_remove(slot);
        if slot < self.slots.len() {
            let moved_id = self.slots[slot].0.id;
            self.index.insert(moved_id.0, slot as u32);
        }
        Some(entry)
    }

    /// App ids in deterministic insertion order.
    pub fn ids(&self) -> impl Iterator<Item = AppId> + '_ {
        self.slots.iter().map(|(a, _)| a.id)
    }

    /// All app instances in deterministic insertion order.
    pub fn values(&self) -> impl Iterator<Item = &AppInst> {
        self.slots.iter().map(|(a, _)| a)
    }
}

impl std::ops::Index<&AppId> for AppArena {
    type Output = AppInst;

    fn index(&self, id: &AppId) -> &AppInst {
        self.get(id).expect("unknown app id")
    }
}

/// Batch membership (the engine's `running` / `prefilling` queues):
/// O(1) push / remove / contains with order-preserving iteration.
///
/// Removal tombstones the slot instead of shifting (so the decode order
/// every other request observes is unchanged — a `swap_remove` would
/// reorder the batch and perturb scheduling); compaction runs amortized
/// when tombstones outnumber live entries.
#[derive(Debug, Clone, Default)]
pub struct BatchQueue {
    slots: Vec<Option<RequestId>>,
    /// id.0 → slot.
    pos: IdMap<u32>,
    live: usize,
}

impl BatchQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    pub fn contains(&self, rid: RequestId) -> bool {
        self.pos.contains_key(&rid.0)
    }

    pub fn push(&mut self, rid: RequestId) {
        debug_assert!(!self.contains(rid), "batch double-insert {rid:?}");
        self.pos.insert(rid.0, self.slots.len() as u32);
        self.slots.push(Some(rid));
        self.live += 1;
    }

    pub fn extend<I: IntoIterator<Item = RequestId>>(&mut self, it: I) {
        for rid in it {
            self.push(rid);
        }
    }

    /// Remove by id; true if the request was present.
    pub fn remove(&mut self, rid: RequestId) -> bool {
        let Some(slot) = self.pos.remove(&rid.0) else {
            return false;
        };
        self.slots[slot as usize] = None;
        self.live -= 1;
        if self.slots.len() >= 16 && self.live * 2 < self.slots.len() {
            self.compact();
        }
        true
    }

    fn compact(&mut self) {
        self.slots.retain(|s| s.is_some());
        self.pos.clear();
        for (i, s) in self.slots.iter().enumerate() {
            self.pos.insert(s.unwrap().0, i as u32);
        }
    }

    pub fn clear(&mut self) {
        self.slots.clear();
        self.pos.clear();
        self.live = 0;
    }

    /// Live entries in insertion order.
    pub fn iter(&self) -> impl Iterator<Item = RequestId> + '_ {
        self.slots.iter().filter_map(|s| *s)
    }

    /// The k-th live entry (linear; tests / cold paths only).
    pub fn get(&self, k: usize) -> Option<RequestId> {
        self.iter().nth(k)
    }

    /// Raw slot count including tombstones (for index loops that must not
    /// hold a borrow across mutation of other fields).
    pub fn raw_len(&self) -> usize {
        self.slots.len()
    }

    /// Raw slot access; `None` marks a tombstone.
    pub fn raw_get(&self, i: usize) -> Option<RequestId> {
        self.slots[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::NodeId;

    fn req(id: u64) -> Request {
        Request {
            id: RequestId(id),
            app_id: AppId(0),
            node: NodeId(0),
            type_id: 0,
            critical_path: false,
            static_priority: 0.0,
            f_struct: 0.0,
            created_us: 0,
            queue_enter_us: 0,
            prompt_tokens: 1,
            shared_prefix_tokens: 0,
            phases: Vec::new(),
            cur_phase: 0,
            gen_in_phase: 0,
            context_tokens: 1,
            state: ReqState::Waiting,
            blocks: Default::default(),
            reserved_charged: 0,
            cpu_blocks: Vec::new(),
            remaining_prefill: 1,
            prefix_xfer: None,
            fc: None,
            offload_evaluated: false,
            migrations: 0,
            preempt_count: 0,
            admit_full: false,
            pulled: false,
            priority: 0.0,
            upload_reserved: Default::default(),
            upload_reserved_charged: 0,
            finished_us: None,
            tokens_generated: 0,
            wait_time_us: 0,
            exec_time_us: 0,
            attrib: Default::default(),
        }
    }

    #[test]
    fn arena_insert_lookup_remove() {
        let mut a = RequestArena::new();
        for i in 0..5u64 {
            a.insert(RequestId(i), req(i));
        }
        assert_eq!(a.len(), 5);
        assert_eq!(a.live_len(), 5);
        assert_eq!(a[&RequestId(3)].id, RequestId(3));
        let r = a.remove(&RequestId(1)).unwrap();
        assert_eq!(r.id, RequestId(1));
        assert!(a.get(&RequestId(1)).is_none());
        assert_eq!(a.len(), 4);
        assert_eq!(a.live_len(), 4);
        // The moved (formerly last) request is still addressable.
        assert_eq!(a[&RequestId(4)].id, RequestId(4));
    }

    #[test]
    fn arena_live_list_tracks_finished() {
        let mut a = RequestArena::new();
        for i in 0..4u64 {
            a.insert(RequestId(i), req(i));
        }
        a.get_mut(&RequestId(2)).unwrap().state = ReqState::Finished;
        a.mark_finished(RequestId(2));
        a.mark_finished(RequestId(2)); // idempotent
        assert_eq!(a.live_len(), 3);
        let live: Vec<u64> =
            (0..a.live_len()).map(|k| a.live_ref(k).id.0).collect();
        assert!(!live.contains(&2));
        assert_eq!(live.len(), 3);
        // Removing a finished request keeps live bookkeeping consistent.
        a.remove(&RequestId(2));
        assert_eq!(a.live_len(), 3);
        assert_eq!(a.len(), 3);
        // Inserting an already-finished request skips the live list.
        let mut f = req(9);
        f.state = ReqState::Finished;
        a.insert(RequestId(9), f);
        assert_eq!(a.live_len(), 3);
        assert_eq!(a.len(), 4);
    }

    #[test]
    fn arena_remove_fixes_moved_live_slot() {
        let mut a = RequestArena::new();
        for i in 0..6u64 {
            a.insert(RequestId(i), req(i));
        }
        // Remove a middle element: the last slot (id 5) moves into it.
        a.remove(&RequestId(2));
        // Every remaining live entry must resolve to the right request.
        let mut seen: Vec<u64> =
            (0..a.live_len()).map(|k| a.live_ref(k).id.0).collect();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 3, 4, 5]);
        for &i in &[0u64, 1, 3, 4, 5] {
            assert_eq!(a[&RequestId(i)].id.0, i);
        }
    }

    #[test]
    fn batch_queue_preserves_order_across_removal() {
        let mut q = BatchQueue::new();
        for i in 0..6u64 {
            q.push(RequestId(i));
        }
        assert!(q.remove(RequestId(2)));
        assert!(!q.remove(RequestId(2)));
        assert!(q.remove(RequestId(4)));
        let order: Vec<u64> = q.iter().map(|r| r.0).collect();
        assert_eq!(order, vec![0, 1, 3, 5]);
        assert_eq!(q.len(), 4);
        assert!(q.contains(RequestId(3)));
        assert!(!q.contains(RequestId(4)));
        assert_eq!(q.get(2), Some(RequestId(3)));
    }

    #[test]
    fn batch_queue_compacts_without_reordering() {
        let mut q = BatchQueue::new();
        for i in 0..64u64 {
            q.push(RequestId(i));
        }
        for i in 0..48u64 {
            q.remove(RequestId(i));
        }
        // Compaction must have fired (raw length shrunk) and preserved
        // both order and addressability.
        assert!(q.raw_len() < 64);
        let order: Vec<u64> = q.iter().map(|r| r.0).collect();
        assert_eq!(order, (48..64).collect::<Vec<u64>>());
        for i in 48..64u64 {
            assert!(q.contains(RequestId(i)));
        }
        q.push(RequestId(100));
        assert_eq!(q.iter().last(), Some(RequestId(100)));
    }

    #[test]
    fn app_arena_roundtrip() {
        let mut a = AppArena::new();
        let inst = |i: u64| AppInst {
            id: AppId(i),
            arrival_us: 0,
            pending_parents: Vec::new(),
            node_done: Vec::new(),
            nodes_remaining: 0,
            scales: crate::workload::SampledLengths {
                prompt_scale: 1.0,
                gen_scale: 1.0,
            },
            finished_us: None,
            node_req: Vec::new(),
        };
        a.insert(AppId(7), inst(7), 0);
        a.insert(AppId(9), inst(9), 3);
        assert_eq!(a.template_of(&AppId(9)), 3);
        assert_eq!(a[&AppId(7)].id, AppId(7));
        let ids: Vec<AppId> = a.ids().collect();
        assert_eq!(ids, vec![AppId(7), AppId(9)]);
        let (inst7, t7) = a.remove(&AppId(7)).unwrap();
        assert_eq!((inst7.id, t7), (AppId(7), 0));
        assert_eq!(a.len(), 1);
        assert_eq!(a[&AppId(9)].id, AppId(9));
    }
}
