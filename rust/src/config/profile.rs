//! Hardware/model calibration profiles.
//!
//! The paper evaluates Qwen2.5-14B on A100-80GB, Qwen2.5-32B on H20-96GB,
//! and Qwen2.5-72B on 2×H20 (TP=2). We have no GPUs, so each profile
//! captures the *rates* that drive the discrete-event engine. Where the
//! paper publishes a number we calibrate to it directly:
//!
//! * Fig 17 (A100 PCIe, 14B): 256 blocks offload in 32.0 ms / upload in
//!   31.7 ms → ≈125 µs/block each way; recomputing 4096 tokens takes
//!   1815 ms → ≈443 µs/token prefill; 16 tokens/block, 3 MiB/block bf16.
//! * §7.1: 100 GB of CPU memory reserved as the offload destination.
//!
//! Decode iteration times are calibrated so that end-to-end latencies land
//! in the paper's regime (hundreds of seconds per app at 0.2–1.0 QPS with
//! 20 concurrent apps).

/// Calibrated rates for one (model, hardware) configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelProfile {
    pub name: &'static str,
    /// Tokens per KV block (paper: 16).
    pub block_tokens: u32,
    /// Bytes per KV block (14B bf16: 3 MiB).
    pub block_bytes: u64,
    /// Total GPU KV blocks (whole pool, before `gpu_mem_frac`).
    pub gpu_blocks: u32,
    /// CPU offload pool blocks (100 GB / block_bytes).
    pub cpu_blocks: u32,
    /// Prefill cost per token (µs) — also the recompute cost.
    pub prefill_us_per_token: f64,
    /// Decode iteration fixed cost (µs).
    pub decode_base_us: f64,
    /// Decode iteration marginal cost per running sequence (µs).
    pub decode_us_per_seq: f64,
    /// D2H offload cost per block (µs).
    pub offload_us_per_block: f64,
    /// H2D upload cost per block (µs).
    pub upload_us_per_block: f64,
    /// Fixed transfer issue latency per direction (µs).
    pub transfer_latency_us: f64,
    /// Tensor-parallel degree (per-GPU pools are `gpu_blocks / tp`).
    pub tp: u32,
}

impl ModelProfile {
    /// Qwen2.5-14B on one A100-80GB (paper's Fig 9/10/17 config).
    pub fn qwen14b_a100() -> Self {
        Self {
            name: "qwen14b-a100",
            block_tokens: 16,
            block_bytes: 3 << 20,
            // ~40 GB of the 80 GB HBM left for KV after weights+activations.
            gpu_blocks: 13_000,
            // 100 GB CPU pool / 3 MiB.
            cpu_blocks: 34_000,
            prefill_us_per_token: 443.0,
            decode_base_us: 18_000.0,
            decode_us_per_seq: 280.0,
            offload_us_per_block: 125.0,
            upload_us_per_block: 124.0,
            transfer_latency_us: 300.0,
            tp: 1,
        }
    }

    /// Qwen2.5-32B on one H20-96GB.
    pub fn qwen32b_h20() -> Self {
        Self {
            name: "qwen32b-h20",
            block_tokens: 16,
            block_bytes: 5 << 20,
            // ~30 GB KV pool after 64 GB of weights.
            gpu_blocks: 6_000,
            cpu_blocks: 20_000,
            // H20 has weak compute (~1/6 of A100 FLOPs): slower prefill.
            prefill_us_per_token: 1_400.0,
            decode_base_us: 30_000.0,
            decode_us_per_seq: 500.0,
            // H20 PCIe gen5: a bit faster per byte, bigger blocks.
            offload_us_per_block: 160.0,
            upload_us_per_block: 158.0,
            transfer_latency_us: 300.0,
            tp: 1,
        }
    }

    /// Qwen2.5-72B on two H20s, tensor parallel degree 2.
    ///
    /// TP=2 halves the per-GPU KV footprint per token but admission must
    /// reserve blocks on *all* participating GPUs (§5 Multi-GPU Support).
    pub fn qwen72b_h20x2() -> Self {
        Self {
            name: "qwen72b-h20x2",
            block_tokens: 16,
            block_bytes: 7 << 20,
            // Pool across both GPUs after ~72 GB weights per-GPU shard.
            gpu_blocks: 7_000,
            cpu_blocks: 14_000,
            prefill_us_per_token: 2_600.0,
            decode_base_us: 45_000.0,
            decode_us_per_seq: 800.0,
            offload_us_per_block: 210.0,
            upload_us_per_block: 208.0,
            transfer_latency_us: 400.0,
            tp: 2,
        }
    }

    /// TinyQwen on the in-process PJRT CPU backend (e2e example).
    ///
    /// One block = one decode *slot* (256 tokens): with 8 slots the block
    /// pool maps 1:1 onto the batched cache, so the coordinator's block
    /// accounting is exact for the real engine. Transfer/prefill rates are
    /// irrelevant — execution is real, not simulated — but kept non-zero
    /// so Eq. 2's gate arithmetic stays meaningful (host memcpy ≈ µs).
    pub fn tinyqwen_cpu() -> Self {
        Self {
            name: "tinyqwen-cpu",
            block_tokens: 256,
            // k+v, L=2 layers, 256 tok, H=2, D=64, f32.
            block_bytes: (2 * 2 * 256 * 2 * 64 * 4) as u64,
            gpu_blocks: 8,
            cpu_blocks: 64,
            prefill_us_per_token: 50.0,
            decode_base_us: 10_000.0,
            decode_us_per_seq: 1_000.0,
            offload_us_per_block: 500.0,
            upload_us_per_block: 500.0,
            transfer_latency_us: 100.0,
            tp: 1,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        Some(match name {
            "qwen14b-a100" | "14b" => Self::qwen14b_a100(),
            "qwen32b-h20" | "32b" => Self::qwen32b_h20(),
            "qwen72b-h20x2" | "72b" => Self::qwen72b_h20x2(),
            "tinyqwen-cpu" | "tiny" => Self::tinyqwen_cpu(),
            _ => return None,
        })
    }

    /// Blocks needed to hold `tokens` tokens of KV.
    #[inline]
    pub fn blocks_for_tokens(&self, tokens: u32) -> u32 {
        tokens.div_ceil(self.block_tokens)
    }

    /// Simulated prefill (= recompute) duration for a context length.
    #[inline]
    pub fn prefill_us(&self, tokens: u32) -> u64 {
        (self.prefill_us_per_token * tokens as f64) as u64
    }

    /// Simulated decode iteration duration for a batch of running seqs.
    #[inline]
    pub fn decode_iter_us(&self, batch: usize) -> u64 {
        if batch == 0 {
            0
        } else {
            (self.decode_base_us + self.decode_us_per_seq * batch as f64)
                as u64
        }
    }

    /// D2H transfer duration for `blocks` blocks.
    #[inline]
    pub fn offload_us(&self, blocks: u32) -> u64 {
        (self.transfer_latency_us + self.offload_us_per_block * blocks as f64)
            as u64
    }

    /// H2D transfer duration for `blocks` blocks.
    #[inline]
    pub fn upload_us(&self, blocks: u32) -> u64 {
        (self.transfer_latency_us + self.upload_us_per_block * blocks as f64)
            as u64
    }

    /// Round-trip transfer estimate (Eq. 2).
    #[inline]
    pub fn round_trip_us(&self, blocks: u32) -> u64 {
        self.offload_us(blocks) + self.upload_us(blocks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig17_calibration_holds() {
        // 4096 tokens = 256 blocks: offload ≈ 32.0 ms, upload ≈ 31.7 ms,
        // recompute ≈ 1815 ms, ratio ≈ 28.5× (paper: 26.8–37.5×).
        let p = ModelProfile::qwen14b_a100();
        let blocks = p.blocks_for_tokens(4096);
        assert_eq!(blocks, 256);
        let off = p.offload_us(blocks) as f64 / 1e3;
        let up = p.upload_us(blocks) as f64 / 1e3;
        assert!((off - 32.3).abs() < 1.0, "offload={off}ms");
        assert!((up - 32.0).abs() < 1.0, "upload={up}ms");
        let recompute = p.prefill_us(4096) as f64 / 1e3;
        assert!((recompute - 1815.0).abs() < 20.0, "recompute={recompute}ms");
        let ratio = recompute / (off + up);
        assert!(
            (26.0..38.0).contains(&ratio),
            "recompute/rt ratio {ratio} outside paper band"
        );
    }

    #[test]
    fn recompute_dominates_across_lengths() {
        // Fig 17's claim across 1024..=5120 tokens.
        let p = ModelProfile::qwen14b_a100();
        for tokens in [1024u32, 2048, 3072, 4096, 5120] {
            let blocks = p.blocks_for_tokens(tokens);
            let rt = p.round_trip_us(blocks) as f64;
            let rc = p.prefill_us(tokens) as f64;
            let ratio = rc / rt;
            assert!(
                (20.0..45.0).contains(&ratio),
                "tokens={tokens} ratio={ratio}"
            );
        }
    }

    #[test]
    fn blocks_for_tokens_rounds_up() {
        let p = ModelProfile::qwen14b_a100();
        assert_eq!(p.blocks_for_tokens(1), 1);
        assert_eq!(p.blocks_for_tokens(16), 1);
        assert_eq!(p.blocks_for_tokens(17), 2);
        assert_eq!(p.blocks_for_tokens(0), 0);
    }

    #[test]
    fn by_name_resolves_all() {
        for n in ["qwen14b-a100", "qwen32b-h20", "qwen72b-h20x2",
                  "tinyqwen-cpu"] {
            assert!(ModelProfile::by_name(n).is_some(), "{n}");
        }
        assert!(ModelProfile::by_name("x").is_none());
    }

    #[test]
    fn decode_iter_scales_with_batch() {
        let p = ModelProfile::qwen14b_a100();
        assert_eq!(p.decode_iter_us(0), 0);
        assert!(p.decode_iter_us(32) > p.decode_iter_us(1));
    }
}
