//! A dependency-free TOML-subset parser: `[section]` headers and
//! `key = value` lines, `#` comments, quoted or bare values. Enough to make
//! deployments file-configurable without serde (not vendored offline).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ParseError {
    Io(String),
    Syntax { line: usize, text: String },
    UnknownKey { section: String, key: String },
    BadValue { section: String, key: String, value: String },
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ParseError::Io(e) => write!(f, "io error: {e}"),
            ParseError::Syntax { line, text } => {
                write!(f, "syntax error at line {line}: {text:?}")
            }
            ParseError::UnknownKey { section, key } => {
                write!(f, "unknown key [{section}] {key}")
            }
            ParseError::BadValue { section, key, value } => {
                write!(f, "bad value for [{section}] {key}: {value:?}")
            }
        }
    }
}

impl std::error::Error for ParseError {}

/// Parse file contents into ((section, key) -> value), last write wins.
pub fn parse_kv_str(
    content: &str,
) -> Result<BTreeMap<(String, String), String>, ParseError> {
    let mut out = BTreeMap::new();
    let mut section = String::from("serve");
    for (idx, raw) in content.lines().enumerate() {
        let line = match raw.find('#') {
            Some(i) => &raw[..i],
            None => raw,
        }
        .trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name.strip_suffix(']').ok_or(ParseError::Syntax {
                line: idx + 1,
                text: raw.to_string(),
            })?;
            section = name.trim().to_string();
            continue;
        }
        let (k, v) = line.split_once('=').ok_or(ParseError::Syntax {
            line: idx + 1,
            text: raw.to_string(),
        })?;
        let key = k.trim().to_string();
        let mut value = v.trim();
        if value.len() >= 2
            && ((value.starts_with('"') && value.ends_with('"'))
                || (value.starts_with('\'') && value.ends_with('\'')))
        {
            value = &value[1..value.len() - 1];
        }
        if key.is_empty() {
            return Err(ParseError::Syntax {
                line: idx + 1,
                text: raw.to_string(),
            });
        }
        out.insert((section.clone(), key), value.to_string());
    }
    Ok(out)
}

/// Parse a file from disk.
pub fn parse_kv_file(
    path: &str,
) -> Result<BTreeMap<(String, String), String>, ParseError> {
    let content = std::fs::read_to_string(path)
        .map_err(|e| ParseError::Io(format!("{path}: {e}")))?;
    parse_kv_str(&content)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_comments_quotes() {
        let text = r#"
# a comment
mode = tokencake   # trailing comment
[policy]
pressure_watermark = 0.06
selection = "best_fit"
[serve]
seed = 42
"#;
        let kv = parse_kv_str(text).unwrap();
        assert_eq!(
            kv[&("serve".into(), "mode".into())],
            "tokencake".to_string()
        );
        assert_eq!(kv[&("policy".into(), "selection".into())], "best_fit");
        assert_eq!(kv[&("serve".into(), "seed".into())], "42");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_kv_str("just words").is_err());
        assert!(parse_kv_str("[unclosed").is_err());
        assert!(parse_kv_str("= novalue").is_err());
    }

    #[test]
    fn last_write_wins() {
        let kv = parse_kv_str("a = 1\na = 2").unwrap();
        assert_eq!(kv[&("serve".into(), "a".into())], "2");
    }
}
