//! Configuration system: serving mode, hardware/model calibration profiles,
//! and every scheduler constant from the paper, plus a dependency-free
//! TOML-subset parser so deployments are file-configurable.

mod parse;
mod profile;

pub use parse::{parse_kv_file, ParseError};
pub use profile::ModelProfile;

/// Serving mode: TokenCake proper, its ablation components, and the
/// baseline systems reproduced for §7 (see `baselines` module).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mode {
    /// Full TokenCake: Spatial + Temporal schedulers, coordinated (§3.2).
    TokenCake,
    /// vLLM v0.8.6-style baseline: FCFS continuous batching, paged blocks,
    /// recompute-on-evict, no offload, agent-agnostic.
    Vllm,
    /// vLLM + prefix caching (shared prompt reuse).
    VllmPrefix,
    /// Mooncake-style remote/CPU KV store: *reactive* offload under memory
    /// pressure (LRU victims), prefix reuse, agent-agnostic (Table 2).
    Mooncake,
    /// Parrot-style agent-aware, compute-centric scheduling: DAG priorities
    /// order the queue but memory is unmanaged (§7.4).
    Parrot,
    /// Ablation: Spatial Scheduler only (§7.3 "agent").
    AgentOnly,
    /// Ablation: Temporal Scheduler only, agent-blind (§7.3 "offload").
    OffloadOnly,
    /// InferCept-style: reactive swap on every function-call interception,
    /// FCFS upload (Table 2 comparison row).
    Infercept,
}

impl Mode {
    pub fn parse(s: &str) -> Option<Mode> {
        Some(match s.to_ascii_lowercase().as_str() {
            "tokencake" | "full" => Mode::TokenCake,
            "vllm" | "baseline" => Mode::Vllm,
            "vllm-prefix" | "vllmprefix" => Mode::VllmPrefix,
            "mooncake" => Mode::Mooncake,
            "parrot" => Mode::Parrot,
            "agent" | "agent-only" => Mode::AgentOnly,
            "offload" | "offload-only" => Mode::OffloadOnly,
            "infercept" => Mode::Infercept,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Mode::TokenCake => "tokencake",
            Mode::Vllm => "vllm",
            Mode::VllmPrefix => "vllm-prefix",
            Mode::Mooncake => "mooncake",
            Mode::Parrot => "parrot",
            Mode::AgentOnly => "agent",
            Mode::OffloadOnly => "offload",
            Mode::Infercept => "infercept",
        }
    }

    /// Does this mode run the Spatial Scheduler (agent-aware priorities +
    /// dynamic reservation)?
    pub fn agent_aware(&self) -> bool {
        matches!(self, Mode::TokenCake | Mode::AgentOnly | Mode::Parrot)
    }

    /// Does this mode reserve memory for critical agents? (Parrot is
    /// agent-aware but compute-centric: schedules, never reserves.)
    pub fn reserves_memory(&self) -> bool {
        matches!(self, Mode::TokenCake | Mode::AgentOnly)
    }

    /// Does this mode proactively offload on function-call events?
    pub fn fc_offload(&self) -> bool {
        matches!(
            self,
            Mode::TokenCake | Mode::OffloadOnly | Mode::Infercept
        )
    }

    /// Does this mode offload reactively under memory pressure?
    pub fn reactive_offload(&self) -> bool {
        matches!(self, Mode::Mooncake)
    }

    /// Does this mode reuse cached prefixes across requests?
    pub fn prefix_cache(&self) -> bool {
        matches!(self, Mode::VllmPrefix | Mode::Mooncake | Mode::TokenCake)
    }

    /// Does this mode keep a CPU tier for the prefix cache? (vLLM-Prefix
    /// has no host KV store — reclaimed prefixes are dropped, not
    /// demoted; Mooncake and TokenCake demote to CPU blocks.)
    pub fn prefix_cpu_tier(&self) -> bool {
        matches!(self, Mode::Mooncake | Mode::TokenCake)
    }
}

/// Waiting-request selection policy for the opportunistic gate (§4.2, Fig 15).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SelectionPolicy {
    /// First request in queue order that fits (paper default: preserves the
    /// order the Spatial Scheduler already optimized).
    FirstFit,
    /// Request whose demand best matches the freed capacity.
    BestFit,
    /// Highest-priority request that fits.
    PriorityFirst,
}

impl SelectionPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "first_fit" | "first-fit" | "first" => SelectionPolicy::FirstFit,
            "best_fit" | "best-fit" | "best" => SelectionPolicy::BestFit,
            "priority_first" | "priority" => SelectionPolicy::PriorityFirst,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SelectionPolicy::FirstFit => "first_fit",
            SelectionPolicy::BestFit => "best_fit",
            SelectionPolicy::PriorityFirst => "priority_first",
        }
    }
}

/// Every tunable of the two schedulers, defaulting to the paper's published
/// constants (§5.1, §4.2, §4.3).
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyConfig {
    // ---- Spatial Scheduler: dynamic partitioning (Algorithm 2) ----
    /// Initial reserved-pool fraction ρ.
    pub reserve_init: f64,
    /// ρ adjustment step per window.
    pub reserve_step: f64,
    /// Clamp bounds for ρ.
    pub reserve_min: f64,
    pub reserve_max: f64,
    /// GPU-usage watermarks driving ρ up/down.
    pub high_watermark: f64,
    pub low_watermark: f64,
    /// Fraction of active agent types designated critical (top by S_a).
    pub critical_ratio: f64,
    /// Reservation-plan adjustment window (µs).
    pub adjust_window_us: u64,
    /// Minimum per-type quota worth reserving (blocks); smaller shares are
    /// pure fragmentation and stay in the shared pool.
    pub min_quota_blocks: u32,

    // ---- Per-request priority (Eq. 5) ----
    pub alpha_struct: f64,
    pub alpha_sync: f64,
    pub alpha_aging: f64,

    // ---- Agent-type score (Eq. 6) ----
    pub w_structural: f64,
    pub w_urgency: f64,
    pub w_recompute: f64,
    pub w_graph: f64,
    /// Preemption counts weigh more than waiting counts inside U_a —
    /// preemption directly signals KV-capacity loss (§5.2).
    pub urgency_preempt_coef: f64,
    pub urgency_wait_coef: f64,

    // ---- Temporal Scheduler (§4) ----
    /// Eq. 1 blend weight on the user-supplied estimate.
    pub forecast_alpha_user: f64,
    /// EWMA smoothing for observed tool durations.
    pub forecast_ewma: f64,
    /// Conservative system-wide default when no estimate exists (µs).
    pub forecast_default_us: u64,
    /// Waiting-request selection policy for the opportunistic gate.
    pub selection: SelectionPolicy,
    /// Gate: GPU free-fraction must be *below* (1 - this) — i.e. usage above
    /// this — before offload is considered. Fig 16's "spatial pressure
    /// watermark" sweeps the waiting-demand threshold below.
    pub offload_usage_threshold: f64,
    /// Gate: waiting demand (blocks / total) that makes freed blocks useful.
    pub pressure_watermark: f64,
    /// Soft-score acceptance threshold.
    pub score_threshold: f64,
    /// Penalty weight for offloading critical agents.
    pub critical_penalty: f64,
    /// Penalty for requests close to completion.
    pub near_completion_penalty: f64,
    /// Penalty per prior migration (churn).
    pub churn_penalty: f64,
    /// Emergency override: GPU usage above this allows offloading even
    /// high-importance requests when the stall margin is large.
    pub emergency_usage: f64,
    /// Stall/transfer ratio considered a "large margin".
    pub emergency_margin: f64,
    /// Predictive upload: start gradual reservation this early (fraction of
    /// predicted remaining stall).
    pub upload_lead_frac: f64,
    /// Bandwidth cap on the batched offload planner: at most this many
    /// blocks may be in flight D2H at once; a planning event spends
    /// `cap − inflight` on new victims and defers the rest of the batch
    /// until transfers complete (partial-batch fallback).
    pub offload_inflight_cap_blocks: u32,

    // ---- Mooncake-style reactive policy ----
    /// Reactive offload triggers when GPU usage exceeds this.
    pub reactive_usage_threshold: f64,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        Self {
            reserve_init: 0.05,
            reserve_step: 0.05,
            reserve_min: 0.05,
            reserve_max: 0.30,
            high_watermark: 0.75,
            low_watermark: 0.40,
            critical_ratio: 0.75,
            adjust_window_us: 1_000_000,
            min_quota_blocks: 8,

            alpha_struct: 0.5,
            alpha_sync: 0.3,
            alpha_aging: 0.2,

            w_structural: 0.35,
            w_urgency: 0.30,
            w_recompute: 0.20,
            w_graph: 0.15,
            urgency_preempt_coef: 3.0,
            urgency_wait_coef: 1.0,

            forecast_alpha_user: 0.4,
            forecast_ewma: 0.3,
            forecast_default_us: 2_000_000,
            selection: SelectionPolicy::FirstFit,
            offload_usage_threshold: 0.50,
            pressure_watermark: 0.05,
            score_threshold: 0.35,
            critical_penalty: 0.60,
            near_completion_penalty: 0.25,
            churn_penalty: 0.15,
            emergency_usage: 0.95,
            emergency_margin: 4.0,
            upload_lead_frac: 0.35,
            offload_inflight_cap_blocks: 4096,

            reactive_usage_threshold: 0.90,
        }
    }
}

/// Request-placement policy for the cluster router (see
/// `cluster::Router`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Agent-oblivious round robin — the multi-worker baseline.
    RoundRobin,
    /// Pick the shard with the lowest pressure score.
    LeastLoaded,
    /// Route an application to the shard already holding its agent types'
    /// KV state (warm prefixes, hot forecaster); fall back to the
    /// pressure score when the affinity target is saturated.
    AgentAffinity,
}

impl PlacementPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "round-robin" | "roundrobin" | "rr" => {
                PlacementPolicy::RoundRobin
            }
            "least-loaded" | "leastloaded" | "least" => {
                PlacementPolicy::LeastLoaded
            }
            "agent-affinity" | "affinity" | "aff" => {
                PlacementPolicy::AgentAffinity
            }
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PlacementPolicy::RoundRobin => "round-robin",
            PlacementPolicy::LeastLoaded => "least-loaded",
            PlacementPolicy::AgentAffinity => "agent-affinity",
        }
    }
}

/// Elastic replica autoscaling: the hysteresis controller that grows and
/// drains worker shards from the aggregate pressure signal (see
/// `cluster::autoscale`). Disabled by default — a fixed fleet behaves
/// exactly as before.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscaleConfig {
    pub enabled: bool,
    /// The fleet never drains below this many serving shards.
    pub min_shards: usize,
    /// The fleet never provisions beyond this many shards.
    pub max_shards: usize,
    /// Grow when the mean pressure signal (load score + stalled/offloaded
    /// resumption demand, averaged over active shards) is at/above this.
    pub grow_watermark: f64,
    /// Drain when the signal stays at/below this for `drain_confirm`
    /// consecutive evaluations (hysteresis: strictly below
    /// `grow_watermark`).
    pub drain_watermark: f64,
    /// Modeled shard spin-up cost on the shared clock (model load + KV
    /// pool init); the router sends a warming shard nothing.
    pub warmup_cost_us: u64,
    /// Minimum clock time between scale decisions (anti-flap).
    pub cooldown_us: u64,
    /// Consecutive below-watermark evaluations before a drain starts.
    pub drain_confirm: u32,
    /// Minimum clock time between controller evaluations (the pressure
    /// epoch gate decides whether an evaluation happens at all).
    pub interval_us: u64,
    /// EWMA smoothing for the KV-lifetime predictor's observed per-
    /// template function-call stall durations.
    pub lifetime_ewma: f64,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            min_shards: 1,
            max_shards: 8,
            grow_watermark: 0.85,
            drain_watermark: 0.30,
            warmup_cost_us: 2_000_000,
            cooldown_us: 2_000_000,
            drain_confirm: 3,
            interval_us: 250_000,
            lifetime_ewma: 0.3,
        }
    }
}

impl AutoscaleConfig {
    /// Panic on inconsistent bounds (called when an engine adopts the
    /// config, so a bad file/flag set fails loudly up front).
    pub fn validate(&self) {
        assert!(self.min_shards >= 1, "autoscale.min_shards must be >= 1");
        assert!(
            self.min_shards <= self.max_shards,
            "autoscale.min_shards must be <= max_shards"
        );
        assert!(
            self.drain_watermark < self.grow_watermark,
            "autoscale watermarks must leave a hysteresis band \
             (drain < grow)"
        );
        assert!(self.lifetime_ewma > 0.0 && self.lifetime_ewma <= 1.0);
    }
}

/// Deterministic fault injection: seeded shard crashes and interconnect
/// partition windows executed on the shared cluster clock (see
/// `cluster::faults`). Disabled by default — a fault-free fleet behaves
/// exactly as before.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    pub enabled: bool,
    /// Fault-plan RNG seed; 0 derives the plan seed from `serve.seed`,
    /// so a workload seed sweep also sweeps the fault placement.
    pub seed: u64,
    /// Explicit crash schedule: `shard@ms` entries joined with `;`
    /// (e.g. `"1@2500;3@6000"`). Applied before any random crashes.
    pub crash_schedule: String,
    /// Number of additional randomly placed shard crashes.
    pub crashes: u32,
    /// Number of randomly placed interconnect partition windows.
    pub partitions: u32,
    /// Wire-cost multiplier on transfers priced while a partition
    /// window between their shard pair is open (a straggling link).
    pub partition_factor: f64,
    /// Extra fixed delivery hold on transfers crossing an open window
    /// (µs).
    pub partition_hold_us: u64,
    /// Duration of each partition window (µs).
    pub partition_len_us: u64,
    /// Random faults land uniformly in
    /// `[window_start_us, window_start_us + window_len_us)`.
    pub window_start_us: u64,
    pub window_len_us: u64,
    /// Hard partition: a migration that would cross an open window is
    /// dropped at planning time instead of priced up.
    pub drop_wire: bool,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            seed: 0,
            crash_schedule: String::new(),
            crashes: 0,
            partitions: 0,
            partition_factor: 4.0,
            partition_hold_us: 50_000,
            partition_len_us: 2_000_000,
            window_start_us: 1_000_000,
            window_len_us: 10_000_000,
            drop_wire: false,
        }
    }
}

impl FaultConfig {
    /// Panic on inconsistent knobs (called when an engine adopts the
    /// config, so a bad file/flag set fails loudly up front).
    pub fn validate(&self) {
        assert!(
            self.partition_factor >= 1.0,
            "faults.partition_factor must be >= 1.0 (a straggler \
             never speeds the wire up)"
        );
        assert!(
            self.window_len_us >= 1,
            "faults.window_len_us must be >= 1"
        );
        assert!(
            self.partition_len_us >= 1,
            "faults.partition_len_us must be >= 1"
        );
    }
}

/// Multi-worker cluster configuration: N shards, each an independent
/// worker with its own GPU/CPU block pools and scheduler state, fed by a
/// placement router and (optionally) rebalanced through cross-worker KV
/// migration.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Per-shard serving configuration (each shard models one worker GPU;
    /// `gpu_mem_frac` applies per shard). Shard RNGs derive from
    /// `serve.seed` by folding the shard index.
    pub serve: ServeConfig,
    /// Number of worker shards.
    pub shards: usize,
    pub placement: PlacementPolicy,
    /// Enable cross-worker migration of stalled agents' KV blocks.
    pub migration: bool,
    /// A shard is a migration *source* when its GPU usage is at or above
    /// this.
    pub migrate_src_usage: f64,
    /// A shard is a migration *destination* when its GPU usage is below
    /// this.
    pub migrate_dst_usage: f64,
    /// Migrate only when the predicted remaining stall exceeds this
    /// multiple of the cross-worker transfer time (the move must pay for
    /// itself).
    pub migrate_payback: f64,
    /// Cross-worker interconnect slowdown vs. the local PCIe D2H+H2D
    /// round trip (NIC hop + remote write).
    pub interconnect_factor: f64,
    /// How often the migration planner runs (µs of simulated time).
    pub rebalance_interval_us: u64,
    /// AgentAffinity spills to a cold shard once the warm shard's
    /// pressure score is at or above this.
    pub affinity_spill_load: f64,
    /// Interconnect budget per planning window (blocks): one planning
    /// event migrates a multi-victim batch up to this large, with a
    /// partial-batch fallback when a victim no longer fits.
    pub migrate_batch_budget_blocks: u32,
    /// Federate the per-shard prefix indexes through the cluster prefix
    /// directory: shards publish insert/evict/relocate events, routing
    /// reads real resident-block warmth, and spilled apps hit remote
    /// prefixes at interconnect price instead of re-prefilling.
    pub prefix_directory: bool,
    /// Remote hits on one prefix before the directory replicates it to
    /// the hitting shard's CPU tier (local price afterwards). Replica
    /// traffic draws on the same per-window interconnect budget as
    /// migration batches.
    pub prefix_replicate_threshold: u32,
    /// Elastic replica autoscaling (`[cluster.autoscale]` section). When
    /// enabled, `shards` becomes the *initial* serving count (clamped to
    /// `[min_shards, max_shards]`) and the engine provisions capacity up
    /// to `max_shards`.
    pub autoscale: AutoscaleConfig,
    /// Deterministic fault injection (`[cluster.faults]` section): shard
    /// crashes with full recovery accounting, plus interconnect
    /// partition/straggler windows.
    pub faults: FaultConfig,
    /// Multi-tenant QoS (`[cluster.qos]` section): per-tier token-bucket
    /// admission in front of the router, load shedding under overload,
    /// and SLO-aware victim selection inside the shards.
    pub qos: crate::qos::QosConfig,
    /// Execute the shard-local phases of each engine iteration on
    /// scoped worker threads (`--parallel`). Off = the serial oracle
    /// mode: same code path in shard index order on one thread. The
    /// two modes are byte-identical per seed (digests and traces) —
    /// pinned by `serial_parallel_digest_parity` and the CI
    /// `--assert-parity` smoke.
    pub parallel: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            serve: ServeConfig::default(),
            shards: 2,
            placement: PlacementPolicy::AgentAffinity,
            migration: true,
            migrate_src_usage: 0.90,
            migrate_dst_usage: 0.60,
            migrate_payback: 2.0,
            interconnect_factor: 2.0,
            rebalance_interval_us: 250_000,
            affinity_spill_load: 0.80,
            migrate_batch_budget_blocks: 2048,
            prefix_directory: true,
            prefix_replicate_threshold: 2,
            autoscale: AutoscaleConfig::default(),
            faults: FaultConfig::default(),
            qos: crate::qos::QosConfig::default(),
            parallel: false,
        }
    }
}

impl ClusterConfig {
    pub fn with_shards(mut self, n: usize) -> Self {
        assert!(n >= 1, "cluster needs at least one shard");
        self.shards = n;
        self
    }

    pub fn with_placement(mut self, p: PlacementPolicy) -> Self {
        self.placement = p;
        self
    }

    pub fn with_migration(mut self, on: bool) -> Self {
        self.migration = on;
        self
    }

    pub fn with_serve(mut self, serve: ServeConfig) -> Self {
        self.serve = serve;
        self
    }

    pub fn with_parallel(mut self, on: bool) -> Self {
        self.parallel = on;
        self
    }

    /// Apply one (section, key, value) override; `cluster` keys are
    /// handled here, everything else falls through to the per-shard
    /// [`ServeConfig`].
    pub fn apply_kv(
        &mut self,
        section: &str,
        key: &str,
        value: &str,
    ) -> Result<(), ParseError> {
        let bad = || ParseError::BadValue {
            section: section.to_string(),
            key: key.to_string(),
            value: value.to_string(),
        };
        let on_off = |v: &str| match v {
            "true" | "on" | "1" => Ok(true),
            "false" | "off" | "0" => Ok(false),
            _ => Err(bad()),
        };
        if section == "cluster.autoscale" {
            let a = &mut self.autoscale;
            match key {
                "enabled" => a.enabled = on_off(value)?,
                "min_shards" => {
                    // Reject rather than clamp: silently rewriting an
                    // invalid floor would mask a config mistake the
                    // CLI path reports loudly.
                    let v =
                        value.parse::<usize>().map_err(|_| bad())?;
                    if v == 0 {
                        return Err(bad());
                    }
                    a.min_shards = v;
                }
                "max_shards" => {
                    let v =
                        value.parse::<usize>().map_err(|_| bad())?;
                    if v == 0 {
                        return Err(bad());
                    }
                    a.max_shards = v;
                }
                "grow_watermark" => {
                    a.grow_watermark = value.parse().map_err(|_| bad())?
                }
                "drain_watermark" => {
                    a.drain_watermark = value.parse().map_err(|_| bad())?
                }
                "warmup_cost_us" => {
                    a.warmup_cost_us = value.parse().map_err(|_| bad())?
                }
                "cooldown_us" => {
                    a.cooldown_us = value.parse().map_err(|_| bad())?
                }
                "drain_confirm" => {
                    a.drain_confirm = value.parse().map_err(|_| bad())?
                }
                "interval_us" => {
                    a.interval_us = value.parse().map_err(|_| bad())?
                }
                "lifetime_ewma" => {
                    a.lifetime_ewma = value.parse().map_err(|_| bad())?
                }
                _ => {
                    return Err(ParseError::UnknownKey {
                        section: section.to_string(),
                        key: key.to_string(),
                    })
                }
            }
            return Ok(());
        }
        if section == "cluster.faults" {
            let fc = &mut self.faults;
            match key {
                "enabled" => fc.enabled = on_off(value)?,
                "seed" => fc.seed = value.parse().map_err(|_| bad())?,
                "crash_schedule" => {
                    // Syntax is checked here; shard indices are range-
                    // checked against the fleet when the plan builds.
                    for part in
                        value.split(';').filter(|s| !s.is_empty())
                    {
                        let Some((s, ms)) = part.split_once('@') else {
                            return Err(bad());
                        };
                        s.parse::<usize>().map_err(|_| bad())?;
                        ms.parse::<u64>().map_err(|_| bad())?;
                    }
                    fc.crash_schedule = value.to_string();
                }
                "crashes" => {
                    fc.crashes = value.parse().map_err(|_| bad())?
                }
                "partitions" => {
                    fc.partitions = value.parse().map_err(|_| bad())?
                }
                "partition_factor" => {
                    fc.partition_factor =
                        value.parse().map_err(|_| bad())?
                }
                "partition_hold_us" => {
                    fc.partition_hold_us =
                        value.parse().map_err(|_| bad())?
                }
                "partition_len_us" => {
                    fc.partition_len_us =
                        value.parse().map_err(|_| bad())?
                }
                "window_start_us" => {
                    fc.window_start_us =
                        value.parse().map_err(|_| bad())?
                }
                "window_len_us" => {
                    fc.window_len_us = value.parse().map_err(|_| bad())?
                }
                "drop_wire" => fc.drop_wire = on_off(value)?,
                _ => {
                    return Err(ParseError::UnknownKey {
                        section: section.to_string(),
                        key: key.to_string(),
                    })
                }
            }
            return Ok(());
        }
        if section == "cluster.qos" {
            let q = &mut self.qos;
            // Per-tier keys use the `_interactive/_standard/_batch`
            // suffix; `*_ms` keys convert to µs here so the struct
            // stays single-unit.
            let tier_ix = |k: &str| match k {
                k if k.ends_with("_interactive") => Some(0usize),
                k if k.ends_with("_standard") => Some(1),
                k if k.ends_with("_batch") => Some(2),
                _ => None,
            };
            match key {
                "enabled" => q.enabled = on_off(value)?,
                "age_promote_ms" => {
                    q.age_promote_us = value
                        .parse::<u64>()
                        .map_err(|_| bad())?
                        .saturating_mul(1000)
                }
                "shed_band" => {
                    q.shed_band = value.parse().map_err(|_| bad())?
                }
                "shed_queue_depth" => {
                    q.shed_queue_depth =
                        value.parse().map_err(|_| bad())?
                }
                k if k.starts_with("rate_") && tier_ix(k).is_some() => {
                    q.rate_per_s[tier_ix(k).unwrap()] =
                        value.parse().map_err(|_| bad())?
                }
                k if k.starts_with("burst_") && tier_ix(k).is_some() => {
                    q.burst[tier_ix(k).unwrap()] =
                        value.parse().map_err(|_| bad())?
                }
                k if k.starts_with("slo_ms_") && tier_ix(k).is_some() => {
                    q.slo_us[tier_ix(k).unwrap()] = value
                        .parse::<u64>()
                        .map_err(|_| bad())?
                        .saturating_mul(1000)
                }
                _ => {
                    return Err(ParseError::UnknownKey {
                        section: section.to_string(),
                        key: key.to_string(),
                    })
                }
            }
            return Ok(());
        }
        if section != "cluster" {
            return self.serve.apply_kv(section, key, value);
        }
        match key {
            "shards" => {
                self.shards =
                    value.parse::<usize>().map_err(|_| bad())?.max(1)
            }
            "placement" => {
                self.placement =
                    PlacementPolicy::parse(value).ok_or_else(bad)?
            }
            "migration" => {
                self.migration = match value {
                    "true" | "on" | "1" => true,
                    "false" | "off" | "0" => false,
                    _ => return Err(bad()),
                }
            }
            "migrate_src_usage" => {
                self.migrate_src_usage =
                    value.parse().map_err(|_| bad())?
            }
            "migrate_dst_usage" => {
                self.migrate_dst_usage =
                    value.parse().map_err(|_| bad())?
            }
            "migrate_payback" => {
                self.migrate_payback = value.parse().map_err(|_| bad())?
            }
            "interconnect_factor" => {
                self.interconnect_factor =
                    value.parse().map_err(|_| bad())?
            }
            "rebalance_interval_us" => {
                self.rebalance_interval_us =
                    value.parse().map_err(|_| bad())?
            }
            "affinity_spill_load" => {
                self.affinity_spill_load =
                    value.parse().map_err(|_| bad())?
            }
            "migrate_batch_budget_blocks" => {
                self.migrate_batch_budget_blocks =
                    value.parse().map_err(|_| bad())?
            }
            "prefix_directory" => {
                self.prefix_directory = match value {
                    "true" | "on" | "1" => true,
                    "false" | "off" | "0" => false,
                    _ => return Err(bad()),
                }
            }
            "prefix_replicate_threshold" => {
                self.prefix_replicate_threshold =
                    value.parse().map_err(|_| bad())?
            }
            "parallel" => self.parallel = on_off(value)?,
            _ => {
                return Err(ParseError::UnknownKey {
                    section: section.to_string(),
                    key: key.to_string(),
                })
            }
        }
        Ok(())
    }

    /// Load overrides from a TOML-subset file (shared parser with
    /// [`ServeConfig::apply_file`]).
    pub fn apply_file(&mut self, path: &str) -> Result<(), ParseError> {
        let kv = parse_kv_file(path)?;
        for ((section, key), value) in kv.iter() {
            self.apply_kv(section, key, value)?;
        }
        Ok(())
    }
}

/// Top-level serving configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub mode: Mode,
    pub profile: ModelProfile,
    pub policy: PolicyConfig,
    /// Maximum sequences batched per decode iteration.
    pub max_batch: usize,
    /// Maximum prefill tokens admitted per iteration (chunked prefill).
    pub max_prefill_tokens: u32,
    /// Master RNG seed (workload, tools, corpus).
    pub seed: u64,
    /// Fraction of GPU KV pool available (paper §7.3 uses 0.5 for the
    /// ablation study to induce pressure).
    pub gpu_mem_frac: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            mode: Mode::TokenCake,
            profile: ModelProfile::qwen14b_a100(),
            policy: PolicyConfig::default(),
            max_batch: 64,
            max_prefill_tokens: 2048,
            seed: 0xC0FFEE,
            gpu_mem_frac: 1.0,
        }
    }
}

impl ServeConfig {
    pub fn with_mode(mut self, mode: Mode) -> Self {
        self.mode = mode;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_gpu_mem_frac(mut self, frac: f64) -> Self {
        assert!(frac > 0.0 && frac <= 1.0);
        self.gpu_mem_frac = frac;
        self
    }

    /// Effective GPU KV blocks after the memory fraction.
    pub fn gpu_blocks(&self) -> u32 {
        ((self.profile.gpu_blocks as f64) * self.gpu_mem_frac) as u32
    }

    /// Load overrides from a TOML-subset file (see `parse_kv_file`).
    pub fn apply_file(&mut self, path: &str) -> Result<(), ParseError> {
        let kv = parse_kv_file(path)?;
        for ((section, key), value) in kv.iter() {
            self.apply_kv(section, key, value)?;
        }
        Ok(())
    }

    /// Apply one (section, key, value) override.
    pub fn apply_kv(
        &mut self,
        section: &str,
        key: &str,
        value: &str,
    ) -> Result<(), ParseError> {
        let bad = || ParseError::BadValue {
            section: section.to_string(),
            key: key.to_string(),
            value: value.to_string(),
        };
        let f = |v: &str| v.parse::<f64>().map_err(|_| bad());
        let u = |v: &str| v.parse::<u64>().map_err(|_| bad());
        match (section, key) {
            ("serve", "mode") => self.mode = Mode::parse(value).ok_or_else(bad)?,
            ("serve", "profile") => {
                self.profile =
                    ModelProfile::by_name(value).ok_or_else(bad)?
            }
            ("serve", "max_batch") => self.max_batch = u(value)? as usize,
            ("serve", "max_prefill_tokens") => {
                self.max_prefill_tokens = u(value)? as u32
            }
            ("serve", "seed") => self.seed = u(value)?,
            ("serve", "gpu_mem_frac") => self.gpu_mem_frac = f(value)?,
            ("policy", "reserve_init") => self.policy.reserve_init = f(value)?,
            ("policy", "reserve_step") => self.policy.reserve_step = f(value)?,
            ("policy", "reserve_min") => self.policy.reserve_min = f(value)?,
            ("policy", "reserve_max") => self.policy.reserve_max = f(value)?,
            ("policy", "high_watermark") => {
                self.policy.high_watermark = f(value)?
            }
            ("policy", "low_watermark") => {
                self.policy.low_watermark = f(value)?
            }
            ("policy", "critical_ratio") => {
                self.policy.critical_ratio = f(value)?
            }
            ("policy", "adjust_window_us") => {
                self.policy.adjust_window_us = u(value)?
            }
            ("policy", "selection") => {
                self.policy.selection =
                    SelectionPolicy::parse(value).ok_or_else(bad)?
            }
            ("policy", "pressure_watermark") => {
                self.policy.pressure_watermark = f(value)?
            }
            ("policy", "score_threshold") => {
                self.policy.score_threshold = f(value)?
            }
            ("policy", "offload_inflight_cap_blocks") => {
                self.policy.offload_inflight_cap_blocks = u(value)? as u32
            }
            ("policy", "forecast_alpha_user") => {
                self.policy.forecast_alpha_user = f(value)?
            }
            ("policy", "forecast_ewma") => {
                self.policy.forecast_ewma = f(value)?
            }
            _ => {
                return Err(ParseError::UnknownKey {
                    section: section.to_string(),
                    key: key.to_string(),
                })
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_roundtrip() {
        for m in [
            Mode::TokenCake,
            Mode::Vllm,
            Mode::VllmPrefix,
            Mode::Mooncake,
            Mode::Parrot,
            Mode::AgentOnly,
            Mode::OffloadOnly,
            Mode::Infercept,
        ] {
            assert_eq!(Mode::parse(m.name()), Some(m));
        }
        assert_eq!(Mode::parse("nonsense"), None);
    }

    #[test]
    fn mode_capability_matrix_matches_table2() {
        // Table 2: TokenCake proactive FC-triggered; Mooncake reactive
        // pressure-triggered; Parrot schedules but never reserves/offloads.
        assert!(Mode::TokenCake.fc_offload());
        assert!(Mode::TokenCake.reserves_memory());
        assert!(!Mode::Mooncake.fc_offload());
        assert!(Mode::Mooncake.reactive_offload());
        assert!(Mode::Parrot.agent_aware());
        assert!(!Mode::Parrot.reserves_memory());
        assert!(!Mode::Vllm.fc_offload());
        assert!(Mode::Infercept.fc_offload());
        assert!(!Mode::OffloadOnly.agent_aware());
        assert!(Mode::AgentOnly.reserves_memory());
        assert!(!Mode::AgentOnly.fc_offload());
        // Prefix CPU tier: only the modes with a host KV store demote.
        assert!(Mode::TokenCake.prefix_cpu_tier());
        assert!(Mode::Mooncake.prefix_cpu_tier());
        assert!(!Mode::VllmPrefix.prefix_cpu_tier());
        assert!(!Mode::Vllm.prefix_cpu_tier());
    }

    #[test]
    fn defaults_match_paper_constants() {
        let p = PolicyConfig::default();
        assert_eq!(p.reserve_init, 0.05);
        assert_eq!(p.reserve_step, 0.05);
        assert_eq!(p.reserve_max, 0.30);
        assert_eq!(p.high_watermark, 0.75);
        assert_eq!(p.low_watermark, 0.40);
        assert_eq!(p.critical_ratio, 0.75);
        assert_eq!(p.selection, SelectionPolicy::FirstFit);
    }

    #[test]
    fn apply_kv_overrides() {
        let mut c = ServeConfig::default();
        c.apply_kv("serve", "mode", "mooncake").unwrap();
        c.apply_kv("policy", "pressure_watermark", "0.08").unwrap();
        c.apply_kv("policy", "selection", "best_fit").unwrap();
        assert_eq!(c.mode, Mode::Mooncake);
        assert_eq!(c.policy.pressure_watermark, 0.08);
        assert_eq!(c.policy.selection, SelectionPolicy::BestFit);
        assert!(c.apply_kv("serve", "mode", "bogus").is_err());
        assert!(c.apply_kv("nope", "x", "1").is_err());
    }

    #[test]
    fn gpu_mem_frac_scales_blocks() {
        let c = ServeConfig::default().with_gpu_mem_frac(0.5);
        assert_eq!(c.gpu_blocks(), c.profile.gpu_blocks / 2);
    }

    #[test]
    fn placement_policy_parse_roundtrip() {
        for p in [
            PlacementPolicy::RoundRobin,
            PlacementPolicy::LeastLoaded,
            PlacementPolicy::AgentAffinity,
        ] {
            assert_eq!(PlacementPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(PlacementPolicy::parse("rr"),
                   Some(PlacementPolicy::RoundRobin));
        assert_eq!(PlacementPolicy::parse("affinity"),
                   Some(PlacementPolicy::AgentAffinity));
        assert_eq!(PlacementPolicy::parse("bogus"), None);
    }

    #[test]
    fn cluster_config_kv_overrides() {
        let mut c = ClusterConfig::default();
        c.apply_kv("cluster", "shards", "4").unwrap();
        c.apply_kv("cluster", "placement", "least-loaded").unwrap();
        c.apply_kv("cluster", "migration", "off").unwrap();
        c.apply_kv("cluster", "interconnect_factor", "3.5").unwrap();
        c.apply_kv("cluster", "prefix_directory", "off").unwrap();
        c.apply_kv("cluster", "prefix_replicate_threshold", "5").unwrap();
        c.apply_kv("cluster", "parallel", "on").unwrap();
        // Non-cluster sections fall through to the per-shard config.
        c.apply_kv("serve", "mode", "vllm").unwrap();
        assert_eq!(c.shards, 4);
        assert_eq!(c.placement, PlacementPolicy::LeastLoaded);
        assert!(!c.migration);
        assert_eq!(c.interconnect_factor, 3.5);
        assert!(!c.prefix_directory);
        assert_eq!(c.prefix_replicate_threshold, 5);
        assert!(c.parallel);
        assert_eq!(c.serve.mode, Mode::Vllm);
        assert!(c.apply_kv("cluster", "shards", "x").is_err());
        assert!(c.apply_kv("cluster", "nope", "1").is_err());
    }

    #[test]
    fn autoscale_section_kv_overrides() {
        let mut c = ClusterConfig::default();
        assert!(!c.autoscale.enabled);
        c.apply_kv("cluster.autoscale", "enabled", "on").unwrap();
        c.apply_kv("cluster.autoscale", "min_shards", "2").unwrap();
        c.apply_kv("cluster.autoscale", "max_shards", "6").unwrap();
        c.apply_kv("cluster.autoscale", "grow_watermark", "0.9").unwrap();
        c.apply_kv("cluster.autoscale", "drain_watermark", "0.2")
            .unwrap();
        c.apply_kv("cluster.autoscale", "warmup_cost_us", "500000")
            .unwrap();
        c.apply_kv("cluster.autoscale", "cooldown_us", "750000")
            .unwrap();
        c.apply_kv("cluster.autoscale", "drain_confirm", "5").unwrap();
        c.apply_kv("cluster.autoscale", "interval_us", "100000")
            .unwrap();
        assert!(c.autoscale.enabled);
        assert_eq!(c.autoscale.min_shards, 2);
        assert_eq!(c.autoscale.max_shards, 6);
        assert_eq!(c.autoscale.grow_watermark, 0.9);
        assert_eq!(c.autoscale.drain_watermark, 0.2);
        assert_eq!(c.autoscale.warmup_cost_us, 500_000);
        assert_eq!(c.autoscale.cooldown_us, 750_000);
        assert_eq!(c.autoscale.drain_confirm, 5);
        assert_eq!(c.autoscale.interval_us, 100_000);
        c.autoscale.validate();
        assert!(c.apply_kv("cluster.autoscale", "nope", "1").is_err());
        assert!(c
            .apply_kv("cluster.autoscale", "min_shards", "x")
            .is_err());
        // Invalid bounds are rejected, not silently clamped.
        assert!(c
            .apply_kv("cluster.autoscale", "min_shards", "0")
            .is_err());
        assert!(c
            .apply_kv("cluster.autoscale", "max_shards", "0")
            .is_err());
    }

    #[test]
    fn faults_section_kv_overrides() {
        let mut c = ClusterConfig::default();
        assert!(!c.faults.enabled);
        c.apply_kv("cluster.faults", "enabled", "on").unwrap();
        c.apply_kv("cluster.faults", "seed", "42").unwrap();
        c.apply_kv("cluster.faults", "crash_schedule", "1@2500;3@6000")
            .unwrap();
        c.apply_kv("cluster.faults", "crashes", "2").unwrap();
        c.apply_kv("cluster.faults", "partitions", "1").unwrap();
        c.apply_kv("cluster.faults", "partition_factor", "3.0")
            .unwrap();
        c.apply_kv("cluster.faults", "partition_hold_us", "25000")
            .unwrap();
        c.apply_kv("cluster.faults", "partition_len_us", "500000")
            .unwrap();
        c.apply_kv("cluster.faults", "window_start_us", "2000000")
            .unwrap();
        c.apply_kv("cluster.faults", "window_len_us", "8000000")
            .unwrap();
        c.apply_kv("cluster.faults", "drop_wire", "on").unwrap();
        assert!(c.faults.enabled);
        assert_eq!(c.faults.seed, 42);
        assert_eq!(c.faults.crash_schedule, "1@2500;3@6000");
        assert_eq!(c.faults.crashes, 2);
        assert_eq!(c.faults.partitions, 1);
        assert_eq!(c.faults.partition_factor, 3.0);
        assert_eq!(c.faults.partition_hold_us, 25_000);
        assert_eq!(c.faults.partition_len_us, 500_000);
        assert_eq!(c.faults.window_start_us, 2_000_000);
        assert_eq!(c.faults.window_len_us, 8_000_000);
        assert!(c.faults.drop_wire);
        c.faults.validate();
        assert!(c.apply_kv("cluster.faults", "nope", "1").is_err());
        // Malformed schedules are rejected at parse time, not at plan
        // build.
        assert!(c
            .apply_kv("cluster.faults", "crash_schedule", "1-2500")
            .is_err());
        assert!(c
            .apply_kv("cluster.faults", "crash_schedule", "x@9")
            .is_err());
    }

    #[test]
    fn qos_section_kv_overrides() {
        let mut c = ClusterConfig::default();
        assert!(!c.qos.enabled);
        c.apply_kv("cluster.qos", "enabled", "on").unwrap();
        c.apply_kv("cluster.qos", "rate_interactive", "6.0").unwrap();
        c.apply_kv("cluster.qos", "rate_standard", "3.0").unwrap();
        c.apply_kv("cluster.qos", "rate_batch", "1.5").unwrap();
        c.apply_kv("cluster.qos", "burst_interactive", "10").unwrap();
        c.apply_kv("cluster.qos", "burst_batch", "3").unwrap();
        c.apply_kv("cluster.qos", "slo_ms_interactive", "1500")
            .unwrap();
        c.apply_kv("cluster.qos", "slo_ms_standard", "6000").unwrap();
        c.apply_kv("cluster.qos", "slo_ms_batch", "45000").unwrap();
        c.apply_kv("cluster.qos", "age_promote_ms", "3000").unwrap();
        c.apply_kv("cluster.qos", "shed_band", "4").unwrap();
        c.apply_kv("cluster.qos", "shed_queue_depth", "12").unwrap();
        assert!(c.qos.enabled);
        assert_eq!(c.qos.rate_per_s, [6.0, 3.0, 1.5]);
        assert_eq!(c.qos.burst[0], 10);
        assert_eq!(c.qos.burst[2], 3);
        assert_eq!(
            c.qos.slo_us,
            [1_500_000, 6_000_000, 45_000_000]
        );
        assert_eq!(c.qos.age_promote_us, 3_000_000);
        assert_eq!(c.qos.shed_band, 4);
        assert_eq!(c.qos.shed_queue_depth, 12);
        c.qos.validate();
        assert!(c.apply_kv("cluster.qos", "nope", "1").is_err());
        assert!(c
            .apply_kv("cluster.qos", "rate_interactive", "x")
            .is_err());
    }

    #[test]
    #[should_panic]
    fn qos_validate_rejects_zero_rate() {
        let q = crate::qos::QosConfig {
            rate_per_s: [0.0, 1.0, 1.0],
            ..Default::default()
        };
        q.validate();
    }

    #[test]
    #[should_panic]
    fn faults_validate_rejects_speedup_factor() {
        let fc = FaultConfig {
            partition_factor: 0.5,
            ..Default::default()
        };
        fc.validate();
    }

    #[test]
    #[should_panic]
    fn autoscale_validate_rejects_inverted_watermarks() {
        let a = AutoscaleConfig {
            grow_watermark: 0.2,
            drain_watermark: 0.8,
            ..Default::default()
        };
        a.validate();
    }

    #[test]
    fn cluster_defaults_sane() {
        let c = ClusterConfig::default();
        assert!(c.shards >= 1);
        assert_eq!(c.placement, PlacementPolicy::AgentAffinity);
        assert!(c.migration);
        assert!(c.migrate_src_usage > c.migrate_dst_usage);
        assert!(c.interconnect_factor >= 1.0);
    }
}
