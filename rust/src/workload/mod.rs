//! Workload generation (§7.1): Poisson application arrivals, synthetic
//! corpora standing in for ShareGPT (D1) and AgentCode (D2), and the
//! simulated MCP tool endpoints with Table 1 latency ranges plus the
//! multiplicative noise injection of §7.5.

mod corpus;
mod tools;

pub use corpus::{Dataset, SampledLengths};
pub use tools::ToolSim;

use crate::graph::AppGraph;
use crate::sim::{Poisson, Rng};

/// A complete workload specification: which app, how often, how many, on
/// which corpus, with how much tool-time noise.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub graph: AppGraph,
    /// Application arrival rate (queries per second, Poisson).
    pub qps: f64,
    /// Number of application instances to submit.
    pub num_apps: usize,
    /// Length-distribution corpus (D1 = ShareGPT-like, D2 = AgentCode-like).
    pub dataset: Dataset,
    /// Multiplicative tool-time noise scale s (§7.5): actual time is drawn
    /// from [t·(1−s), t·(1+s)].
    pub tool_noise: f64,
}

impl WorkloadSpec {
    pub fn poisson(graph: &AppGraph, qps: f64, num_apps: usize) -> Self {
        Self {
            graph: graph.clone(),
            qps,
            num_apps,
            dataset: Dataset::D1,
            tool_noise: 0.0,
        }
    }

    pub fn with_dataset(mut self, d: Dataset) -> Self {
        self.dataset = d;
        self
    }

    pub fn with_tool_noise(mut self, s: f64) -> Self {
        assert!((0.0..1.0).contains(&s), "noise scale in [0,1)");
        self.tool_noise = s;
        self
    }

    /// Generate the arrival schedule: `num_apps` timestamps (µs).
    pub fn arrivals(&self, rng: &mut Rng) -> Vec<u64> {
        let mut p = Poisson::new(self.qps);
        (0..self.num_apps)
            .map(|_| p.next_arrival_us(rng))
            .collect()
    }
}

/// One application template in a cluster mix with its arrival weight.
#[derive(Debug, Clone)]
pub struct MixEntry {
    pub graph: AppGraph,
    /// Relative arrival weight (unnormalized, > 0).
    pub weight: f64,
}

/// A heterogeneous cluster workload: Poisson application arrivals whose
/// template is drawn from a weighted mix (e.g. 2:1 code-writer to
/// deep-research). This is the offered load a `cluster::ClusterEngine`
/// routes across its worker shards.
#[derive(Debug, Clone)]
pub struct ClusterWorkload {
    pub entries: Vec<MixEntry>,
    /// Aggregate application arrival rate across the whole cluster (QPS).
    pub qps: f64,
    pub num_apps: usize,
    pub dataset: Dataset,
    pub tool_noise: f64,
}

impl ClusterWorkload {
    /// Build from `(graph, weight)` pairs.
    pub fn mixed(mix: &[(AppGraph, f64)], qps: f64, num_apps: usize) -> Self {
        assert!(!mix.is_empty(), "cluster workload needs >= 1 template");
        assert!(
            mix.iter().all(|(_, w)| *w > 0.0),
            "mix weights must be positive"
        );
        Self {
            entries: mix
                .iter()
                .map(|(g, w)| MixEntry {
                    graph: g.clone(),
                    weight: *w,
                })
                .collect(),
            qps,
            num_apps,
            dataset: Dataset::D1,
            tool_noise: 0.0,
        }
    }

    /// Single-template convenience (the cluster analogue of
    /// [`WorkloadSpec::poisson`]).
    pub fn uniform(graph: &AppGraph, qps: f64, num_apps: usize) -> Self {
        Self::mixed(&[(graph.clone(), 1.0)], qps, num_apps)
    }

    pub fn with_dataset(mut self, d: Dataset) -> Self {
        self.dataset = d;
        self
    }

    pub fn with_tool_noise(mut self, s: f64) -> Self {
        assert!((0.0..1.0).contains(&s), "noise scale in [0,1)");
        self.tool_noise = s;
        self
    }

    /// Generate the arrival schedule: `(timestamp µs, template index)`
    /// per application, template drawn by mix weight.
    pub fn arrivals(&self, rng: &mut Rng) -> Vec<(u64, usize)> {
        let weights: Vec<f64> =
            self.entries.iter().map(|e| e.weight).collect();
        let mut p = Poisson::new(self.qps);
        (0..self.num_apps)
            .map(|_| {
                (p.next_arrival_us(rng), rng.weighted_index(&weights))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::templates;

    #[test]
    fn arrivals_monotone_and_rate_close() {
        let g = templates::code_writer();
        let spec = WorkloadSpec::poisson(&g, 0.5, 2000);
        let mut rng = Rng::new(9);
        let arr = spec.arrivals(&mut rng);
        assert_eq!(arr.len(), 2000);
        assert!(arr.windows(2).all(|w| w[0] <= w[1]));
        let rate = arr.len() as f64 / (*arr.last().unwrap() as f64 / 1e6);
        assert!((rate - 0.5).abs() < 0.05, "rate={rate}");
    }

    #[test]
    fn arrivals_deterministic_per_seed() {
        let g = templates::rag();
        let spec = WorkloadSpec::poisson(&g, 1.0, 50);
        let a = spec.arrivals(&mut Rng::new(1));
        let b = spec.arrivals(&mut Rng::new(1));
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn rejects_bad_noise() {
        let g = templates::rag();
        let _ = WorkloadSpec::poisson(&g, 1.0, 1).with_tool_noise(1.5);
    }

    #[test]
    fn cluster_mix_respects_weights() {
        let mix = [
            (templates::code_writer(), 3.0),
            (templates::deep_research(), 1.0),
        ];
        let w = ClusterWorkload::mixed(&mix, 1.0, 4000);
        let arr = w.arrivals(&mut Rng::new(5));
        assert_eq!(arr.len(), 4000);
        assert!(arr.windows(2).all(|a| a[0].0 <= a[1].0));
        let cw = arr.iter().filter(|(_, t)| *t == 0).count() as f64;
        let dr = arr.iter().filter(|(_, t)| *t == 1).count() as f64;
        let ratio = cw / dr;
        assert!((2.4..3.6).contains(&ratio), "mix ratio {ratio}");
    }

    #[test]
    fn cluster_arrivals_deterministic_per_seed() {
        let mix = [
            (templates::code_writer(), 1.0),
            (templates::rag(), 1.0),
        ];
        let w = ClusterWorkload::mixed(&mix, 0.5, 100);
        let a = w.arrivals(&mut Rng::new(9));
        let b = w.arrivals(&mut Rng::new(9));
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn cluster_rejects_empty_mix() {
        let _ = ClusterWorkload::mixed(&[], 1.0, 1);
    }
}
