//! Workload generation (§7.1): Poisson application arrivals, synthetic
//! corpora standing in for ShareGPT (D1) and AgentCode (D2), and the
//! simulated MCP tool endpoints with Table 1 latency ranges plus the
//! multiplicative noise injection of §7.5.

mod corpus;
mod tools;

pub use corpus::{Dataset, SampledLengths};
pub use tools::ToolSim;

use crate::graph::AppGraph;
use crate::sim::{Dist, Poisson, Rng};

/// Periodic traffic bursts: the arrival process alternates between a
/// burst rate and the workload's base rate on a fixed period — the
/// flash-crowd pattern that exercises replica autoscaling (grow on the
/// burst, drain in the lull).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BurstSpec {
    /// Arrival rate during the burst phase (QPS, Poisson).
    pub burst_qps: f64,
    /// Length of one base+burst cycle (µs).
    pub period_us: u64,
    /// Fraction of each period (at the start) spent bursting, in (0,1].
    pub duty: f64,
}

impl BurstSpec {
    pub fn validate(&self) {
        assert!(self.burst_qps > 0.0, "burst_qps must be > 0");
        assert!(self.period_us > 0, "burst period must be > 0");
        assert!(
            self.duty > 0.0 && self.duty <= 1.0,
            "burst duty must be in (0,1]"
        );
    }

    fn in_burst(&self, t_us: f64) -> bool {
        let period = self.period_us as f64;
        t_us % period < self.duty * period
    }

    /// Next phase boundary strictly after `t_us`.
    fn next_boundary_us(&self, t_us: f64) -> f64 {
        let period = self.period_us as f64;
        let base = (t_us / period).floor() * period;
        let burst_end = base + self.duty * period;
        if t_us < burst_end {
            burst_end
        } else {
            base + period
        }
    }
}

/// A complete workload specification: which app, how often, how many, on
/// which corpus, with how much tool-time noise.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub graph: AppGraph,
    /// Application arrival rate (queries per second, Poisson).
    pub qps: f64,
    /// Number of application instances to submit.
    pub num_apps: usize,
    /// Length-distribution corpus (D1 = ShareGPT-like, D2 = AgentCode-like).
    pub dataset: Dataset,
    /// Multiplicative tool-time noise scale s (§7.5): actual time is drawn
    /// from [t·(1−s), t·(1+s)].
    pub tool_noise: f64,
}

impl WorkloadSpec {
    pub fn poisson(graph: &AppGraph, qps: f64, num_apps: usize) -> Self {
        Self {
            graph: graph.clone(),
            qps,
            num_apps,
            dataset: Dataset::D1,
            tool_noise: 0.0,
        }
    }

    pub fn with_dataset(mut self, d: Dataset) -> Self {
        self.dataset = d;
        self
    }

    pub fn with_tool_noise(mut self, s: f64) -> Self {
        assert!((0.0..1.0).contains(&s), "noise scale in [0,1)");
        self.tool_noise = s;
        self
    }

    /// Generate the arrival schedule: `num_apps` timestamps (µs).
    pub fn arrivals(&self, rng: &mut Rng) -> Vec<u64> {
        let mut p = Poisson::new(self.qps);
        (0..self.num_apps)
            .map(|_| p.next_arrival_us(rng))
            .collect()
    }
}

/// One application template in a cluster mix with its arrival weight.
#[derive(Debug, Clone)]
pub struct MixEntry {
    pub graph: AppGraph,
    /// Relative arrival weight (unnormalized, > 0).
    pub weight: f64,
    /// QoS tier every app of this template carries from generation
    /// through routing, admission, and victim selection (read by the
    /// cluster layer only when `[cluster.qos]` is enabled).
    pub tier: crate::qos::Tier,
}

/// A heterogeneous cluster workload: Poisson application arrivals whose
/// template is drawn from a weighted mix (e.g. 2:1 code-writer to
/// deep-research). This is the offered load a `cluster::ClusterEngine`
/// routes across its worker shards.
#[derive(Debug, Clone)]
pub struct ClusterWorkload {
    pub entries: Vec<MixEntry>,
    /// Aggregate application arrival rate across the whole cluster (QPS).
    /// With a [`BurstSpec`], this is the *base* (lull) rate.
    pub qps: f64,
    pub num_apps: usize,
    pub dataset: Dataset,
    pub tool_noise: f64,
    /// Optional periodic burst phases layered over the base rate.
    pub burst: Option<BurstSpec>,
}

impl ClusterWorkload {
    /// Build from `(graph, weight)` pairs.
    pub fn mixed(mix: &[(AppGraph, f64)], qps: f64, num_apps: usize) -> Self {
        assert!(!mix.is_empty(), "cluster workload needs >= 1 template");
        assert!(
            mix.iter().all(|(_, w)| *w > 0.0),
            "mix weights must be positive"
        );
        Self {
            entries: mix
                .iter()
                .map(|(g, w)| MixEntry {
                    graph: g.clone(),
                    weight: *w,
                    tier: crate::qos::Tier::default(),
                })
                .collect(),
            qps,
            num_apps,
            dataset: Dataset::D1,
            tool_noise: 0.0,
            burst: None,
        }
    }

    /// Single-template convenience (the cluster analogue of
    /// [`WorkloadSpec::poisson`]).
    pub fn uniform(graph: &AppGraph, qps: f64, num_apps: usize) -> Self {
        Self::mixed(&[(graph.clone(), 1.0)], qps, num_apps)
    }

    pub fn with_dataset(mut self, d: Dataset) -> Self {
        self.dataset = d;
        self
    }

    pub fn with_tool_noise(mut self, s: f64) -> Self {
        assert!((0.0..1.0).contains(&s), "noise scale in [0,1)");
        self.tool_noise = s;
        self
    }

    pub fn with_burst(mut self, b: BurstSpec) -> Self {
        b.validate();
        self.burst = Some(b);
        self
    }

    /// Assign QoS tiers to the mix entries, index-aligned. Shorter
    /// lists leave the remaining entries at the default (Standard).
    pub fn with_tiers(mut self, tiers: &[crate::qos::Tier]) -> Self {
        assert!(
            tiers.len() <= self.entries.len(),
            "more tiers ({}) than mix entries ({})",
            tiers.len(),
            self.entries.len()
        );
        for (e, &t) in self.entries.iter_mut().zip(tiers) {
            e.tier = t;
        }
        self
    }

    /// Tier per template, index-aligned with `entries` (what the
    /// cluster engine registers on its shards).
    pub fn tiers(&self) -> Vec<crate::qos::Tier> {
        self.entries.iter().map(|e| e.tier).collect()
    }

    /// Generate the arrival schedule: `(timestamp µs, template index)`
    /// per application, template drawn by mix weight.
    ///
    /// With a burst spec the process is a piecewise-constant-rate
    /// Poisson, sampled exactly: an exponential draw that would cross a
    /// phase boundary is discarded and redrawn from the boundary at the
    /// new phase's rate (valid by memorylessness), so burst windows see
    /// `burst_qps` and lulls see the base `qps` with no smearing.
    pub fn arrivals(&self, rng: &mut Rng) -> Vec<(u64, usize)> {
        let weights: Vec<f64> =
            self.entries.iter().map(|e| e.weight).collect();
        match self.burst {
            None => {
                let mut p = Poisson::new(self.qps);
                (0..self.num_apps)
                    .map(|_| {
                        (
                            p.next_arrival_us(rng),
                            rng.weighted_index(&weights),
                        )
                    })
                    .collect()
            }
            Some(b) => {
                let mut t_us: f64 = 0.0;
                (0..self.num_apps)
                    .map(|_| {
                        loop {
                            let rate = if b.in_burst(t_us) {
                                b.burst_qps
                            } else {
                                self.qps
                            };
                            let dt =
                                Dist::Exp(1e6 / rate).sample(rng);
                            let boundary = b.next_boundary_us(t_us);
                            if t_us + dt < boundary {
                                t_us += dt;
                                break;
                            }
                            // Crossed into the next phase: restart the
                            // exponential clock at the boundary.
                            t_us = boundary;
                        }
                        (t_us as u64, rng.weighted_index(&weights))
                    })
                    .collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::templates;

    #[test]
    fn arrivals_monotone_and_rate_close() {
        let g = templates::code_writer();
        let spec = WorkloadSpec::poisson(&g, 0.5, 2000);
        let mut rng = Rng::new(9);
        let arr = spec.arrivals(&mut rng);
        assert_eq!(arr.len(), 2000);
        assert!(arr.windows(2).all(|w| w[0] <= w[1]));
        let rate = arr.len() as f64 / (*arr.last().unwrap() as f64 / 1e6);
        assert!((rate - 0.5).abs() < 0.05, "rate={rate}");
    }

    #[test]
    fn arrivals_deterministic_per_seed() {
        let g = templates::rag();
        let spec = WorkloadSpec::poisson(&g, 1.0, 50);
        let a = spec.arrivals(&mut Rng::new(1));
        let b = spec.arrivals(&mut Rng::new(1));
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn rejects_bad_noise() {
        let g = templates::rag();
        let _ = WorkloadSpec::poisson(&g, 1.0, 1).with_tool_noise(1.5);
    }

    #[test]
    fn cluster_mix_respects_weights() {
        let mix = [
            (templates::code_writer(), 3.0),
            (templates::deep_research(), 1.0),
        ];
        let w = ClusterWorkload::mixed(&mix, 1.0, 4000);
        let arr = w.arrivals(&mut Rng::new(5));
        assert_eq!(arr.len(), 4000);
        assert!(arr.windows(2).all(|a| a[0].0 <= a[1].0));
        let cw = arr.iter().filter(|(_, t)| *t == 0).count() as f64;
        let dr = arr.iter().filter(|(_, t)| *t == 1).count() as f64;
        let ratio = cw / dr;
        assert!((2.4..3.6).contains(&ratio), "mix ratio {ratio}");
    }

    #[test]
    fn cluster_arrivals_deterministic_per_seed() {
        let mix = [
            (templates::code_writer(), 1.0),
            (templates::rag(), 1.0),
        ];
        let w = ClusterWorkload::mixed(&mix, 0.5, 100);
        let a = w.arrivals(&mut Rng::new(9));
        let b = w.arrivals(&mut Rng::new(9));
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn cluster_rejects_empty_mix() {
        let _ = ClusterWorkload::mixed(&[], 1.0, 1);
    }

    #[test]
    fn burst_arrivals_concentrate_in_burst_windows() {
        let b = BurstSpec {
            burst_qps: 8.0,
            period_us: 20_000_000,
            duty: 0.25,
        };
        let w = ClusterWorkload::uniform(
            &templates::code_writer(),
            0.5,
            2000,
        )
        .with_burst(b);
        let arr = w.arrivals(&mut Rng::new(3));
        assert_eq!(arr.len(), 2000);
        assert!(arr.windows(2).all(|a| a[0].0 <= a[1].0));
        // A quarter of the time carries 8 QPS, the rest 0.5 QPS: the
        // burst windows must hold the large majority of arrivals
        // (expected fraction 2.0 / 2.375 ≈ 84%).
        let in_burst = arr
            .iter()
            .filter(|(t, _)| (t % 20_000_000) < 5_000_000)
            .count() as f64;
        let frac = in_burst / arr.len() as f64;
        assert!(
            (0.75..0.95).contains(&frac),
            "burst fraction {frac} out of range"
        );
    }

    #[test]
    fn burst_arrivals_deterministic_per_seed() {
        let b = BurstSpec {
            burst_qps: 4.0,
            period_us: 10_000_000,
            duty: 0.3,
        };
        let w = ClusterWorkload::uniform(&templates::rag(), 0.5, 200)
            .with_burst(b);
        let a = w.arrivals(&mut Rng::new(11));
        let bb = w.arrivals(&mut Rng::new(11));
        assert_eq!(a, bb);
    }

    #[test]
    #[should_panic]
    fn burst_rejects_bad_duty() {
        let _ = ClusterWorkload::uniform(&templates::rag(), 1.0, 1)
            .with_burst(BurstSpec {
                burst_qps: 2.0,
                period_us: 1_000_000,
                duty: 1.5,
            });
    }
}
