//! Synthetic corpora (DESIGN.md §3 substitution).
//!
//! The paper synthesizes user requests from ShareGPT (D1) and AgentCode
//! (D2). The schedulers consume only *lengths*, so we reproduce the length
//! marginals: ShareGPT-like conversational prompts are shortish and
//! heavy-tailed; AgentCode-like coding contexts are longer in both prompt
//! and completion. Each app instance draws per-instance scale factors that
//! multiply the template's per-node token counts, preserving the graph's
//! relative structure while matching the corpus distribution.

use crate::sim::{Dist, LogNormal, Rng};

/// Which corpus the workload draws lengths from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataset {
    /// ShareGPT-like: conversational. Median prompt ≈ 220 tokens,
    /// completions a few hundred tokens, heavy tail.
    D1,
    /// AgentCode-like: code contexts. Longer prompts (median ≈ 600) and
    /// longer completions.
    D2,
}

impl Dataset {
    pub fn name(&self) -> &'static str {
        match self {
            Dataset::D1 => "D1-sharegpt",
            Dataset::D2 => "D2-agentcode",
        }
    }

    fn prompt_scale_dist(&self) -> Dist {
        match self {
            Dataset::D1 => Dist::LogNormal(LogNormal {
                median: 1.0,
                sigma: 0.45,
            }),
            Dataset::D2 => Dist::LogNormal(LogNormal {
                median: 1.6,
                sigma: 0.55,
            }),
        }
    }

    fn gen_scale_dist(&self) -> Dist {
        match self {
            Dataset::D1 => Dist::LogNormal(LogNormal {
                median: 1.0,
                sigma: 0.35,
            }),
            Dataset::D2 => Dist::LogNormal(LogNormal {
                median: 1.35,
                sigma: 0.45,
            }),
        }
    }

    /// Draw per-instance scale factors.
    pub fn sample(&self, rng: &mut Rng) -> SampledLengths {
        let clamp = |x: f64| x.clamp(0.25, 6.0);
        SampledLengths {
            prompt_scale: clamp(self.prompt_scale_dist().sample(rng)),
            gen_scale: clamp(self.gen_scale_dist().sample(rng)),
        }
    }
}

/// Per-app-instance length multipliers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SampledLengths {
    pub prompt_scale: f64,
    pub gen_scale: f64,
}

impl SampledLengths {
    pub fn scale_prompt(&self, tokens: u32) -> u32 {
        ((tokens as f64 * self.prompt_scale) as u32).max(1)
    }

    pub fn scale_gen(&self, tokens: u32) -> u32 {
        ((tokens as f64 * self.gen_scale) as u32).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn d2_longer_than_d1_on_average() {
        let mut rng = Rng::new(42);
        let n = 5000;
        let mean = |d: Dataset, rng: &mut Rng| {
            (0..n)
                .map(|_| d.sample(rng).prompt_scale)
                .sum::<f64>()
                / n as f64
        };
        let m1 = mean(Dataset::D1, &mut rng);
        let m2 = mean(Dataset::D2, &mut rng);
        assert!(m2 > m1 * 1.3, "D2 {m2} vs D1 {m1}");
    }

    #[test]
    fn scales_clamped_and_positive() {
        let mut rng = Rng::new(7);
        for _ in 0..2000 {
            let s = Dataset::D2.sample(&mut rng);
            assert!(s.prompt_scale >= 0.25 && s.prompt_scale <= 6.0);
            assert!(s.scale_prompt(100) >= 1);
            assert!(s.scale_gen(0) >= 1); // never zero-length
        }
    }

    #[test]
    fn median_prompt_scale_near_nominal() {
        let mut rng = Rng::new(3);
        let mut xs: Vec<f64> = (0..4001)
            .map(|_| Dataset::D1.sample(&mut rng).prompt_scale)
            .collect();
        xs.sort_by(|a, b| a.total_cmp(b));
        let med = xs[2000];
        assert!((med - 1.0).abs() < 0.1, "median={med}");
    }
}
