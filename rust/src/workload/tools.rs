//! Simulated MCP tool endpoints.
//!
//! The paper deploys real tool servers matching Table 1's latency ranges;
//! here each tool invocation samples its kind's latency distribution, then
//! applies the §7.5 multiplicative noise: at noise scale *s* the actual
//! execution time is drawn from [t·(1−s), t·(1+s)].
//!
//! Stage decomposition (§3.1 FuncNode): a call with k stages reports
//! progress at k−1 intermediate points; the Temporal Scheduler can use the
//! stage boundaries as refined progress signals for upload timing.

use crate::graph::CallSpec;
#[cfg(test)]
use crate::graph::FuncKind;
use crate::sim::Rng;

/// Stateless sampler for tool execution times.
#[derive(Debug, Clone)]
pub struct ToolSim {
    /// §7.5 noise scale s ∈ [0, 1).
    pub noise: f64,
}

/// A sampled tool execution: the true duration and its stage boundaries.
#[derive(Debug, Clone, PartialEq)]
pub struct ToolExecution {
    pub duration_us: u64,
    /// Elapsed-time offsets (µs) at which each stage completes; the last
    /// equals `duration_us`.
    pub stage_ends_us: Vec<u64>,
}

impl ToolSim {
    pub fn new(noise: f64) -> Self {
        assert!((0.0..1.0).contains(&noise));
        Self { noise }
    }

    /// Sample the actual execution time for one call.
    pub fn sample(&self, call: &CallSpec, rng: &mut Rng) -> ToolExecution {
        let base = call.kind.latency().dist.sample(rng).max(1_000.0);
        let noisy = if self.noise > 0.0 {
            base * rng.range_f64(1.0 - self.noise, 1.0 + self.noise)
        } else {
            base
        };
        let duration_us = noisy.max(1_000.0) as u64;
        let stages = call.stages.max(1) as u64;
        let stage_ends_us = (1..=stages)
            .map(|i| duration_us * i / stages)
            .collect();
        ToolExecution {
            duration_us,
            stage_ends_us,
        }
    }

    /// The estimate the scheduler would use *before* any history exists:
    /// the user's `predict_time` if present, else the tool-kind mean.
    pub fn prior_estimate_us(call: &CallSpec) -> u64 {
        call.predict_time_us
            .unwrap_or_else(|| call.kind.latency().mean_us() as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn call(kind: FuncKind) -> CallSpec {
        CallSpec::new(kind)
    }

    #[test]
    fn zero_noise_tracks_distribution() {
        let sim = ToolSim::new(0.0);
        let mut rng = Rng::new(1);
        let c = call(FuncKind::FileRead);
        let n = 4000;
        let mean: f64 = (0..n)
            .map(|_| sim.sample(&c, &mut rng).duration_us as f64)
            .sum::<f64>()
            / n as f64;
        // File system: uniform 50–150 ms → mean ≈ 100 ms.
        assert!((mean - 100_000.0).abs() < 3_000.0, "mean={mean}");
    }

    #[test]
    fn noise_widens_spread() {
        let mut rng = Rng::new(2);
        let c = call(FuncKind::Database);
        let spread = |s: f64, rng: &mut Rng| {
            let sim = ToolSim::new(s);
            let xs: Vec<f64> = (0..3000)
                .map(|_| sim.sample(&c, rng).duration_us as f64)
                .collect();
            let m = xs.iter().sum::<f64>() / xs.len() as f64;
            (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
                / xs.len() as f64)
                .sqrt()
        };
        let s0 = spread(0.0, &mut rng);
        let s5 = spread(0.5, &mut rng);
        assert!(s5 > s0 * 1.05, "s0={s0} s5={s5}");
    }

    #[test]
    fn stages_partition_duration() {
        let sim = ToolSim::new(0.0);
        let mut rng = Rng::new(3);
        let c = call(FuncKind::DataAnalysis).with_stages(4);
        let e = sim.sample(&c, &mut rng);
        assert_eq!(e.stage_ends_us.len(), 4);
        assert_eq!(*e.stage_ends_us.last().unwrap(), e.duration_us);
        assert!(e.stage_ends_us.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn prior_estimate_prefers_user_hint() {
        let c = call(FuncKind::WebSearch).with_predict_time_us(42);
        assert_eq!(ToolSim::prior_estimate_us(&c), 42);
        let c2 = call(FuncKind::WebSearch);
        assert!(ToolSim::prior_estimate_us(&c2) > 1_000_000);
    }

    #[test]
    fn durations_never_zero() {
        let sim = ToolSim::new(0.9);
        let mut rng = Rng::new(4);
        let c = call(FuncKind::FileRead);
        for _ in 0..500 {
            assert!(sim.sample(&c, &mut rng).duration_us >= 1_000);
        }
    }
}
