//! TokenCake leader binary.
//!
//! ```text
//! tokencake bench   --app code-writer --mode tokencake --qps 0.5 --apps 20
//!                   [--frac 0.05] [--dataset d1|d2] [--noise 0.25]
//!                   [--seed N] [--config file.toml] [--json BENCH_1.json]
//! tokencake compare --app code-writer --qps 0.5 --apps 20 [--frac 0.05]
//! tokencake cluster --shards 4 [--policy affinity|least|rr]
//!                   [--mix cw:2,dr:1] [--qps 1.0] [--apps 40]
//!                   [--frac 0.08] [--no-migrate] [--seed N]
//!                   [--autoscale --min-shards 1 --max-shards 8]
//!                   [--burst-qps 6.0 --burst-period-s 60 --burst-duty 0.25]
//!                   [--crash "1@2500;3@6000" --crashes 1 --partitions 1
//!                    --fault-seed 7 --assert-recovery]
//!                   [--qos --tiers interactive,batch
//!                    --qos-rates 4,2,1 --slo-ms 2000,8000,30000
//!                    --qos-shed-band 3 --qos-shed-depth 4
//!                    --qos-age-ms 2000 --assert-qos]
//!                   [--metrics-out metrics.prom] [--assert-attrib]
//! tokencake audit   --trace out.json [--summary]
//! tokencake analyze --trace out.json
//! tokencake serve   [--port 8080]
//! tokencake graph   --app deep-research
//! tokencake help
//! ```

use tokencake::cli::Args;
use tokencake::cluster::{ClusterEngine, ClusterReport};
use tokencake::config::{
    ClusterConfig, Mode, PlacementPolicy, ServeConfig,
};
use tokencake::engine::sim::SimEngine;
use tokencake::graph::{templates, AppGraph};
use tokencake::server::Server;
use tokencake::workload::{
    BurstSpec, ClusterWorkload, Dataset, WorkloadSpec,
};

fn app_by_name(name: &str) -> Result<AppGraph, String> {
    Ok(match name {
        "code-writer" | "cw" => templates::code_writer(),
        "deep-research" | "dr" => templates::deep_research(),
        "rag" => templates::rag(),
        other => return Err(format!("unknown app {other:?}")),
    })
}

/// Apply serve-level CLI flags (mode/frac/seed/profile) onto a config;
/// flags always override whatever a `--config` file set.
fn apply_serve_flags(
    args: &Args,
    cfg: &mut ServeConfig,
) -> Result<(), String> {
    if let Some(m) = args.get("mode") {
        cfg.mode = Mode::parse(m).ok_or(format!("unknown mode {m:?}"))?;
    }
    cfg.gpu_mem_frac = args.get_f64("frac", cfg.gpu_mem_frac)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    if let Some(p) = args.get("profile") {
        cfg.profile = tokencake::config::ModelProfile::by_name(p)
            .ok_or(format!("unknown profile {p:?}"))?;
    }
    Ok(())
}

fn build_config(args: &Args) -> Result<ServeConfig, String> {
    let mut cfg = ServeConfig::default();
    if let Some(path) = args.get("config") {
        cfg.apply_file(path).map_err(|e| e.to_string())?;
    }
    apply_serve_flags(args, &mut cfg)?;
    Ok(cfg)
}

fn build_spec(args: &Args, graph: &AppGraph) -> Result<WorkloadSpec, String> {
    let qps = args.get_f64("qps", 0.5)?;
    let apps = args.get_u64("apps", 20)? as usize;
    let dataset = match args.get_or("dataset", "d1") {
        "d1" | "D1" => Dataset::D1,
        "d2" | "D2" => Dataset::D2,
        other => return Err(format!("unknown dataset {other:?}")),
    };
    let noise = args.get_f64("noise", 0.0)?;
    Ok(WorkloadSpec::poisson(graph, qps, apps)
        .with_dataset(dataset)
        .with_tool_noise(noise))
}

fn cmd_bench(args: &Args) -> Result<(), String> {
    let graph = app_by_name(args.get_or("app", "code-writer"))?;
    let cfg = build_config(args)?;
    let spec = build_spec(args, &graph)?;
    let mut eng = SimEngine::new(cfg.clone());
    if args.get("trace").is_some() {
        eng.enable_trace();
    }
    let report = eng.run_workload(&spec);
    println!("{}", report.summary());
    if report.truncated {
        eprintln!("warning: run truncated before completion");
    }
    if let Some(path) = args.get("trace") {
        std::fs::write(path, eng.export_trace())
            .map_err(|e| e.to_string())?;
        println!("wrote trace to {path}");
    }
    if let Some(path) = args.get("json") {
        write_bench_trajectory(path, args, &cfg)?;
        println!("wrote benchmark trajectory to {path}");
    }
    Ok(())
}

/// Machine-readable benchmark trajectory: single-worker vs an N-shard
/// agent-affinity cluster (`--shards`, default 4) under the same offered
/// load (throughput, mean/p99 latency, effective GPU utilization), plus
/// the hot-path `sim_throughput` metric — wall-clock simulated-events/sec
/// (scheduling steps + executed decode iterations) and ticks/sec
/// (scheduling steps) — and the epoch-gating/batching headlines
/// (`planner_runs_per_1k_ticks`, `mean_migration_batch`) and the
/// latency-attribution headlines (`stall_hidden_frac`,
/// `exposed_upload_us_p99`, `queue_wait_us_p99`). The app mix is
/// always the standard 2:1 code-writer:deep-research cluster workload
/// (independent of `--app`); dataset and noise follow the flags and are
/// recorded in the output.
fn write_bench_trajectory(
    path: &str,
    args: &Args,
    cfg: &ServeConfig,
) -> Result<(), String> {
    let qps = args.get_f64("qps", 0.5)?;
    let apps = args.get_u64("apps", 20)? as usize;
    let shards = args.get_u64("shards", 4)? as usize;
    if shards == 0 {
        return Err("--shards must be >= 1".into());
    }
    let dataset = match args.get_or("dataset", "d1") {
        "d1" | "D1" => Dataset::D1,
        "d2" | "D2" => Dataset::D2,
        other => return Err(format!("unknown dataset {other:?}")),
    };
    let noise = args.get_f64("noise", 0.0)?;
    let mix = [
        (templates::code_writer(), 2.0),
        (templates::deep_research(), 1.0),
    ];
    let workload = ClusterWorkload::mixed(&mix, qps, apps)
        .with_dataset(dataset)
        .with_tool_noise(noise);

    let mut rows: Vec<String> = Vec::new();
    let mut row = |name: &str, rep: &ClusterReport, wall_s: f64| {
        rows.push(bench_row(name, rep, wall_s));
    };

    let single = ClusterConfig::default()
        .with_serve(cfg.clone())
        .with_shards(1)
        .with_placement(PlacementPolicy::RoundRobin);
    let t0 = std::time::Instant::now();
    let rep = ClusterEngine::new(single).run(&workload);
    row("single-worker", &rep, t0.elapsed().as_secs_f64());

    let multi = ClusterConfig::default()
        .with_serve(cfg.clone())
        .with_shards(shards)
        .with_placement(PlacementPolicy::AgentAffinity);
    let t0 = std::time::Instant::now();
    let rep = ClusterEngine::new(multi).run(&workload);
    row(
        &format!("cluster-{shards}-affinity"),
        &rep,
        t0.elapsed().as_secs_f64(),
    );

    let json = format!(
        "{{\n  \"benchmark\": \"tokencake_trajectory\",\n  \
         \"qps\": {qps},\n  \"apps\": {apps},\n  \
         \"dataset\": \"{}\",\n  \"tool_noise\": {noise},\n  \
         \"mix\": \"code-writer:2,deep-research:1\",\n  \
         \"runs\": [\n{}\n  ]\n}}\n",
        dataset.name(),
        rows.join(",\n")
    );
    std::fs::write(path, json).map_err(|e| e.to_string())
}

/// One machine-readable benchmark row for a cluster run. Shared by
/// `bench --json` (trajectory) and `cluster --json` (single run): the
/// autoscale fields are zero/fixed for a fixed fleet.
fn bench_row(name: &str, rep: &ClusterReport, wall_s: f64) -> String {
    let ticks = rep.aggregate.counters.sched_steps;
    let events = ticks + rep.aggregate.counters.decode_iterations;
    let wall = wall_s.max(1e-9);
    // Mean migration batch pools the cluster planner's windows with
    // the per-shard temporal planners' local D2H offload batches.
    let batches =
        rep.migration_batches + rep.aggregate.counters.offload_batches;
    let batch_victims =
        rep.migrations + rep.aggregate.counters.offload_batch_victims;
    let mean_batch = if batches == 0 {
        0.0
    } else {
        batch_victims as f64 / batches as f64
    };
    // For an elastic run, "shards" is the fleet that was SERVING at the
    // end, comparable with fixed-fleet rows; provisioned capacity is
    // implied by the autoscale fields.
    let shards = if rep.autoscale_enabled {
        rep.final_active_shards
    } else {
        rep.num_shards
    };
    let [p50, p99, p999] =
        rep.aggregate.latency.percentiles_s([50.0, 99.0, 99.9]);
    format!(
        "    {{\"name\": \"{name}\", \"shards\": {shards}, \
         \"policy\": \"{}\", \"apps\": {}, \
         \"throughput_apps_per_s\": {:.6}, \
         \"mean_latency_s\": {:.3}, \"p50_latency_s\": {:.3}, \
         \"p99_latency_s\": {:.3}, \"p999_latency_s\": {:.3}, \
         \"effective_gpu_util\": {:.4}, \"migrations\": {}, \
         \"wall_s\": {:.3}, \"sim_events_per_s\": {:.0}, \
         \"sim_ticks_per_s\": {:.0}, \
         \"planner_runs_per_1k_ticks\": {:.2}, \
         \"mean_migration_batch\": {:.2}, \
         \"stall_hidden_frac\": {:.4}, \
         \"exposed_upload_us_p99\": {}, \
         \"queue_wait_us_p99\": {}, \
         \"prefix_hit_rate_local\": {:.4}, \
         \"prefix_hit_rate_remote\": {:.4}, \
         \"prefill_tokens_saved\": {}, \
         \"prefix_replications\": {}, \
         \"crashes\": {}, \"crash_requeued_apps\": {}, \
         \"crash_requeue_tokens\": {}, \"crash_lost_blocks\": {}, \
         \"qos\": {}, \"qos_shed\": [{}], \"qos_starved\": {}, \
         \"tier_p99_s\": [{}], \
         \"autoscale\": {}, \"final_shards\": {}, \
         \"scale_up_events\": {}, \"scale_down_events\": {}, \
         \"shards_retired\": {}, \"drained_app_blocks\": {}, \
         \"drained_prefix_blocks\": {}, \
         \"shard_lifetimes_s\": [{}], \"truncated\": {}}}",
        rep.policy,
        rep.aggregate.apps_completed,
        rep.aggregate.throughput(),
        rep.aggregate.latency.mean_s(),
        p50,
        p99,
        p999,
        rep.effective_util(),
        rep.migrations,
        wall_s,
        events as f64 / wall,
        ticks as f64 / wall,
        rep.aggregate.counters.planner_runs_per_1k_ticks(),
        mean_batch,
        rep.aggregate.stall_hidden_frac(),
        rep.aggregate.exposed_upload_us_p99(),
        rep.aggregate.queue_wait_us_p99(),
        rep.aggregate.counters.prefix_hit_rate_local(),
        rep.aggregate.counters.prefix_hit_rate_remote(),
        rep.aggregate.counters.prefill_tokens_saved,
        rep.prefix_replications,
        rep.crashes,
        rep.crash_requeued_apps,
        rep.crash_requeued_tokens,
        rep.crash_lost_app_blocks
            + rep.crash_lost_prefix_blocks
            + rep.crash_lost_wire_blocks,
        rep.qos_enabled,
        rep.qos_shed
            .map(|v| v.to_string())
            .join(", "),
        rep.qos_starved,
        rep.tier_p99_us
            .map(|v| format!("{:.3}", v as f64 / 1e6))
            .join(", "),
        rep.autoscale_enabled,
        rep.final_active_shards,
        rep.scale_up_events,
        rep.scale_down_events,
        rep.shards_retired,
        rep.drained_app_blocks,
        rep.drained_prefix_blocks,
        rep.shard_lifetimes_us
            .iter()
            .map(|l| format!("{:.1}", *l as f64 / 1e6))
            .collect::<Vec<_>>()
            .join(", "),
        rep.truncated,
    )
}

/// Parse a `--flag a,b,c` per-tier triplet, ordered
/// interactive,standard,batch.
fn parse_tier_triplet(
    flag: &str,
    s: &str,
) -> Result<[f64; tokencake::qos::TIERS], String> {
    let parts: Vec<f64> = s
        .split(',')
        .map(|p| {
            p.trim()
                .parse::<f64>()
                .map_err(|_| format!("--{flag}: bad number {p:?}"))
        })
        .collect::<Result<_, _>>()?;
    match <[f64; tokencake::qos::TIERS]>::try_from(parts) {
        Ok(t) => Ok(t),
        Err(_) => Err(format!(
            "--{flag} needs {} comma-separated values \
             (interactive,standard,batch)",
            tokencake::qos::TIERS
        )),
    }
}

/// Parse `--mix cw:2,dr:1` into weighted graph templates.
fn parse_mix(spec: &str) -> Result<Vec<(AppGraph, f64)>, String> {
    let mut out = Vec::new();
    for part in spec.split(',') {
        let (name, weight) = match part.split_once(':') {
            Some((n, w)) => (
                n,
                w.parse::<f64>()
                    .map_err(|_| format!("bad mix weight {w:?}"))?,
            ),
            None => (part, 1.0),
        };
        if weight <= 0.0 {
            return Err(format!("mix weight must be > 0: {part:?}"));
        }
        out.push((app_by_name(name)?, weight));
    }
    if out.is_empty() {
        return Err("empty --mix".into());
    }
    Ok(out)
}

fn cmd_cluster(args: &Args) -> Result<(), String> {
    // File first (both [serve]/[policy] and [cluster] sections land via
    // the cluster-aware parser), then CLI flags override.
    let mut cluster = ClusterConfig::default();
    if let Some(path) = args.get("config") {
        cluster.apply_file(path).map_err(|e| e.to_string())?;
    }
    apply_serve_flags(args, &mut cluster.serve)?;
    cluster.shards = args.get_u64("shards", cluster.shards as u64)? as usize;
    if cluster.shards == 0 {
        return Err("--shards must be >= 1".into());
    }
    if let Some(p) = args.get("policy") {
        cluster.placement = PlacementPolicy::parse(p)
            .ok_or("unknown --policy (rr | least | affinity)")?;
    }
    if args.has("no-migrate") {
        cluster.migration = false;
    }
    // Execution mode: --parallel runs the shard-local phases on scoped
    // worker threads; --serial forces the single-thread oracle (the
    // default). Both modes are byte-identical per seed — enforceable
    // in-run with --assert-parity.
    if args.has("parallel") {
        cluster.parallel = true;
    }
    if args.has("serial") {
        cluster.parallel = false;
    }
    // Elastic autoscaling: --autoscale flips it on; the bounds and
    // controller constants are flag-overridable on top of the
    // [cluster.autoscale] file section.
    if args.has("autoscale") {
        cluster.autoscale.enabled = true;
    }
    cluster.autoscale.min_shards = args
        .get_u64("min-shards", cluster.autoscale.min_shards as u64)?
        as usize;
    cluster.autoscale.max_shards = args
        .get_u64("max-shards", cluster.autoscale.max_shards as u64)?
        as usize;
    cluster.autoscale.grow_watermark = args
        .get_f64("grow-watermark", cluster.autoscale.grow_watermark)?;
    cluster.autoscale.drain_watermark = args
        .get_f64("drain-watermark", cluster.autoscale.drain_watermark)?;
    // Only override when the flag is present: the ms→µs round trip
    // must not silently truncate a sub-millisecond config-file value.
    if args.get("warmup-ms").is_some() {
        cluster.autoscale.warmup_cost_us =
            args.get_u64("warmup-ms", 0)? * 1000;
    }
    if args.get("cooldown-ms").is_some() {
        cluster.autoscale.cooldown_us =
            args.get_u64("cooldown-ms", 0)? * 1000;
    }
    // Deterministic fault injection: an explicit --crash schedule
    // and/or randomly placed --crashes/--partitions, all derived from
    // the fault seed (0 = derive from the workload seed). Any fault
    // flag flips injection on.
    if args.has("faults") {
        cluster.faults.enabled = true;
    }
    if let Some(s) = args.get("crash") {
        cluster.faults.enabled = true;
        cluster.faults.crash_schedule = s.to_string();
    }
    if args.get("crashes").is_some() {
        cluster.faults.enabled = true;
        cluster.faults.crashes = args.get_u64("crashes", 0)? as u32;
    }
    if args.get("partitions").is_some() {
        cluster.faults.enabled = true;
        cluster.faults.partitions =
            args.get_u64("partitions", 0)? as u32;
    }
    cluster.faults.seed =
        args.get_u64("fault-seed", cluster.faults.seed)?;
    cluster.faults.partition_factor = args
        .get_f64("partition-factor", cluster.faults.partition_factor)?;
    if args.has("drop-wire") {
        cluster.faults.drop_wire = true;
    }
    if cluster.faults.enabled && cluster.faults.partition_factor < 1.0 {
        return Err(
            "--partition-factor must be >= 1.0 (a straggler never \
             speeds the wire up)"
                .into(),
        );
    }
    // Multi-tenant QoS: --qos flips the admission gate on; the per-tier
    // knobs are flag-overridable on top of the [cluster.qos] file
    // section (any QoS flag also flips the gate on).
    if args.has("qos") {
        cluster.qos.enabled = true;
    }
    if let Some(s) = args.get("qos-rates") {
        cluster.qos.enabled = true;
        cluster.qos.rate_per_s = parse_tier_triplet("qos-rates", s)?;
    }
    if let Some(s) = args.get("slo-ms") {
        cluster.qos.enabled = true;
        let ms = parse_tier_triplet("slo-ms", s)?;
        cluster.qos.slo_us = ms.map(|m| (m * 1000.0) as u64);
    }
    if args.get("qos-shed-band").is_some() {
        cluster.qos.shed_band =
            args.get_u64("qos-shed-band", 0)? as u8;
    }
    if args.get("qos-shed-depth").is_some() {
        cluster.qos.shed_queue_depth =
            args.get_u64("qos-shed-depth", 0)? as usize;
    }
    if args.get("qos-age-ms").is_some() {
        cluster.qos.age_promote_us =
            args.get_u64("qos-age-ms", 0)? * 1000;
    }
    // Validate with the CLI's normal error path, mirroring autoscale.
    if cluster.qos.enabled {
        if cluster.qos.rate_per_s.iter().any(|&r| r <= 0.0) {
            return Err(
                "--qos-rates: every tier rate must be > 0".into()
            );
        }
        if cluster.qos.slo_us.iter().any(|&s| s == 0) {
            return Err("--slo-ms: every tier SLO must be > 0".into());
        }
        if cluster.qos.shed_band > 4 {
            return Err(
                "--qos-shed-band must be <= 4 (pressure bands are \
                 0..=4)"
                    .into(),
            );
        }
    }
    // Validate here with the CLI's normal error path — the engine's
    // own validate() is an assert meant for programmatic misuse.
    if cluster.autoscale.enabled {
        let a = &cluster.autoscale;
        if a.min_shards < 1 {
            return Err("--min-shards must be >= 1".into());
        }
        if a.min_shards > a.max_shards {
            return Err(format!(
                "--min-shards ({}) must be <= --max-shards ({})",
                a.min_shards, a.max_shards
            ));
        }
        if a.drain_watermark >= a.grow_watermark {
            return Err(format!(
                "--drain-watermark ({}) must be below \
                 --grow-watermark ({}) — the hysteresis band",
                a.drain_watermark, a.grow_watermark
            ));
        }
    }
    let (shards, policy) = (cluster.shards, cluster.placement);
    let faults_on = cluster.faults.enabled;

    let qps = args.get_f64("qps", 1.0)?;
    let apps = args.get_u64("apps", 40)? as usize;
    let mix = parse_mix(args.get_or("mix", "cw:2,dr:1"))?;
    let dataset = match args.get_or("dataset", "d1") {
        "d1" | "D1" => Dataset::D1,
        "d2" | "D2" => Dataset::D2,
        other => return Err(format!("unknown dataset {other:?}")),
    };
    let noise = args.get_f64("noise", 0.0)?;
    let mut workload = ClusterWorkload::mixed(&mix, qps, apps)
        .with_dataset(dataset)
        .with_tool_noise(noise);
    // Per-template QoS tiers: --tiers interactive,batch labels the
    // --mix entries in order (unlisted entries stay Standard).
    if let Some(s) = args.get("tiers") {
        let tiers = tokencake::qos::parse_tier_list(s)?;
        if tiers.len() > mix.len() {
            return Err(format!(
                "--tiers lists {} tiers for {} mix entries",
                tiers.len(),
                mix.len()
            ));
        }
        workload = workload.with_tiers(&tiers);
    }
    // Bursty arrival phases (--burst-qps N [--burst-period-s P]
    // [--burst-duty D]): the flash-crowd workload autoscaling exists
    // for.
    if let Some(bq) = args.get("burst-qps") {
        let burst_qps: f64 = bq
            .parse()
            .map_err(|_| format!("--burst-qps: bad number {bq:?}"))?;
        let period_s = args.get_f64("burst-period-s", 60.0)?;
        let duty = args.get_f64("burst-duty", 0.25)?;
        workload = workload.with_burst(BurstSpec {
            burst_qps,
            period_us: (period_s * 1e6) as u64,
            duty,
        });
    }

    let autoscale_on = cluster.autoscale.enabled;
    let (min_s, max_s) =
        (cluster.autoscale.min_shards, cluster.autoscale.max_shards);
    println!(
        "cluster: {shards} shard(s), policy={}, migration={}, \
         autoscale={}, mode={}, qps={qps}, apps={apps}, mix={}",
        policy.name(),
        cluster.migration,
        if autoscale_on {
            format!("{min_s}..{max_s}")
        } else {
            "off".into()
        },
        if cluster.parallel { "parallel" } else { "serial" },
        args.get_or("mix", "cw:2,dr:1"),
    );
    // The parity oracle re-runs the identical workload in the opposite
    // execution mode; snapshot the config before the engine takes it.
    let parity_cfg = if args.has("assert-parity") {
        let mut c = cluster.clone();
        c.parallel = !c.parallel;
        Some(c)
    } else {
        None
    };
    let mut eng = ClusterEngine::new(cluster);
    // --assert-attrib needs full capture: its second half re-derives
    // the phase ledgers from the exported trace and byte-compares them
    // against the live ones.
    if args.get("trace").is_some() || args.has("assert-attrib") {
        eng.enable_trace();
    }
    if args.has("assert-autoscale")
        || args.has("assert-planner-gated")
        || args.has("assert-recovery")
        || args.has("assert-qos")
        || args.has("assert-parity")
        || args.has("assert-attrib")
    {
        // Assert runs arm the flight recorder so a failure ships its
        // recent-event ring (full capture stays off unless --trace).
        eng.arm_flight();
    }
    let t0 = std::time::Instant::now();
    let report = eng.run(&workload);
    let wall_s = t0.elapsed().as_secs_f64();
    for line in report.shard_lines() {
        println!("{line}");
    }
    println!("{}", report.summary());
    let c = &report.aggregate.counters;
    println!(
        "planner: runs={} skips={} ({:.1}/1k ticks) spatial_plans={} \
         spatial_skips={} mean_migration_batch={:.2}",
        c.planner_runs,
        c.planner_skips,
        c.planner_runs_per_1k_ticks(),
        c.spatial_plans,
        c.spatial_plan_skips,
        report.mean_migration_batch(),
    );
    println!(
        "prefix: lookups={} hit_local={:.2} hit_remote={:.2} \
         saved_tokens={} replications={} evict={} demote={}",
        c.prefix_lookups,
        c.prefix_hit_rate_local(),
        c.prefix_hit_rate_remote(),
        c.prefill_tokens_saved,
        report.prefix_replications,
        c.prefix_evictions,
        c.prefix_demotions,
    );
    if report.autoscale_enabled {
        println!(
            "autoscale: up={} down={} cancels={} retired={} \
             final_active={} drained_app_blocks={} \
             drained_prefix_blocks={} (dropped {}) lifetimes_s=[{}]",
            report.scale_up_events,
            report.scale_down_events,
            report.drain_cancels,
            report.shards_retired,
            report.final_active_shards,
            report.drained_app_blocks,
            report.drained_prefix_blocks,
            report.drained_prefix_dropped_blocks,
            report
                .shard_lifetimes_us
                .iter()
                .map(|l| format!("{:.1}", *l as f64 / 1e6))
                .collect::<Vec<_>>()
                .join(", "),
        );
    }
    if report.faults_enabled {
        println!(
            "faults: crashes={} requeued_apps={} requeue_tokens={} \
             lost_app={} lost_prefix={} (sole {}) lost_wire={} \
             replica_drops={} settled={}+{}",
            report.crashes,
            report.crash_requeued_apps,
            report.crash_requeued_tokens,
            report.crash_lost_app_blocks,
            report.crash_lost_prefix_blocks,
            report.crash_sole_prefix_blocks,
            report.crash_lost_wire_blocks,
            report.crash_replica_drop_blocks,
            report.settle_landed_transfers,
            report.settle_dropped_transfers,
        );
    }
    if report.qos_enabled {
        let j = |a: &[u64; tokencake::qos::TIERS]| {
            a.map(|v| v.to_string()).join(",")
        };
        println!(
            "qos: arrivals=[{}] admitted=[{}] deferred=[{}] \
             shed=[{}] aged=[{}] starved={} tier_p99_s=[{}] \
             slo_s=[{}]",
            j(&report.qos_arrivals),
            j(&report.qos_admitted),
            j(&report.qos_deferred),
            j(&report.qos_shed),
            j(&report.qos_aged),
            report.qos_starved,
            report
                .tier_p99_us
                .map(|v| format!("{:.1}", v as f64 / 1e6))
                .join(","),
            report
                .qos_slo_us
                .map(|v| format!("{:.0}", v as f64 / 1e6))
                .join(","),
        );
    }
    if report.truncated {
        eprintln!("warning: cluster run truncated before completion");
    }
    if let Some(path) = args.get("trace") {
        std::fs::write(path, eng.export_trace())
            .map_err(|e| e.to_string())?;
        println!("wrote trace to {path}");
    }
    if let Some(path) = args.get("json") {
        let name = args.get_or("json-name", "cluster-run");
        let json = format!("{}\n", bench_row(name, &report, wall_s));
        std::fs::write(path, json).map_err(|e| e.to_string())?;
        println!("wrote run row to {path}");
    }
    if let Some(path) = args.get("metrics-out") {
        std::fs::write(path, report.prometheus_text())
            .map_err(|e| e.to_string())?;
        println!("wrote Prometheus metrics to {path}");
    }
    if args.has("assert-attrib") {
        // CI attribution smoke: every finished request's phase ledger
        // must tile its wall time exactly (Σ phases == end-to-end
        // latency, no gaps or overlaps), and rebuilding the ledgers
        // from the exported trace alone must reproduce the live ones
        // byte-for-byte.
        eng.check_attrib()?;
        let n = eng.render_ledgers().lines().count();
        println!(
            "attrib OK: {n} request ledger(s) conserve and match the \
             trace-derived reconstruction (stall_hidden_frac={:.3})",
            report.aggregate.stall_hidden_frac(),
        );
    }
    if args.has("assert-autoscale") {
        // CI smoke: the elastic fleet must respect its bounds and lose
        // nothing — every shard's pool conserved, every migrated block
        // landed or dropped-to-recompute, nothing in flight.
        if !autoscale_on {
            return Err(
                "--assert-autoscale requires --autoscale".to_string()
            );
        }
        let serving = report.final_active_shards;
        if serving < min_s || serving > max_s {
            return Err(format!(
                "autoscale out of bounds: {serving} serving shards \
                 not in [{min_s}, {max_s}]\n\
                 --- flight recorder (newest last) ---\n{}",
                eng.flight_dump()
            ));
        }
        eng.check_conservation()?;
        println!(
            "autoscale OK: {serving} serving in [{min_s}, {max_s}], \
             zero lost blocks ({} migrated = {} landed + dropped)",
            report.migration_blocks,
            report.migration_landed_blocks
                + report.migration_drop_blocks,
        );
    }
    if args.has("assert-recovery") {
        // CI fault smoke: a crash must lose nothing silently — every
        // planned crash executed, every application still completed
        // (re-queued ones included), and block conservation holds with
        // the crash-loss ledger folded in.
        if !faults_on {
            return Err("--assert-recovery requires fault injection \
                        (--crash / --crashes / --faults)"
                .to_string());
        }
        if report.crashes == 0 {
            return Err(format!(
                "no crash executed — schedule outside the run window \
                 or no survivor to crash into?\n\
                 --- flight recorder (newest last) ---\n{}",
                eng.flight_dump()
            ));
        }
        if report.truncated {
            return Err(format!(
                "recovery run truncated before completion\n\
                 --- flight recorder (newest last) ---\n{}",
                eng.flight_dump()
            ));
        }
        let done = report.aggregate.apps_completed;
        if done != apps as u64 {
            return Err(format!(
                "recovery incomplete: {done}/{apps} apps finished \
                 after {} crash(es)\n\
                 --- flight recorder (newest last) ---\n{}",
                report.crashes,
                eng.flight_dump()
            ));
        }
        eng.check_conservation()?;
        println!(
            "recovery OK: {done}/{apps} apps finished across {} \
             crash(es); {} apps re-queued ({} re-prefill tokens), \
             losses accounted (app={} prefix={} wire={})",
            report.crashes,
            report.crash_requeued_apps,
            report.crash_requeued_tokens,
            report.crash_lost_app_blocks,
            report.crash_lost_prefix_blocks,
            report.crash_lost_wire_blocks,
        );
    }
    if args.has("assert-qos") {
        // CI QoS smoke: under a Batch flood the gate must keep the
        // no-starvation invariant (every deferred arrival eventually
        // admitted or shed), hold Interactive p99 inside its SLO, and
        // lose nothing — conservation with sheds accounted.
        if !report.qos_enabled {
            return Err(
                "--assert-qos requires --qos (or another QoS flag)"
                    .to_string(),
            );
        }
        if report.qos_starved != 0 {
            return Err(format!(
                "{} request(s) still queued at end of run — \
                 starvation\n\
                 --- flight recorder (newest last) ---\n{}",
                report.qos_starved,
                eng.flight_dump()
            ));
        }
        for i in 0..tokencake::qos::TIERS {
            let (a, ad, sh) = (
                report.qos_arrivals[i],
                report.qos_admitted[i],
                report.qos_shed[i],
            );
            if a != ad + sh {
                return Err(format!(
                    "tier {} accounting broken: {} arrivals != {} \
                     admitted + {} shed\n\
                     --- flight recorder (newest last) ---\n{}",
                    tokencake::qos::Tier::from_index(i).name(),
                    a,
                    ad,
                    sh,
                    eng.flight_dump()
                ));
            }
        }
        let (int_p99, int_slo) =
            (report.tier_p99_us[0], report.qos_slo_us[0]);
        if int_p99 > int_slo {
            return Err(format!(
                "Interactive p99 {:.1}s exceeds its SLO {:.0}s under \
                 QoS\n\
                 --- flight recorder (newest last) ---\n{}",
                int_p99 as f64 / 1e6,
                int_slo as f64 / 1e6,
                eng.flight_dump()
            ));
        }
        eng.check_conservation()?;
        println!(
            "qos OK: starved=0, per-tier arrivals balance, \
             Interactive p99 {:.1}s <= SLO {:.0}s ({} shed)",
            int_p99 as f64 / 1e6,
            int_slo as f64 / 1e6,
            report.qos_shed.iter().sum::<u64>(),
        );
    }
    if args.has("assert-planner-gated") {
        // CI perf smoke: steady-state ticks must skip the planner — the
        // epoch gate keeps planner phase runs under 10% of sched steps.
        let runs = c.planner_runs + c.spatial_plans;
        if runs * 10 >= c.sched_steps {
            return Err(format!(
                "epoch gating ineffective: {} planner runs over {} \
                 scheduling steps (>= 10%)",
                runs, c.sched_steps
            ));
        }
        println!(
            "planner gating OK: {} runs / {} steps",
            runs, c.sched_steps
        );
    }
    if let Some(cfg2) = parity_cfg {
        // CI parity smoke: the serial oracle and the parallel engine
        // must be indistinguishable — byte-identical digest (and
        // trace, when captured) for the same seed and workload.
        let mode_a =
            if eng.cfg.parallel { "parallel" } else { "serial" };
        let mode_b = if cfg2.parallel { "parallel" } else { "serial" };
        let trace_a =
            args.get("trace").is_some().then(|| eng.export_trace());
        let mut oracle = ClusterEngine::new(cfg2);
        if trace_a.is_some() {
            oracle.enable_trace();
        }
        let rep2 = oracle.run(&workload);
        if report.digest() != rep2.digest() {
            return Err(format!(
                "parity violation: {mode_a} and {mode_b} digests \
                 differ for the same seed/workload\n\
                 --- {mode_a} ---\n{}\n--- {mode_b} ---\n{}",
                report.digest(),
                rep2.digest()
            ));
        }
        if let Some(ta) = trace_a {
            let tb = oracle.export_trace();
            if ta != tb {
                return Err(format!(
                    "parity violation: {mode_a} and {mode_b} traces \
                     differ ({} vs {} bytes)",
                    ta.len(),
                    tb.len()
                ));
            }
        }
        println!(
            "parity OK: {mode_a} == {mode_b} digest across {} \
             shard(s)",
            report.num_shards
        );
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<(), String> {
    let graph = app_by_name(args.get_or("app", "code-writer"))?;
    let spec = build_spec(args, &graph)?;
    println!(
        "app={} qps={} apps={} dataset={}",
        graph.name,
        spec.qps,
        spec.num_apps,
        spec.dataset.name()
    );
    for mode in [
        Mode::Vllm,
        Mode::VllmPrefix,
        Mode::Mooncake,
        Mode::Parrot,
        Mode::AgentOnly,
        Mode::OffloadOnly,
        Mode::TokenCake,
    ] {
        let mut cfg = build_config(args)?;
        cfg.mode = mode;
        let report = SimEngine::new(cfg).run_workload(&spec);
        println!("{}", report.summary());
    }
    Ok(())
}

/// Audit an exported trace file against the obs-layer ordering
/// invariants (transfer pairing, offload-before-upload, no decode
/// under a pending prefix fetch, retire-is-final, phase-ledger
/// conservation, clock sanity). `--summary` additionally prints
/// per-event-type counts and span-duration stats per transfer kind.
fn cmd_audit(args: &Args) -> Result<(), String> {
    let path = args
        .get("trace")
        .ok_or("audit requires --trace FILE (an exported trace)")?;
    let doc =
        std::fs::read_to_string(path).map_err(|e| e.to_string())?;
    if args.has("summary") {
        let recs = tokencake::obs::parse_chrome_trace(&doc)
            .map_err(|e| format!("{path}: {e}"))?;
        print!("{}", tokencake::obs::TraceAuditor::deep_summary(&recs));
    }
    match tokencake::obs::TraceAuditor::audit_chrome_trace(&doc) {
        Ok(summary) => {
            println!("{path}: {summary}");
            Ok(())
        }
        Err(e) => Err(format!("{path}: trace audit failed: {e}")),
    }
}

/// Reconstruct the per-request phase ledgers — and the per-app
/// critical paths over the workflow DAG — from an exported trace
/// alone, no live engine needed. The ledger table is byte-identical
/// to the live engine's rendering for the same run (`--assert-attrib`
/// enforces exactly that in CI).
fn cmd_analyze(args: &Args) -> Result<(), String> {
    use tokencake::obs::attrib;
    let path = args
        .get("trace")
        .ok_or("analyze requires --trace FILE (an exported trace)")?;
    let doc =
        std::fs::read_to_string(path).map_err(|e| e.to_string())?;
    let recs = tokencake::obs::parse_chrome_trace(&doc)
        .map_err(|e| format!("{path}: {e}"))?;
    let recon = attrib::reconstruct(&recs);
    let finished = recon.finished();
    if finished.is_empty() {
        println!(
            "{path}: no finished requests with spawn marks (trace \
             predates attribution or run produced none)"
        );
        return Ok(());
    }
    print!("{}", attrib::render_ledgers(&finished));
    let paths = attrib::critical_paths(&recon);
    print!("{}", attrib::render_critical_paths(&paths));
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let port = args.get_u64("port", 8080)? as u16;
    let server = Server::start(port).map_err(|e| e.to_string())?;
    println!("tokencake frontend listening on http://{}", server.addr);
    println!("endpoints: POST /graphs /apps /call_start /call_finish; GET /state /healthz");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_graph(args: &Args) -> Result<(), String> {
    let graph = app_by_name(args.get_or("app", "code-writer"))?;
    println!("graph {} ({} nodes, depth {})", graph.name, graph.len(),
             graph.max_depth());
    for node in graph.nodes() {
        let crit = if graph.is_critical(node.id) { "*" } else { " " };
        println!(
            "  {crit} [{:>2}] {:<20} depth={} out={} f_struct={:.2}",
            node.id.0,
            node.name,
            graph.depth(node.id),
            graph.out_degree(node.id),
            graph.f_struct(node.id),
        );
    }
    Ok(())
}

const HELP: &str = "\
TokenCake — KV-cache-centric serving for LLM multi-agent applications

USAGE: tokencake <command> [--flag value]...

COMMANDS:
  bench    run one workload:  --app --mode --qps --apps --frac --dataset
           --noise --seed --profile --config
           --trace FILE  export a Chrome/Perfetto trace of the run
           (request/KV lifecycle spans; byte-identical per seed)
           --json FILE  also write a single-worker vs N-shard cluster
           trajectory (--shards, default 4: throughput, mean/p99
           latency, effective GPU util, planner_runs_per_1k_ticks,
           mean_migration_batch, prefix_hit_rate_local/remote,
           prefill_tokens_saved)
  compare  run all modes on one workload (same flags, no --mode)
  cluster  sharded multi-worker serving:  --shards N
           --policy rr|least|affinity  --mix cw:2,dr:1  --qps --apps
           --frac --dataset --noise --seed --config  --no-migrate
           --autoscale [--min-shards N --max-shards N
           --grow-watermark X --drain-watermark X --warmup-ms N
           --cooldown-ms N]  (elastic fleet: grow/drain shards from
           the aggregate pressure signal; --shards is the initial
           serving count)
           --burst-qps N [--burst-period-s P --burst-duty D]
           (periodic traffic bursts over the base --qps)
           --trace FILE  export a merged cluster trace (one track per
           shard plus the control plane)
           --json FILE [--json-name NAME]  write the run's benchmark
           row
           --crash \"1@2500;3@6000\"  (crash shard@ms schedule)
           --crashes N --partitions N [--fault-seed N
           --partition-factor X --drop-wire]  (randomly placed
           seeded faults; same seed => byte-identical digests)
           --assert-recovery  (fail unless every planned crash
           executed, all apps completed after re-queueing, and block
           conservation holds with crash losses accounted — the
           fault-injection CI smoke)
           --assert-autoscale  (fail unless min <= serving <= max and
           zero blocks were lost — the autoscale CI smoke)
           --assert-planner-gated  (fail unless planner runs < 10% of
           scheduling steps — the epoch-gate CI smoke)
           --qos  per-tier token-bucket admission in front of the
           Router, with aging (no starvation) and Batch load-shedding
           under overload; SLO-headroom biases every victim choice
           (preemption, offload, prefix reclaim, drain order)
           --tiers LIST  (interactive|standard|batch per --mix entry,
           in order; unlisted entries stay standard)
           --qos-rates I,S,B  (admissions/s per tier)
           --slo-ms I,S,B  (per-tier app-latency SLO targets)
           --qos-shed-band N --qos-shed-depth N  (overload signal:
           shed new Batch arrivals at/above pressure band N with >= N
           deferred)  --qos-age-ms N  (priority-aging step)
           --assert-qos  (fail unless zero starved requests, per-tier
           arrivals == admitted + shed, Interactive p99 <= its SLO,
           and block conservation holds — the QoS CI smoke)
           --parallel | --serial  (execute the shard-local phases on
           scoped worker threads, or force the single-thread oracle —
           the default; both modes are byte-identical per seed)
           --assert-parity  (re-run the identical workload in the
           opposite execution mode and fail unless digests — and
           traces, with --trace — match byte-for-byte: the
           parallel-determinism CI smoke)
           --metrics-out FILE  write the run's aggregate metrics in
           Prometheus text format (per-phase attribution counters and
           p99s, per-tier breakdowns, stall_hidden_frac,
           exposed_upload_us_p99, queue_wait_us_p99)
           --assert-attrib  (fail unless every finished request's
           phase ledger tiles its wall time exactly — queued,
           qos-deferred, prefix-fetch, prefill, decode, fc-stall,
           offload-wire, exposed, crash-requeue phases sum to its
           end-to-end latency — AND re-deriving the ledgers from the
           exported trace alone matches the live ones byte-for-byte:
           the latency-attribution CI smoke; implies tracing)
  audit    check an exported trace against the obs-layer ordering
           invariants:  --trace FILE  (exit 1 on the first violation)
           --summary  also print per-event-type counts and transfer
           span-duration stats (min/p50/p99 per kind)
  analyze  reconstruct per-request phase ledgers and per-app critical
           paths from an exported trace alone:  --trace FILE
           (output is byte-identical to the live engine's ledger for
           the same run)
  serve    start the frontend HTTP server:  --port
  graph    inspect a built-in app template:  --app
  help     this text
";

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{HELP}");
            std::process::exit(2);
        }
    };
    let result = match args.command.as_str() {
        "bench" => cmd_bench(&args),
        "compare" => cmd_compare(&args),
        "cluster" => cmd_cluster(&args),
        "audit" => cmd_audit(&args),
        "analyze" => cmd_analyze(&args),
        "serve" => cmd_serve(&args),
        "graph" => cmd_graph(&args),
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => Err(format!("unknown command {other:?}\n{HELP}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
