//! TokenCake leader binary.
//!
//! ```text
//! tokencake bench   --app code-writer --mode tokencake --qps 0.5 --apps 20
//!                   [--frac 0.05] [--dataset d1|d2] [--noise 0.25]
//!                   [--seed N] [--config file.toml]
//! tokencake compare --app code-writer --qps 0.5 --apps 20 [--frac 0.05]
//! tokencake serve   [--port 8080]
//! tokencake graph   --app deep-research
//! tokencake help
//! ```

use tokencake::cli::Args;
use tokencake::config::{Mode, ServeConfig};
use tokencake::engine::sim::SimEngine;
use tokencake::graph::{templates, AppGraph};
use tokencake::server::Server;
use tokencake::workload::{Dataset, WorkloadSpec};

fn app_by_name(name: &str) -> Result<AppGraph, String> {
    Ok(match name {
        "code-writer" | "cw" => templates::code_writer(),
        "deep-research" | "dr" => templates::deep_research(),
        "rag" => templates::rag(),
        other => return Err(format!("unknown app {other:?}")),
    })
}

fn build_config(args: &Args) -> Result<ServeConfig, String> {
    let mut cfg = ServeConfig::default();
    if let Some(path) = args.get("config") {
        cfg.apply_file(path).map_err(|e| e.to_string())?;
    }
    if let Some(m) = args.get("mode") {
        cfg.mode = Mode::parse(m).ok_or(format!("unknown mode {m:?}"))?;
    }
    cfg.gpu_mem_frac = args.get_f64("frac", cfg.gpu_mem_frac)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    if let Some(p) = args.get("profile") {
        cfg.profile = tokencake::config::ModelProfile::by_name(p)
            .ok_or(format!("unknown profile {p:?}"))?;
    }
    Ok(cfg)
}

fn build_spec(args: &Args, graph: &AppGraph) -> Result<WorkloadSpec, String> {
    let qps = args.get_f64("qps", 0.5)?;
    let apps = args.get_u64("apps", 20)? as usize;
    let dataset = match args.get_or("dataset", "d1") {
        "d1" | "D1" => Dataset::D1,
        "d2" | "D2" => Dataset::D2,
        other => return Err(format!("unknown dataset {other:?}")),
    };
    let noise = args.get_f64("noise", 0.0)?;
    Ok(WorkloadSpec::poisson(graph, qps, apps)
        .with_dataset(dataset)
        .with_tool_noise(noise))
}

fn cmd_bench(args: &Args) -> Result<(), String> {
    let graph = app_by_name(args.get_or("app", "code-writer"))?;
    let cfg = build_config(args)?;
    let spec = build_spec(args, &graph)?;
    let report = SimEngine::new(cfg).run_workload(&spec);
    println!("{}", report.summary());
    if report.truncated {
        eprintln!("warning: run truncated before completion");
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<(), String> {
    let graph = app_by_name(args.get_or("app", "code-writer"))?;
    let spec = build_spec(args, &graph)?;
    println!(
        "app={} qps={} apps={} dataset={}",
        graph.name,
        spec.qps,
        spec.num_apps,
        spec.dataset.name()
    );
    for mode in [
        Mode::Vllm,
        Mode::VllmPrefix,
        Mode::Mooncake,
        Mode::Parrot,
        Mode::AgentOnly,
        Mode::OffloadOnly,
        Mode::TokenCake,
    ] {
        let mut cfg = build_config(args)?;
        cfg.mode = mode;
        let report = SimEngine::new(cfg).run_workload(&spec);
        println!("{}", report.summary());
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let port = args.get_u64("port", 8080)? as u16;
    let server = Server::start(port).map_err(|e| e.to_string())?;
    println!("tokencake frontend listening on http://{}", server.addr);
    println!("endpoints: POST /graphs /apps /call_start /call_finish; GET /state /healthz");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_graph(args: &Args) -> Result<(), String> {
    let graph = app_by_name(args.get_or("app", "code-writer"))?;
    println!("graph {} ({} nodes, depth {})", graph.name, graph.len(),
             graph.max_depth());
    for node in graph.nodes() {
        let crit = if graph.is_critical(node.id) { "*" } else { " " };
        println!(
            "  {crit} [{:>2}] {:<20} depth={} out={} f_struct={:.2}",
            node.id.0,
            node.name,
            graph.depth(node.id),
            graph.out_degree(node.id),
            graph.f_struct(node.id),
        );
    }
    Ok(())
}

const HELP: &str = "\
TokenCake — KV-cache-centric serving for LLM multi-agent applications

USAGE: tokencake <command> [--flag value]...

COMMANDS:
  bench    run one workload:  --app --mode --qps --apps --frac --dataset
           --noise --seed --profile --config
  compare  run all modes on one workload (same flags, no --mode)
  serve    start the frontend HTTP server:  --port
  graph    inspect a built-in app template:  --app
  help     this text
";

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{HELP}");
            std::process::exit(2);
        }
    };
    let result = match args.command.as_str() {
        "bench" => cmd_bench(&args),
        "compare" => cmd_compare(&args),
        "serve" => cmd_serve(&args),
        "graph" => cmd_graph(&args),
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => Err(format!("unknown command {other:?}\n{HELP}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
