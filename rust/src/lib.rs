//! # TokenCake
//!
//! A KV-Cache-centric serving framework for LLM-based multi-agent
//! applications — a faithful reproduction of the CS.DC 2025 paper.
//!
//! Multi-agent LLM applications interleave *LLM inference* with *external
//! function calls* inside a dependency DAG. This creates two KV-cache
//! pathologies that request-level schedulers cannot fix:
//!
//! * **temporal underutilization** — a stalled agent's KV cache idles in
//!   GPU memory for the whole duration of its function call;
//! * **spatial contention** — non-critical agents evict critical-path
//!   agents' caches (*critical inversion*), stalling the whole workflow.
//!
//! TokenCake co-optimizes scheduling and memory through two cooperating
//! schedulers that share a pressure-aware coordination protocol:
//!
//! * [`temporal`] — event-driven (`call_start`/`call_finish`) proactive
//!   offload of stalled caches to a CPU block pool, gated by an
//!   opportunistic cost/benefit policy, plus predictive upload that hides
//!   the H2D transfer behind the tail of the function call;
//! * [`spatial`] — dynamic partitioning of the GPU block pool into shared
//!   and reserved regions, guided by a hybrid priority metric over the
//!   application DAG and runtime state.
//!
//! ## Architecture (five layers, one observability spine)
//!
//! ```text
//! OBS deterministic flight-recorder tracing (obs): a TraceSink on every
//!     ServeState (and one on the cluster control plane) records typed
//!     lifecycle events — request states, ledger transfers, prefix
//!     lifecycle, planner gates, routing, migration, autoscale phases —
//!     stamped with the shared sim clock; consumers are a Perfetto
//!     trace_event exporter (--trace), an always-armed-in-debug flight
//!     recorder dumped on conservation failures, and a post-hoc
//!     invariant auditor (obs::TraceAuditor, `tokencake audit`);
//!     latency attribution (obs::attrib) partitions every request's
//!     wall time *exactly* into scheduling phases (queued, qos-
//!     deferred, prefix-fetch-gated, prefill, decode, fc-stall
//!     held/hidden/exposed, offload-wire, crash-requeue) on a
//!     per-request PhaseLedger driven from the same centralized
//!     transitions the trace records — so the identical ledger is
//!     rebuildable from an exported trace alone (`tokencake analyze`,
//!     per-app critical paths included) and `--assert-attrib`
//!     enforces conservation plus live-vs-trace byte equality;
//!     aggregates feed per-phase/per-tier/per-template metrics, the
//!     digest line, bench rows (stall_hidden_frac,
//!     exposed_upload_us_p99, queue_wait_us_p99), fixed-cadence
//!     scheduler gauges exported as trace counter tracks, and a
//!     Prometheus text dump (`--metrics-out`)
//! QOS multi-tenant admission & SLO spine (qos): every app carries a
//!     Tier (Interactive/Standard/Batch); a deterministic per-tier
//!     token-bucket gate in front of the router defers over-budget
//!     arrivals in an aging priority queue (Batch can never starve)
//!     and sheds Batch with a trace event under a pressure-band +
//!     queue-depth overload watermark; per-tier slo_target_us yields
//!     an SLO-distance term every victim choice (spatial admission,
//!     offload batching, prefix reclaim, drain evacuation) folds in
//! L5  autoscale control plane — elastic fleet sizing on the shared
//!     clock (cluster::autoscale): a hysteresis controller grows/drains
//!     shards from the aggregate pressure signal behind the pressure-
//!     epoch gate, drains evacuate through the batched migration path +
//!     prefix-directory relocation under the interconnect budget, and a
//!     per-template KV-lifetime predictor biases placement (long-lived
//!     apps avoid soon-to-drain shards); retirement conserves every
//!     block and is only reachable from the autoscale module
//! L4  cluster layer — N worker shards on one shared event clock:
//!     agent-affinity router, pressure-aware placement, cross-worker
//!     KV migration of stalled agents (cluster::ClusterEngine), and a
//!     cluster prefix directory federating the per-shard prefix
//!     indexes (cluster::prefix_dir: residency-derived routing warmth,
//!     remote prefix hits at interconnect price, bounded replication);
//!     seeded deterministic fault injection (cluster::faults): planned
//!     shard crashes and interconnect partition windows with full
//!     recovery — apps re-queue through the router, the directory
//!     promotes surviving replicas, and every destroyed block lands in
//!     an accounted-loss ledger so conservation extends to crash loss
//! L3  rust coordinator (this crate): graph API, schedulers, block pools,
//!     engines, baselines, metrics, HTTP server — one worker = one shard
//! L2  JAX TinyQwen model  — python/compile/model.py, AOT → artifacts/
//! L1  Pallas attention kernels — python/compile/kernels/attention.py
//! RT  runtime::PjrtModel loads artifacts/*.hlo.txt via the PJRT C API
//!     (feature `pjrt`; the default build is dependency-free)
//! ```
//!
//! ## Hot-path storage (deterministic by construction)
//!
//! The inner serving loop never observes `HashMap` iteration order, so
//! determinism needs no defensive per-tick sorts:
//!
//! * [`coordination::RequestArena`] / [`coordination::AppArena`] — slab
//!   arenas with identity-hash id indices, insertion-order iteration,
//!   and a live (non-finished) list so per-tick scans are O(live);
//! * [`coordination::ServeState::stalled_ids`] /
//!   [`coordination::ServeState::offloaded_ids`] — id-ordered
//!   incremental indices maintained on function-call lifecycle
//!   transitions (the ordered iteration *is* the seed's sorted order);
//! * [`coordination::BatchQueue`] — O(1), order-preserving batch
//!   membership for the running/prefilling queues;
//! * [`kvcache::BlockSet`] + the extent-map free list in
//!   [`kvcache::GpuPool`] — KV block ownership as coalesced extents,
//!   O(extents) alloc/free instead of per-block list traffic.
//!
//! ## Event-driven scheduling epochs
//!
//! The scheduler reacts to *events*, not wall time. Every mutation that
//! can change a scheduling decision bumps a per-subsystem dirty epoch in
//! [`coordination::SchedEpochs`]:
//!
//! * `temporal` — FC stall / tool return / transfer completion /
//!   lifecycle reindex / broken reservation / app extract+implant /
//!   prefix-cache lifecycle mutation;
//! * `spatial` — arrival, admission grant/deferral, preemption, finish,
//!   executed engine iteration (exec-time drift feeds S_a), prefix-cache
//!   lifecycle mutation;
//! * `pressure` — the free list crossing a policy watermark band
//!   (detected by an O(1) per-tick snapshot delta).
//!
//! Planners record the epochs they consumed (watermarks in
//! `ServeState::planned`): `temporal::maybe_run_phase` skips the whole
//! temporal phase — including building the pressure snapshot — unless an
//! epoch moved or a predictive-upload deadline arrived, and the spatial
//! replan is skipped at window expiry when its inputs are unchanged. A
//! steady-state decode tick therefore does only the snapshot delta plus
//! admission; CI asserts planner runs stay under 10% of scheduling
//! steps and greps against direct `run_phase`/`upload_phase` calls.
//!
//! The prefix cache follows an owned-backing lifecycle: the index in
//! [`kvcache::PrefixIndex`] pins real block extents (carved from the
//! finishing request that recorded them), reclaim demotes or drops LRU
//! entries through deterministic `(last_use, key)`-ordered secondary
//! indices, a CPU/remote hit charges an H2D debt through the migration
//! ledger that gates the request's start, and the cluster prefix
//! directory ([`cluster::prefix_dir`]) federates the shard indexes —
//! so a prefix hit can never reference blocks the pool already freed.
//!
//! Migration is batched under the same event model: one planning event
//! scores all stalled candidates once (off the id-ordered index) and
//! issues a bandwidth-capped multi-victim batch — locally capped by
//! in-flight D2H blocks, across workers by a per-window interconnect
//! budget — with a partial-batch fallback when the budget runs out, so
//! a pressure burst drains in one window instead of one victim per
//! window.
//!
//! ## Concurrency contract (deterministic parallel shard execution)
//!
//! The cluster hot loop runs its shard-local phases — advancing each
//! shard's local events to `now`, and each idle shard's scheduling
//! step — on scoped threads when `ClusterConfig::parallel` is set
//! (CLI `--parallel`), over disjoint `&mut` borrows of the shard
//! engines (no locks, `Send` by construction). Cross-shard effects
//! never happen inside a parallel phase: each shard accumulates its
//! outbound effects (orphaned tool finishes, prefix events,
//! fc-lifetime observations, trace records, ledger completions) in
//! per-shard outboxes that drain at a serial barrier in canonical
//! `(time, shard-id, seq)` order — the same total order the serial
//! sweep produces and [`obs::merge_records`] gives the trace. The
//! router, prefix directory, autoscale controller, fault executor,
//! and QoS gate run only at barriers. `--serial` keeps the
//! single-thread oracle on the identical code path, and the two
//! modes are byte-identical per seed (digests and exported traces) —
//! pinned by the `serial_parallel_digest_parity` determinism test
//! and the CI `--assert-parity` smoke.
//!
//! The fleet itself is elastic under the same discipline
//! ([`cluster::autoscale`]): a hysteresis controller reads the
//! aggregate pressure signal through the pressure-epoch gate and
//! grows (modeled warm-up; the router sends a warming shard nothing)
//! or drains (placement stops, stalled apps leave via the batched
//! migration path, sole-copy prefixes relocate under the interconnect
//! budget, and the shard retires only with empty pools — blocks
//! conserved end to end, the invariant both CI and the drain proptest
//! assert). A per-template KV-lifetime predictor — the template's
//! tool-call profile × an EWMA of observed stall durations — steers
//! long-lived applications away from the shards the controller will
//! drain next.
//!
//! Python never runs on the request path: `make artifacts` lowers the model
//! once; the rust binary is self-contained afterwards.
//!
//! ## Quick start
//!
//! ```no_run
//! use tokencake::prelude::*;
//!
//! let cfg = ServeConfig::default();
//! let graph = templates::code_writer();
//! let mut engine = SimEngine::new(cfg);
//! let report = engine.run_workload(&WorkloadSpec::poisson(&graph, 0.2, 20));
//! println!("avg latency: {:.1}s", report.metrics.latency.mean_s());
//! ```
//!
//! ## Cluster serving
//!
//! ```no_run
//! use tokencake::prelude::*;
//!
//! let cluster = ClusterConfig::default()
//!     .with_shards(4)
//!     .with_placement(PlacementPolicy::AgentAffinity);
//! let workload = ClusterWorkload::mixed(
//!     &[(templates::code_writer(), 2.0), (templates::deep_research(), 1.0)],
//!     1.0,
//!     40,
//! );
//! let report = ClusterEngine::new(cluster).run(&workload);
//! println!("{}", report.summary());
//! ```

pub mod baselines;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordination;
pub mod engine;
pub mod graph;
pub mod kvcache;
pub mod metrics;
pub mod obs;
pub mod qos;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod server;
pub mod sim;
pub mod spatial;
pub mod temporal;
pub mod workload;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::cluster::{ClusterEngine, ClusterReport};
    pub use crate::config::{
        AutoscaleConfig, ClusterConfig, Mode, ModelProfile,
        PlacementPolicy, PolicyConfig, ServeConfig,
    };
    pub use crate::engine::sim::{RunReport, SimEngine};
    pub use crate::graph::templates;
    pub use crate::graph::{AppGraph, FuncKind, NodeKind};
    pub use crate::qos::{QosConfig, Tier};
    pub use crate::workload::{BurstSpec, ClusterWorkload, WorkloadSpec};
}
