//! The agent-type score S_a (Eq. 6) — which agent *classes* deserve
//! reserved KV-cache capacity.
//!
//!   S_a = w1·P_a + w2·U_a + w3·H_a + w4·G_a
//!
//! * P_a — structural priority: the *maximum* static priority among active
//!   instances, so a single high-criticality instance protects the type;
//! * U_a — runtime urgency: how much the system failed to serve the type,
//!   with preemptions weighted above waits (they signal capacity loss);
//! * H_a — recomputation cost: log-compressed average context size and
//!   execution time (types whose caches are expensive to rebuild);
//! * G_a — graph context: average structural position (depth, fan) of the
//!   type's active requests.
//!
//! Each dimension is normalized to [0,1] across active types before the
//! weighted sum so no single raw scale dominates.
//!
//! Accumulation iterates the arena's live list (O(live), deterministic
//! order) into a dense per-type table — the seed walked every request
//! ever created in `HashMap` order, which made the floating-point sums
//! (and thus the critical set) depend on nondeterministic iteration.

use crate::coordination::{ReqState, ServeState};
use crate::kvcache::AgentTypeId;

/// Aggregated per-type statistics + final score.
#[derive(Debug, Clone, PartialEq)]
pub struct TypeStats {
    pub type_id: AgentTypeId,
    pub active: u32,
    pub gpu_blocks: u32,
    pub p_structural: f64,
    pub u_urgency: f64,
    pub h_recompute: f64,
    pub g_graph: f64,
    pub score: f64,
}

/// Compute S_a for every *active* agent type (types with at least one
/// unfinished request).
pub fn agent_type_scores(st: &ServeState) -> Vec<TypeStats> {
    #[derive(Clone, Copy)]
    struct Acc {
        active: u32,
        gpu_blocks: u32,
        p_max: f64,
        ctx_sum: f64,
        exec_sum: f64,
        g_sum: f64,
    }
    // Dense per-type table (type ids are interned, hence contiguous).
    let mut accs: Vec<Option<Acc>> = vec![None; st.types.len()];
    for k in 0..st.reqs.live_len() {
        let r = st.reqs.live_ref(k);
        if r.state == ReqState::Finished {
            continue;
        }
        let a = accs[r.type_id as usize].get_or_insert(Acc {
            active: 0,
            gpu_blocks: 0,
            p_max: 0.0,
            ctx_sum: 0.0,
            exec_sum: 0.0,
            g_sum: 0.0,
        });
        a.active += 1;
        a.gpu_blocks += if r.state.holds_gpu() {
            r.blocks.len()
        } else {
            0
        };
        let stat = r.static_priority
            + if r.critical_path { 0.3 } else { 0.0 };
        a.p_max = a.p_max.max(stat);
        a.ctx_sum += r.context_tokens as f64;
        a.exec_sum += r.exec_time_us as f64;
        a.g_sum += r.f_struct;
    }

    let p = &st.cfg.policy;
    let mut rows: Vec<TypeStats> = Vec::new();
    for (t, acc) in accs.into_iter().enumerate() {
        let Some(a) = acc else { continue };
        let n = a.active.max(1) as f64;
        let u_raw = p.urgency_preempt_coef * st.types.preempts[t]
            + p.urgency_wait_coef * st.types.waits[t];
        // Log-compress token count and execution time (§5.2).
        let avg_ctx = a.ctx_sum / n;
        let avg_exec_s = a.exec_sum / n / 1e6;
        let h_raw = (1.0 + avg_ctx).ln() * (1.0 + avg_exec_s).ln().max(0.1);
        rows.push(TypeStats {
            type_id: t as AgentTypeId,
            active: a.active,
            gpu_blocks: a.gpu_blocks,
            p_structural: a.p_max,
            u_urgency: u_raw,
            h_recompute: h_raw,
            g_graph: a.g_sum / n,
            score: 0.0,
        });
    }
    if rows.is_empty() {
        return rows;
    }

    // Normalize each dimension across types, then weight. Rows are
    // already in type-id order by construction.
    let max_of = |f: fn(&TypeStats) -> f64, rows: &[TypeStats]| {
        rows.iter().map(f).fold(0.0f64, f64::max).max(1e-9)
    };
    let (pm, um, hm, gm) = (
        max_of(|r| r.p_structural, &rows),
        max_of(|r| r.u_urgency, &rows),
        max_of(|r| r.h_recompute, &rows),
        max_of(|r| r.g_graph, &rows),
    );
    for r in rows.iter_mut() {
        r.score = p.w_structural * (r.p_structural / pm)
            + p.w_urgency * (r.u_urgency / um)
            + p.w_recompute * (r.h_recompute / hm)
            + p.w_graph * (r.g_graph / gm);
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;
    use crate::graph::templates;
    use crate::workload::SampledLengths;

    fn state_with_apps(n: usize) -> ServeState {
        let mut st = ServeState::new(ServeConfig::default());
        let g = templates::code_writer();
        let t = st.register_graph(&g);
        let scales = SampledLengths {
            prompt_scale: 1.0,
            gen_scale: 1.0,
        };
        for _ in 0..n {
            st.spawn_app(t, scales, 0);
        }
        st
    }

    #[test]
    fn empty_state_no_scores() {
        let st = ServeState::new(ServeConfig::default());
        assert!(agent_type_scores(&st).is_empty());
    }

    #[test]
    fn scores_bounded_and_per_active_type() {
        let st = state_with_apps(3);
        let scores = agent_type_scores(&st);
        // Only the root type (planner) has live requests so far.
        assert_eq!(scores.len(), 1);
        for s in &scores {
            assert!(s.score > 0.0 && s.score <= 1.0 + 1e-9, "{s:?}");
            assert_eq!(s.active, 3);
        }
    }

    #[test]
    fn preemptions_raise_urgency_and_score() {
        let mut st = state_with_apps(2);
        let base = agent_type_scores(&st)[0].score;
        let t = agent_type_scores(&st)[0].type_id;
        for _ in 0..5 {
            st.types.note_preempt(t);
        }
        let bumped = agent_type_scores(&st)[0].score;
        assert!(bumped >= base, "{base} -> {bumped}");
        // Preemptions weigh more than the same number of waits.
        let mut st2 = state_with_apps(2);
        for _ in 0..5 {
            st2.types.note_wait(t);
        }
        let s_preempt = {
            let r = &agent_type_scores(&st)[0];
            r.u_urgency
        };
        let s_wait = {
            let r = &agent_type_scores(&st2)[0];
            r.u_urgency
        };
        assert!(s_preempt > s_wait);
    }

    #[test]
    fn single_critical_instance_protects_type() {
        let mut st = state_with_apps(2);
        // Degrade one instance's static priority; P_a should use the max.
        let ids: Vec<_> = st.reqs.values().map(|r| r.id).collect();
        st.reqs.get_mut(&ids[0]).unwrap().static_priority = 0.1;
        let s = &agent_type_scores(&st)[0];
        assert!(s.p_structural >= 0.9, "max static+crit = {}", s.p_structural);
    }

    #[test]
    fn larger_contexts_raise_recompute_cost() {
        let mut st = state_with_apps(1);
        let low = agent_type_scores(&st)[0].h_recompute;
        for r in st.reqs.values_mut() {
            r.context_tokens *= 20;
            r.exec_time_us = 10_000_000;
        }
        let high = agent_type_scores(&st)[0].h_recompute;
        assert!(high > low);
    }
}
