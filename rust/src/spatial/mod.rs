//! The Spatial Scheduler (§5): dynamic memory partitioning + agent-aware
//! admission control.
//!
//! Solves *critical inversion* at the memory level: the GPU block pool is
//! split into a shared region and per-type reserved quotas that only
//! critical agent types may draw from. The partition adapts through the
//! Algorithm 2 feedback loop; which requests enter the batch is decided by
//! the hybrid per-request priority P_req (Eq. 5), and which *types* get
//! reservations by the agent-type score S_a (Eq. 6).

mod score;

pub use score::{agent_type_scores, TypeStats};

use crate::config::Mode;
use crate::coordination::{
    Action, PrefixEvent, ReqState, Request, RequestId, ServeState,
};
use crate::kvcache::{
    AgentTypeId, AllocOutcome, Direction, PrefixBacking, PrefixKey,
    PrefixLocation, Route, TransferKind,
};
use crate::obs;

/// Algorithm 2: periodically re-evaluate ρ, the critical set, and the
/// per-type quota distribution. No-op until the adjustment window
/// expires, and — at expiry — *epoch-gated*: the replan is skipped when
/// none of its inputs moved since the plan was computed (no spatial
/// event, no pressure-band crossing) and ρ has nowhere left to drift in
/// the current usage band.
pub fn maybe_update_reservations(st: &mut ServeState, now_us: u64) {
    if now_us < st.spatial.last_adjust_us + st.cfg.policy.adjust_window_us
        && st.spatial.last_adjust_us != 0
    {
        return;
    }
    // The window is consumed either way: a skipped window is the
    // decision "the previous plan still holds".
    st.spatial.last_adjust_us = now_us.max(1);
    let usage = st.gpu.usage();
    let p = &st.cfg.policy;
    let rho_drifts = (usage >= p.high_watermark
        && st.spatial.rho < p.reserve_max - 1e-12)
        || (usage <= p.low_watermark
            && st.spatial.rho > p.reserve_min + 1e-12);
    if st.planned.spatial == st.epochs.spatial
        && st.planned.pressure == st.epochs.pressure
        && !rho_drifts
    {
        st.metrics.counters.spatial_plan_skips += 1;
        return;
    }
    st.planned.spatial = st.epochs.spatial;
    st.planned.pressure = st.epochs.pressure;
    st.metrics.counters.spatial_plans += 1;
    st.trace_planner_run(obs::planner::SPATIAL);
    update_reservations(st);
}

/// The three-step reservation update (Algorithm 2), runnable on demand.
pub fn update_reservations(st: &mut ServeState) {
    let p = st.cfg.policy.clone();
    let n = st.gpu.total();
    let usage = st.gpu.usage();

    // ---- Step 1: adjust the total reserved pool fraction ρ. ----
    let mut rho = st.spatial.rho;
    if usage >= p.high_watermark {
        rho += p.reserve_step;
    } else if usage <= p.low_watermark {
        rho -= p.reserve_step;
    }
    rho = rho.clamp(p.reserve_min, p.reserve_max);
    st.spatial.rho = rho;

    // ---- Step 2: select critical agent types via S_a (Eq. 6). ----
    let scores = agent_type_scores(st);
    if scores.is_empty() {
        st.spatial.critical_types.clear();
        st.gpu.set_quotas(&[]);
        st.trace.spatial_plan(0, 0);
        return;
    }
    let mut ranked: Vec<(AgentTypeId, f64, u32)> = scores
        .iter()
        .map(|s| (s.type_id, s.score, s.gpu_blocks))
        .collect();
    ranked.sort_by(|a, b| b.1.total_cmp(&a.1));
    let k = ((ranked.len() as f64 * p.critical_ratio).ceil() as usize)
        .clamp(1, ranked.len());
    let critical = &ranked[..k];
    st.spatial.critical_types =
        critical.iter().map(|&(t, _, _)| t).collect();

    // ---- Step 3: distribute reserved capacity among critical types:
    // share_a = ½·(GpuUsage(a)/N + S_a / Σ S_{a'}). ----
    let sum_s: f64 = critical.iter().map(|&(_, s, _)| s.max(1e-9)).sum();
    let reserved_total = rho * n as f64;
    let mut plan: Vec<(AgentTypeId, u32)> = Vec::with_capacity(k);
    for &(t, s, used_blocks) in critical {
        let share = 0.5
            * (used_blocks as f64 / n.max(1) as f64
                + s.max(1e-9) / sum_s);
        let quota = (share * reserved_total) as u32;
        // A quota smaller than a typical request is pure fragmentation:
        // it blocks shared admissions without ever admitting anyone.
        if quota >= p.min_quota_blocks {
            plan.push((t, quota));
        }
    }
    st.gpu.set_quotas(&plan);
    st.trace.spatial_plan(
        plan.len() as u32,
        plan.iter().map(|&(_, q)| q as u64).sum(),
    );
}

/// Admission route for a request under the current mode + critical set.
pub fn route_for(st: &ServeState, rid: RequestId) -> Route {
    let r = &st.reqs[&rid];
    if st.cfg.mode.reserves_memory()
        && st.spatial.critical_types.contains(&r.type_id)
    {
        Route::Reserved(r.type_id)
    } else {
        Route::Shared
    }
}

/// Blocks to allocate at admission. Parrot (compute-centric, own engine)
/// reserves worst-case context up front — no paged growth — which is the
/// structural reason it collapses under memory pressure (§7.4, Fig 13).
fn admission_alloc_blocks(st: &ServeState, rid: RequestId) -> u32 {
    let r = &st.reqs[&rid];
    if st.cfg.mode == Mode::Parrot || r.admit_full {
        // Worst-case reservation: Parrot always (its engine predates
        // paged growth); everyone else only after a self-preemption
        // proved that incremental growth cannot complete (admit_full).
        let worst = r.context_tokens
            + (r.total_gen_target() - r.tokens_generated)
            + r.phases[r.cur_phase.min(r.phases.len() - 1)..]
                .iter()
                .map(|p| p.result_tokens)
                .sum::<u32>();
        let need = st.cfg.profile.blocks_for_tokens(worst);
        need.saturating_sub(r.blocks.len())
    } else {
        st.admission_demand(r)
    }
}

/// Phase 4: form the next batch under agent-aware admission control.
///
/// TokenCake / agent-aware modes scan the queue in priority order and may
/// skip requests that don't fit (no head-of-line blocking); FCFS baselines
/// (vLLM, Mooncake) stop at the first request that doesn't fit — classic
/// continuous batching.
///
/// This runs every engine tick: candidate ordering goes through the
/// reusable [`crate::coordination::SchedScratch`] buffers (no per-tick
/// allocation) and the resumed/fresh segments are stable-sorted in place.
pub fn admit(st: &mut ServeState, now_us: u64) {
    let batch_now = st.running.len() + st.prefilling.len();
    if batch_now >= st.cfg.max_batch {
        return;
    }
    if st.waiting.is_empty() {
        return;
    }
    let mut slots = st.cfg.max_batch - batch_now;

    // Candidate order: requests that already hold their KV (resumed after
    // a function call / upload) come first — they are continuations of the
    // decode batch, exactly as vLLM's running queue takes precedence over
    // waiting admissions. Fresh requests follow in mode-dependent order.
    let mut order = std::mem::take(&mut st.scratch.order);
    order.clear();
    order.extend(
        st.waiting
            .iter()
            .copied()
            .filter(|rid| !st.reqs[rid].blocks.is_empty()),
    );
    let n_resumed = order.len();
    order.extend(
        st.waiting
            .iter()
            .copied()
            .filter(|rid| st.reqs[rid].blocks.is_empty()),
    );
    if st.cfg.mode.agent_aware() {
        // Offload beneficiaries jump the line (the freed blocks were
        // justified by their admission); otherwise priority order. Both
        // segments use the same stable comparator, so the order matches
        // the seed's separate resumed/fresh sorts exactly. With QoS on,
        // SLO distance slots between: the request whose app has the
        // *least* SLO headroom admits first (milli fixed-point — the
        // comparison never touches floats).
        let headroom = |rid: &RequestId| -> i64 {
            if !st.qos.enabled {
                return 0;
            }
            let app_id = st.reqs[rid].app_id;
            let age = now_us
                .saturating_sub(st.apps[&app_id].arrival_us);
            st.qos
                .headroom_milli(st.apps.template_of(&app_id), age)
        };
        let mut by_prio = |a: &RequestId, b: &RequestId| {
            let ra = &st.reqs[a];
            let rb = &st.reqs[b];
            rb.pulled
                .cmp(&ra.pulled)
                .then(headroom(a).cmp(&headroom(b)))
                .then(rb.priority.total_cmp(&ra.priority))
        };
        order[..n_resumed].sort_by(&mut by_prio);
        order[n_resumed..].sort_by(&mut by_prio);
    }
    let fcfs_hol = matches!(
        st.cfg.mode,
        Mode::Vllm | Mode::VllmPrefix | Mode::Mooncake | Mode::OffloadOnly
            | Mode::Infercept
    );

    // Growth headroom (vLLM's admission watermark): a fresh admission must
    // leave one spare block per active sequence *that can still grow*, or
    // decode-time growth immediately triggers preemption thrash. Requests
    // whose blocks already cover their worst-case context (e.g. the real
    // engine's one-block-per-slot layout) need no headroom.
    let block_tokens = st.cfg.profile.block_tokens;
    fn needs_growth(r: &Request, block_tokens: u32) -> bool {
        let capacity = r.blocks.len() * block_tokens;
        let worst = r.context_tokens
            + (r.total_gen_target() - r.tokens_generated)
            + r.phases[r.cur_phase.min(r.phases.len() - 1)..]
                .iter()
                .map(|p| p.result_tokens)
                .sum::<u32>();
        capacity < worst
    }
    let mut margin = st
        .running
        .iter()
        .chain(st.prefilling.iter())
        .filter(|rid| needs_growth(&st.reqs[rid], block_tokens))
        .count() as u32;

    let mut admitted = std::mem::take(&mut st.scratch.admitted);
    admitted.clear();
    for &rid in &order {
        if slots == 0 {
            break;
        }
        let need = admission_alloc_blocks(st, rid);
        let route = route_for(st, rid);
        let fresh = st.reqs[&rid].blocks.is_empty();
        if fresh && st.gpu.available_for(route) < need.saturating_add(margin)
        {
            // The prefix cache yields before fresh work defers: demote
            // (or drop) LRU entries to cover the shortfall. Drop-path
            // frees land immediately (fall through and retry now);
            // demoted blocks travel the pending-free D2H path, so those
            // deferrals stand until the transfer completes.
            let shortfall = need
                .saturating_add(margin)
                .saturating_sub(st.gpu.available_for(route))
                .saturating_sub(st.gpu.pending_free_blocks());
            if shortfall > 0 {
                reclaim_prefix_gpu(st, shortfall, now_us);
            }
            if st.gpu.available_for(route) < need.saturating_add(margin) {
                st.metrics.counters.deferrals += 1;
                let t = st.reqs[&rid].type_id;
                st.types.note_wait(t);
                st.epochs.spatial += 1; // wait counters feed S_a
                if fcfs_hol {
                    break;
                }
                continue;
            }
        }
        let mut outcome = st.gpu.alloc(need, route);
        if outcome == AllocOutcome::Deferred {
            // Same reclaim-and-retry for resumed requests (no margin
            // pre-check): immediate drops may cover the need this tick.
            let shortfall = need
                .saturating_sub(st.gpu.available_for(route))
                .saturating_sub(st.gpu.pending_free_blocks());
            if shortfall > 0
                && reclaim_prefix_gpu(st, shortfall, now_us) > 0
            {
                outcome = st.gpu.alloc(need, route);
            }
        }
        match outcome {
            AllocOutcome::Granted {
                blocks,
                reserved_charged,
            } => {
                {
                    let r = st.reqs.get_mut(&rid).unwrap();
                    r.blocks.absorb(blocks);
                    r.reserved_charged += reserved_charged;
                    r.pulled = false;
                    let waited =
                        now_us.saturating_sub(r.queue_enter_us);
                    r.wait_time_us += waited;
                    st.metrics.queue_hist.record(waited);
                }
                // Prefix-cache lookup, applied only once the blocks are
                // granted: a CPU/remote hit issues the H2D debt into the
                // request's own blocks, so the hit must not fire for a
                // request that then fails admission.
                if fresh {
                    maybe_apply_prefix_cache(st, rid, now_us);
                }
                // Waiting → Prefilling/Running: unindexed transition.
                let r = st.reqs.get_mut(&rid).unwrap();
                r.state = if r.remaining_prefill > 0 {
                    ReqState::Prefilling
                } else {
                    ReqState::Running
                };
                if reserved_charged > 0 {
                    st.metrics.counters.reserved_admissions += 1;
                }
                match r.state {
                    ReqState::Prefilling => st.prefilling.push(rid),
                    _ => st.running.push(rid),
                }
                // Trace the granted state. A request admitted with a
                // pending prefix fetch is not decoding yet (the engine
                // gates on `prefix_xfer`), so it traces as prefilling
                // even when its prefill debt is already zero — the
                // auditor's "no decode while a prefix fetch is pending"
                // invariant reads this event literally.
                let granted = st.reqs[&rid].state;
                let code = if granted == ReqState::Running
                    && st.reqs[&rid].prefix_xfer.is_some()
                {
                    obs::state::PREFILLING
                } else {
                    crate::coordination::state_code(granted)
                };
                st.note_direct_transition(rid, code);
                st.epochs.spatial += 1; // per-type residency shifted
                admitted.push(rid);
                slots -= 1;
                if needs_growth(&st.reqs[&rid], block_tokens) {
                    margin += 1;
                }
            }
            AllocOutcome::Deferred => {
                st.metrics.counters.deferrals += 1;
                let t = st.reqs[&rid].type_id;
                st.types.note_wait(t);
                st.epochs.spatial += 1;
                if fcfs_hol {
                    break;
                }
            }
        }
    }
    st.waiting.retain(|rid| !admitted.contains(rid));
    order.clear();
    admitted.clear();
    st.scratch.order = order;
    st.scratch.admitted = admitted;
}

/// The prefix key of a request's shared system prompt.
fn prefix_key_of(st: &ServeState, r: &Request) -> PrefixKey {
    let g = st.graph_of(r.app_id);
    PrefixKey::of_parts(
        &g.name,
        st.types.name(r.type_id),
        r.shared_prefix_tokens,
    )
}

/// Prefix-cache reuse at admission (vLLM-Prefix / Mooncake / TokenCake),
/// applied after the grant so the hit only ever fires for a request that
/// actually holds destination blocks:
///
/// * **GPU hit** — the index's pinned copy is read in place; the saved
///   tokens leave the prefill debt immediately.
/// * **CPU / remote hit** — the saved prefill is only real once the
///   cached blocks are uploaded: an H2D transfer (priced by the entry's
///   `upload_factor` — 1.0 local, the interconnect factor for remote
///   pointers) is charged through the migration ledger, the source entry
///   is pinned for the read, and the request's `prefix_xfer` gates its
///   execution until the transfer completes.
fn maybe_apply_prefix_cache(
    st: &mut ServeState,
    rid: RequestId,
    now_us: u64,
) {
    if !st.cfg.mode.prefix_cache() {
        return;
    }
    let (eligible, key) = {
        let r = &st.reqs[&rid];
        let eligible = r.shared_prefix_tokens > 0
            && r.tokens_generated == 0
            && r.remaining_prefill == r.context_tokens;
        (eligible, prefix_key_of(st, r))
    };
    if !eligible {
        return;
    }
    st.metrics.counters.prefix_lookups += 1;
    let Some(hit) = st.prefix.lookup(key, now_us) else {
        return;
    };
    let saved = {
        let r = st.reqs.get_mut(&rid).unwrap();
        let saved = hit.tokens.min(r.remaining_prefill);
        r.remaining_prefill -= saved;
        saved
    };
    st.metrics.counters.prefill_tokens_saved += saved as u64;
    match hit.location {
        PrefixLocation::Gpu => {
            st.metrics.counters.prefix_hits_gpu += 1;
            st.trace.prefix(
                key.0,
                obs::prefix::HIT_GPU,
                st.cfg.profile.blocks_for_tokens(saved),
            );
        }
        PrefixLocation::Cpu | PrefixLocation::Remote => {
            if hit.location == PrefixLocation::Cpu {
                st.metrics.counters.prefix_hits_cpu += 1;
                st.trace.prefix(key.0, obs::prefix::HIT_CPU, 0);
            } else {
                st.metrics.counters.prefix_hits_remote += 1;
                st.push_prefix_event(PrefixEvent::RemoteHit { key });
            }
            let nb = st
                .cfg
                .profile
                .blocks_for_tokens(saved)
                .min(st.reqs[&rid].blocks.len());
            if nb == 0 {
                return;
            }
            // The upload writes into the request's own prefix-region
            // blocks; the ledger entry carries a copy of that extent
            // range so the debt shows up in the pressure snapshot.
            let dst = st.reqs[&rid].blocks.clone_prefix(nb);
            let cost = (st.cfg.profile.upload_us(nb) as f64
                * hit.upload_factor) as u64;
            let completes = now_us + cost;
            // Only a CPU-resident source is pinned for the read (a
            // remote pointer has no local backing); the flag rides the
            // transfer so completion/cancel unpins exactly once.
            let pinned = hit.location == PrefixLocation::Cpu;
            let xfer = st.ledger.issue_tagged(
                TransferKind::PrefixHit { key, pinned },
                rid.0,
                Direction::H2D,
                dst,
                Vec::new(),
                now_us,
                completes,
            );
            st.trace.transfer_start(
                xfer.0,
                rid.0,
                obs::xfer::PREFIX_HIT,
                false,
                nb,
                cost,
            );
            if pinned {
                st.prefix.pin(key);
            }
            st.reqs.get_mut(&rid).unwrap().prefix_xfer = Some(xfer);
            st.outbox.push(Action::TransferIssued {
                xfer,
                completes_us: completes,
            });
        }
    }
}

/// Record a finished request's shared prefix in the index so later
/// instances of the same agent type hit it. The index takes *ownership*
/// of the prefix-sized head of the finishing request's block set — the
/// entry is backed by real pinned extents, never by blocks the pool is
/// about to free. Freshest copy wins: a displaced older backing (GPU or
/// the CPU copy of a demoted entry — the Cpu→Gpu promotion leg) is
/// returned to its pool here.
pub fn record_prefix(st: &mut ServeState, rid: RequestId, now_us: u64) {
    if !st.cfg.mode.prefix_cache() {
        return;
    }
    let (key, tokens, nb) = {
        let r = &st.reqs[&rid];
        if r.shared_prefix_tokens == 0 {
            return;
        }
        (
            prefix_key_of(st, r),
            r.shared_prefix_tokens,
            st.cfg.profile.blocks_for_tokens(r.shared_prefix_tokens),
        )
    };
    if nb == 0 || st.reqs[&rid].blocks.len() < nb {
        return; // no fully resident copy to pin (defensive)
    }
    if st.prefix.is_pinned(key) {
        return; // an in-flight read owns the entry; keep it untouched
    }
    let backing = {
        let r = st.reqs.get_mut(&rid).unwrap();
        PrefixBacking::Gpu(r.blocks.take_prefix(nb))
    };
    // Carry the producing template's QoS tier so reclaim under
    // pressure can evict Batch prefixes before Interactive ones.
    let tier = {
        let app_id = st.reqs[&rid].app_id;
        st.qos.tier_of(st.apps.template_of(&app_id)).index() as u8
    };
    match st
        .prefix
        .insert_tiered(key, nb, tokens, backing, 1.0, now_us, tier)
    {
        None => {}
        Some(PrefixBacking::Gpu(b)) => st.gpu.free(b, 0, None),
        Some(PrefixBacking::Cpu(b)) => st.cpu.release(b),
        Some(PrefixBacking::Remote) => {}
    }
    st.push_prefix_event(PrefixEvent::Inserted {
        key,
        blocks: nb,
        tokens,
        location: PrefixLocation::Gpu,
    });
}

// ----------------------------------------------------------------------
// Prefix-cache reclaim: the cache always yields to live work
// ----------------------------------------------------------------------

/// Reclaim GPU blocks from the prefix cache under admission pressure:
/// LRU GPU-resident entries are demoted to the CPU tier (when the mode
/// has one and CPU blocks are available — the D2H leg rides the
/// pending-free + migration-ledger path) or dropped outright, until
/// `need` blocks are freed or on their way. Returns the blocks
/// reclaimed.
pub fn reclaim_prefix_gpu(
    st: &mut ServeState,
    need: u32,
    now_us: u64,
) -> u32 {
    let mut freed = 0u32;
    while freed < need {
        // With QoS on the victim order is tier-aware (Batch prefixes
        // yield first); otherwise plain LRU — bit-identical to the
        // pre-QoS behaviour.
        let Some((key, blocks)) = reclaim_victim(st) else {
            break;
        };
        if st.cfg.mode.prefix_cpu_tier() {
            if let Some(cpu_blocks) = st.cpu.alloc(blocks) {
                let gpu = st
                    .prefix
                    .demote_to_cpu(key, cpu_blocks)
                    .expect("LRU-GPU entry must demote");
                st.gpu.mark_pending_free(&gpu, 0, None);
                let completes =
                    now_us + st.cfg.profile.offload_us(blocks);
                let xfer = st.ledger.issue_tagged(
                    TransferKind::PrefixEvict { key },
                    u64::MAX,
                    Direction::D2H,
                    gpu,
                    Vec::new(),
                    now_us,
                    completes,
                );
                st.trace.transfer_start(
                    xfer.0,
                    u64::MAX,
                    obs::xfer::PREFIX_EVICT,
                    true,
                    blocks,
                    completes - now_us,
                );
                st.outbox.push(Action::TransferIssued {
                    xfer,
                    completes_us: completes,
                });
                st.metrics.counters.prefix_demotions += 1;
                st.push_prefix_event(PrefixEvent::Relocated {
                    key,
                    location: PrefixLocation::Cpu,
                });
                freed += blocks;
                continue;
            }
        }
        drop_prefix_gpu_entry(st, key);
        freed += blocks;
    }
    freed
}

/// GPU reclaim victim: tier-aware (Batch first, LRU within tier) when
/// QoS is enabled, plain LRU otherwise.
fn reclaim_victim(st: &ServeState) -> Option<(PrefixKey, u32)> {
    if st.qos.enabled {
        st.prefix.peek_lru_gpu_tiered()
    } else {
        st.prefix.peek_lru_gpu()
    }
}

/// Drop the reclaim-victim GPU-resident prefix entry, returning its
/// blocks to the pool *immediately* (decode growth and deadlock rescue
/// cannot wait for a demotion transfer). Returns false when no GPU
/// entry exists.
pub fn drop_prefix_gpu_lru(st: &mut ServeState) -> bool {
    let Some((key, _)) = reclaim_victim(st) else {
        return false;
    };
    drop_prefix_gpu_entry(st, key);
    true
}

fn drop_prefix_gpu_entry(st: &mut ServeState, key: PrefixKey) {
    match st.prefix.remove(key) {
        Some(PrefixBacking::Gpu(b)) => st.gpu.free(b, 0, None),
        _ => unreachable!("GPU reclaim victim must carry GPU backing"),
    }
    st.metrics.counters.prefix_evictions += 1;
    st.push_prefix_event(PrefixEvent::Removed { key });
}

/// Make room in the CPU pool for `need` blocks by dropping LRU unpinned
/// CPU-resident prefix entries (a request offload outranks a cached
/// prefix). Returns whether the pool can now serve the allocation.
pub fn reclaim_prefix_cpu(st: &mut ServeState, need: u32) -> bool {
    while st.cpu.free_blocks() < need {
        let Some((key, _)) = st.prefix.peek_lru_cpu_unpinned() else {
            break;
        };
        match st.prefix.remove(key) {
            Some(PrefixBacking::Cpu(b)) => st.cpu.release(b),
            _ => unreachable!("LRU-CPU entry must carry CPU backing"),
        }
        st.metrics.counters.prefix_evictions += 1;
        st.push_prefix_event(PrefixEvent::Removed { key });
    }
    st.cpu.free_blocks() >= need
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Mode as M, ServeConfig};
    use crate::graph::templates;
    use crate::workload::SampledLengths;

    fn scales() -> SampledLengths {
        SampledLengths {
            prompt_scale: 1.0,
            gen_scale: 1.0,
        }
    }

    fn state(mode: M) -> ServeState {
        let mut cfg = ServeConfig::default();
        cfg.mode = mode;
        let mut st = ServeState::new(cfg);
        let g = templates::code_writer();
        st.register_graph(&g);
        st
    }

    #[test]
    fn rho_follows_watermarks() {
        let mut st = state(M::TokenCake);
        // Low usage → ρ decreases to min.
        update_reservations(&mut st);
        assert!((st.spatial.rho - st.cfg.policy.reserve_min).abs() < 1e-9);
        // Fill above high watermark → ρ climbs by one step per update.
        let fill = (st.gpu.total() as f64 * 0.8) as u32;
        let AllocOutcome::Granted { .. } = st.gpu.alloc(fill, Route::Shared)
        else {
            panic!()
        };
        st.spawn_app(0, scales(), 0); // need active types for step 2/3
        let r0 = st.spatial.rho;
        update_reservations(&mut st);
        assert!((st.spatial.rho - (r0 + 0.05)).abs() < 1e-9);
        for _ in 0..10 {
            update_reservations(&mut st);
        }
        assert!(st.spatial.rho <= st.cfg.policy.reserve_max + 1e-9);
    }

    #[test]
    fn critical_set_is_top_fraction() {
        let mut st = state(M::TokenCake);
        // Spawn a couple of apps so several types are active.
        st.spawn_app(0, scales(), 0);
        st.spawn_app(0, scales(), 0);
        // Force memory pressure so quotas are meaningful.
        let fill = (st.gpu.total() as f64 * 0.8) as u32;
        st.gpu.alloc(fill, Route::Shared);
        update_reservations(&mut st);
        let n_active = agent_type_scores(&st).len();
        let expect = ((n_active as f64 * 0.75).ceil() as usize).max(1);
        assert_eq!(st.spatial.critical_types.len(), expect);
        assert!(st.gpu.total_quota() > 0);
        // Reserved pool bounded by ρ_max·N.
        assert!(
            st.gpu.total_quota()
                <= (st.cfg.policy.reserve_max * st.gpu.total() as f64) as u32
                    + 1
        );
    }

    #[test]
    fn admit_grants_and_transitions_state() {
        let mut st = state(M::TokenCake);
        st.spawn_app(0, scales(), 0);
        st.refresh_priorities(0);
        admit(&mut st, 0);
        assert!(st.waiting.is_empty());
        assert_eq!(st.prefilling.len(), 1);
        let rid = st.prefilling.get(0).unwrap();
        let r = &st.reqs[&rid];
        assert_eq!(r.state, ReqState::Prefilling);
        assert!(!r.blocks.is_empty());
        assert_eq!(
            r.blocks.len(),
            st.cfg.profile.blocks_for_tokens(r.context_tokens)
        );
    }

    #[test]
    fn fcfs_hol_blocks_vllm_but_not_tokencake() {
        // Two waiting requests; pool only fits the second (smaller) one.
        for (mode, expect_admitted) in
            [(M::Vllm, 0usize), (M::TokenCake, 1usize)]
        {
            let mut cfg = ServeConfig::default();
            cfg.mode = mode;
            cfg.gpu_mem_frac = 0.005; // 65 blocks → 1040 tokens
            let mut st = ServeState::new(cfg);
            let g = templates::code_writer();
            st.register_graph(&g);
            st.spawn_app(0, scales(), 0);
            st.spawn_app(0, scales(), 0);
            // Make the head request huge so it can't fit.
            let head = *st.waiting.front().unwrap();
            {
                let r = st.reqs.get_mut(&head).unwrap();
                r.context_tokens = 10_000;
                r.remaining_prefill = 10_000;
                r.priority = 10.0; // highest priority, still won't fit
            }
            let tail = *st.waiting.back().unwrap();
            st.reqs.get_mut(&tail).unwrap().priority = 1.0;
            admit(&mut st, 0);
            let admitted =
                st.prefilling.len() + st.running.len();
            assert_eq!(admitted, expect_admitted, "{mode:?}");
        }
    }

    #[test]
    fn critical_type_uses_reserved_route() {
        let mut st = state(M::TokenCake);
        st.spawn_app(0, scales(), 0);
        let rid = *st.waiting.front().unwrap();
        let t = st.reqs[&rid].type_id;
        st.spatial.critical_types = vec![t];
        assert_eq!(route_for(&st, rid), Route::Reserved(t));
        st.cfg.mode = M::Parrot; // agent-aware but never reserves
        assert_eq!(route_for(&st, rid), Route::Shared);
    }

    #[test]
    fn parrot_allocates_worst_case() {
        let mut st = state(M::Parrot);
        st.spawn_app(0, scales(), 0);
        let rid = *st.waiting.front().unwrap();
        let paged = st.admission_demand(&st.reqs[&rid]);
        let parrot = admission_alloc_blocks(&st, rid);
        assert!(
            parrot > paged,
            "worst-case reservation {parrot} must exceed paged {paged}"
        );
    }

    #[test]
    fn prefix_cache_saves_prefill_on_second_instance() {
        let mut st = state(M::VllmPrefix);
        st.spawn_app(0, scales(), 0);
        st.refresh_priorities(0);
        admit(&mut st, 0);
        let first = st.prefilling.get(0).unwrap();
        // Finish the first request and record its prefix.
        record_prefix(&mut st, first, 1000);
        // Second instance of the same root agent type.
        st.spawn_app(0, scales(), 2000);
        let second = *st.waiting.front().unwrap();
        let before = st.reqs[&second].remaining_prefill;
        admit(&mut st, 2000);
        let after = st.reqs[&second].remaining_prefill;
        let prefix = st.reqs[&second].shared_prefix_tokens;
        assert_eq!(before - after, prefix);
        assert_eq!(st.metrics.counters.prefix_hits_gpu, 1);
    }

    #[test]
    fn plain_vllm_ignores_prefix_cache() {
        let mut st = state(M::Vllm);
        st.spawn_app(0, scales(), 0);
        admit(&mut st, 0);
        let first = st.prefilling.get(0).unwrap();
        record_prefix(&mut st, first, 1000);
        assert!(st.prefix.is_empty(), "vllm mode must not populate index");
    }

    #[test]
    fn record_prefix_pins_backing_and_conserves_pool() {
        let mut st = state(M::TokenCake);
        st.spawn_app(0, scales(), 0);
        st.refresh_priorities(0);
        admit(&mut st, 0);
        let rid = st.prefilling.get(0).unwrap();
        let held_before = st.reqs[&rid].blocks.len();
        record_prefix(&mut st, rid, 1000);
        let pinned = st.prefix.resident_gpu_blocks();
        assert!(pinned > 0, "the index must own real backing");
        // The backing was carved out of the request, not double-counted.
        assert_eq!(st.reqs[&rid].blocks.len(), held_before - pinned);
        assert_eq!(
            st.gpu.free_blocks()
                + st.reqs[&rid].blocks.len()
                + pinned,
            st.gpu.total(),
            "free + request-held + prefix-resident must cover the pool"
        );
        // Releasing the request leaves only the pinned prefix behind.
        st.release_gpu(rid);
        assert_eq!(
            st.gpu.free_blocks() + st.prefix.resident_gpu_blocks(),
            st.gpu.total()
        );
    }

    #[test]
    fn cpu_prefix_hit_charges_h2d_debt_and_gates_start() {
        let mut st = state(M::TokenCake);
        st.spawn_app(0, scales(), 0);
        st.refresh_priorities(0);
        admit(&mut st, 0);
        let first = st.prefilling.get(0).unwrap();
        record_prefix(&mut st, first, 1000);
        // Demote the cached prefix to the CPU tier.
        let resident = st.prefix.resident_gpu_blocks();
        let freed = reclaim_prefix_gpu(&mut st, resident, 1000);
        assert_eq!(freed, resident);
        assert_eq!(st.metrics.counters.prefix_demotions, 1);
        assert_eq!(
            st.gpu.pending_free_blocks(),
            resident,
            "the D2H leg must ride the pending-free path"
        );
        assert_eq!(st.prefix.resident_cpu_blocks(), resident);
        // A second instance hits the CPU copy: prefill saved, but the
        // upload debt gates its start and pins the source entry.
        st.spawn_app(0, scales(), 2000);
        let second = *st.waiting.front().unwrap();
        let before = st.reqs[&second].remaining_prefill;
        admit(&mut st, 2000);
        let r = &st.reqs[&second];
        assert!(before > r.remaining_prefill, "prefill must shrink");
        assert!(r.prefix_xfer.is_some(), "H2D debt must gate the start");
        assert_eq!(st.metrics.counters.prefix_hits_cpu, 1);
        assert!(st.metrics.counters.prefill_tokens_saved > 0);
        assert_eq!(st.ledger.inflight_upload_blocks(), freed);
        // The pinned source refuses eviction until the read lands.
        assert!(st.prefix.peek_lru_cpu_unpinned().is_none());
    }

    #[test]
    fn reclaim_drops_outright_without_cpu_tier() {
        // vLLM-Prefix has no host KV store: reclaim frees immediately.
        let mut st = state(M::VllmPrefix);
        st.spawn_app(0, scales(), 0);
        admit(&mut st, 0);
        let rid = st.prefilling.get(0).unwrap();
        record_prefix(&mut st, rid, 1000);
        let resident = st.prefix.resident_gpu_blocks();
        let free_before = st.gpu.free_blocks();
        let freed = reclaim_prefix_gpu(&mut st, resident, 2000);
        assert_eq!(freed, resident);
        assert_eq!(st.metrics.counters.prefix_evictions, 1);
        assert_eq!(st.metrics.counters.prefix_demotions, 0);
        assert_eq!(st.gpu.free_blocks(), free_before + resident);
        assert_eq!(st.cpu.used_blocks(), 0);
        assert!(st.prefix.is_empty());
    }
}
