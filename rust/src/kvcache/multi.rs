//! Multi-GPU block pools (§5 "Multi-GPU Support").
//!
//! Tensor-parallel inference shards every KV block across all
//! participating GPUs, so "allocate a block" means taking the same
//! logical slot on *every* device: a request is admitted only when the
//! required blocks can be reserved on all GPUs, and the reservation
//! policy (shared + per-type quotas) is applied per device in lockstep.
//! The pressure snapshot extends with per-device free/reserved counts.
//!
//! Under lockstep sharding, identical per-device pools behave exactly
//! like one pool of the per-device capacity — which is why the
//! simulator's single [`GpuPool`] with `gpu_blocks / tp` per device is a
//! faithful model. This module makes the per-device structure explicit
//! for deployments where devices can diverge (e.g. a device reserved for
//! another tenant), and enforces the all-or-nothing admission rule.

use super::gpu::{AllocOutcome, GpuPool, Route};
use super::{AgentTypeId, BlockSet};

/// Per-device slice of the pressure snapshot (§5: "extends only the
/// pressure snapshot with per-device free blocks, reserved blocks, and
/// pending upload demand").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DevicePressure {
    pub device: usize,
    pub free: u32,
    pub shared_free: u32,
    pub reserved_outstanding: u32,
    pub pending_free: u32,
    pub usage: f64,
}

/// A tensor-parallel group of block pools with all-or-nothing admission.
#[derive(Debug, Clone)]
pub struct MultiGpuPool {
    devices: Vec<GpuPool>,
}

/// One multi-device allocation: the same logical block index may map to
/// different physical ids per device.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardedAlloc {
    /// blocks[d] = the block extents granted on device d.
    pub blocks: Vec<BlockSet>,
    /// Reserved-quota charge (identical across devices by construction).
    pub reserved_charged: u32,
}

impl ShardedAlloc {
    /// Blocks per device (identical across devices by construction).
    pub fn len(&self) -> u32 {
        self.blocks.first().map(|b| b.len()).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl MultiGpuPool {
    /// `tp` devices of `blocks_per_device` each.
    pub fn new(tp: usize, blocks_per_device: u32) -> Self {
        assert!(tp >= 1);
        Self {
            devices: (0..tp).map(|_| GpuPool::new(blocks_per_device)).collect(),
        }
    }

    pub fn tp(&self) -> usize {
        self.devices.len()
    }

    pub fn device(&self, d: usize) -> &GpuPool {
        &self.devices[d]
    }

    /// Blocks allocatable on *every* device via the route — the binding
    /// constraint for TP admission.
    pub fn available_for(&self, route: Route) -> u32 {
        self.devices
            .iter()
            .map(|p| p.available_for(route))
            .min()
            .unwrap_or(0)
    }

    /// All-or-nothing allocation: succeeds only if every device can grant
    /// `n` blocks on the route; otherwise nothing is allocated anywhere.
    pub fn alloc(&mut self, n: u32, route: Route) -> Option<ShardedAlloc> {
        if self.available_for(route) < n {
            return None;
        }
        let mut blocks = Vec::with_capacity(self.devices.len());
        let mut charged = None;
        for (d, pool) in self.devices.iter_mut().enumerate() {
            match pool.alloc(n, route) {
                AllocOutcome::Granted {
                    blocks: b,
                    reserved_charged,
                } => {
                    // Lockstep policy ⇒ identical charge on every device.
                    debug_assert!(
                        charged.map(|c| c == reserved_charged).unwrap_or(true)
                    );
                    charged = Some(reserved_charged);
                    blocks.push(b);
                }
                AllocOutcome::Deferred => {
                    // Roll back devices 0..d (cannot happen when
                    // available_for was honest, but stay safe under
                    // concurrent divergence).
                    let t = match route {
                        Route::Reserved(t) => Some(t),
                        Route::Shared => None,
                    };
                    for (pool, b) in
                        self.devices.iter_mut().zip(blocks.drain(..))
                    {
                        pool.free(b, charged.unwrap_or(0), t);
                    }
                    let _ = d;
                    return None;
                }
            }
        }
        Some(ShardedAlloc {
            blocks,
            reserved_charged: charged.unwrap_or(0),
        })
    }

    /// Free a sharded allocation on every device.
    pub fn free(&mut self, alloc: ShardedAlloc, t: Option<AgentTypeId>) {
        assert_eq!(alloc.blocks.len(), self.devices.len());
        for (pool, b) in self.devices.iter_mut().zip(alloc.blocks) {
            pool.free(b, alloc.reserved_charged, t);
        }
    }

    /// Pending-free on every device (offload in flight reads all shards).
    pub fn mark_pending_free(
        &mut self,
        alloc: &ShardedAlloc,
        t: Option<AgentTypeId>,
    ) {
        for (pool, b) in self.devices.iter_mut().zip(alloc.blocks.iter()) {
            pool.mark_pending_free(b, alloc.reserved_charged, t);
        }
    }

    /// Complete pending-free on every device.
    pub fn complete_pending(&mut self, alloc: ShardedAlloc) {
        for (pool, b) in self.devices.iter_mut().zip(alloc.blocks) {
            pool.complete_pending(b);
        }
    }

    /// Install the same reservation plan on every device (§5: "the same
    /// agent priority metric coordinates admission across devices").
    pub fn set_quotas(&mut self, plan: &[(AgentTypeId, u32)]) {
        for pool in self.devices.iter_mut() {
            pool.set_quotas(plan);
        }
    }

    /// Per-device pressure rows for the extended snapshot.
    pub fn pressure(&self) -> Vec<DevicePressure> {
        self.devices
            .iter()
            .enumerate()
            .map(|(device, p)| DevicePressure {
                device,
                free: p.free_blocks(),
                shared_free: p.shared_free(),
                reserved_outstanding: p.outstanding_reserved(),
                pending_free: p.pending_free_blocks(),
                usage: p.usage(),
            })
            .collect()
    }

    /// Worst-device usage (the admission-relevant scalar).
    pub fn usage(&self) -> f64 {
        self.devices
            .iter()
            .map(|p| p.usage())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_or_nothing_across_devices() {
        let mut m = MultiGpuPool::new(2, 10);
        let a = m.alloc(6, Route::Shared).unwrap();
        assert_eq!(a.len(), 6);
        assert_eq!(a.blocks.len(), 2);
        // 5 more don't fit on either device → refused, nothing leaks.
        assert!(m.alloc(5, Route::Shared).is_none());
        assert_eq!(m.device(0).free_blocks(), 4);
        assert_eq!(m.device(1).free_blocks(), 4);
        m.free(a, None);
        assert_eq!(m.device(0).free_blocks(), 10);
        assert_eq!(m.device(1).free_blocks(), 10);
    }

    #[test]
    fn binding_constraint_is_min_across_devices() {
        let mut m = MultiGpuPool::new(2, 10);
        // Skew device 0 by a direct allocation (simulating divergence).
        // (Reach in through the public API: allocate then free on dev 1.)
        let skew = m.alloc(3, Route::Shared).unwrap();
        // Now both have 7; min = 7.
        assert_eq!(m.available_for(Route::Shared), 7);
        m.free(skew, None);
    }

    #[test]
    fn lockstep_quotas_protect_on_every_device() {
        let mut m = MultiGpuPool::new(2, 20);
        m.set_quotas(&[(3, 8)]);
        assert_eq!(m.available_for(Route::Shared), 12);
        assert!(m.alloc(13, Route::Shared).is_none());
        let crit = m.alloc(8, Route::Reserved(3)).unwrap();
        assert_eq!(crit.reserved_charged, 8);
        for d in 0..2 {
            assert_eq!(m.device(d).quota_used(3), 8);
        }
        m.free(crit, Some(3));
        assert_eq!(m.device(0).headroom(3), 8);
    }

    #[test]
    fn pending_free_lockstep() {
        let mut m = MultiGpuPool::new(2, 10);
        let a = m.alloc(4, Route::Shared).unwrap();
        m.mark_pending_free(&a, None);
        for row in m.pressure() {
            assert_eq!(row.pending_free, 4);
            assert_eq!(row.free, 6);
        }
        m.complete_pending(a);
        assert_eq!(m.available_for(Route::Shared), 10);
    }

    #[test]
    fn pressure_rows_per_device() {
        let mut m = MultiGpuPool::new(4, 8);
        let _a = m.alloc(2, Route::Shared).unwrap();
        let rows = m.pressure();
        assert_eq!(rows.len(), 4);
        for (d, row) in rows.iter().enumerate() {
            assert_eq!(row.device, d);
            assert_eq!(row.free, 6);
            assert!((row.usage - 0.25).abs() < 1e-9);
        }
        assert!((m.usage() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn single_device_degenerates_to_plain_pool() {
        let mut m = MultiGpuPool::new(1, 5);
        let a = m.alloc(5, Route::Shared).unwrap();
        assert!(m.alloc(1, Route::Shared).is_none());
        m.free(a, None);
        assert_eq!(m.tp(), 1);
    }
}
