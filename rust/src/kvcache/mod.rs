//! KV-cache block management: the paper's PagedAttention-style GPU block
//! pool with TokenCake's two-region (shared + reserved) partitioning, the
//! re-introduced CPU block pool (§6.3), the CPU prefix-cache index, and the
//! migration ledger implementing pending-free semantics.
//!
//! All pools deal in fixed-size blocks of `block_tokens` tokens
//! (16 by default, 3 MiB each for Qwen2.5-14B bf16).

mod cpu;
mod extent;
mod gpu;
mod migrate;
mod multi;
mod prefix;

pub use cpu::CpuBlockPool;
pub use extent::{BlockSet, Extent};
pub use gpu::{AllocOutcome, GpuPool, Route};
pub use migrate::{
    Direction, MigrationLedger, Transfer, TransferId, TransferKind,
};
pub use multi::{DevicePressure, MultiGpuPool, ShardedAlloc};
pub use prefix::{
    PrefixBacking, PrefixHit, PrefixIndex, PrefixKey, PrefixLocation,
};

/// Physical GPU block identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId(pub u32);

/// Physical CPU block identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CpuBlockId(pub u32);

/// Interned agent-type id (registry lives in the engine state).
pub type AgentTypeId = u16;
