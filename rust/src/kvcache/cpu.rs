//! CPU offload block pool (§6.3).
//!
//! vLLM V1 removed host-memory swap; TokenCake re-introduces a CPU block
//! pool with a lightweight free list that recycles fixed-size blocks
//! without returning them to the OS allocator — avoiding the near-second
//! worst-case allocation stalls that high-frequency offloading would
//! otherwise hit (§7.6 reports consistent sub-millisecond allocation).
//!
//! In the simulator the pool is pure accounting; the real engine attaches
//! actual buffers to the same ids (runtime::HostStore).

use super::CpuBlockId;

/// Fixed-capacity CPU block pool with an id-recycling free list.
#[derive(Debug, Clone)]
pub struct CpuBlockPool {
    total: u32,
    free: Vec<CpuBlockId>,
    /// High-water mark of simultaneously allocated blocks (reporting).
    peak_used: u32,
}

impl CpuBlockPool {
    pub fn new(total: u32) -> Self {
        Self {
            total,
            free: (0..total).rev().map(CpuBlockId).collect(),
            peak_used: 0,
        }
    }

    pub fn total(&self) -> u32 {
        self.total
    }

    pub fn free_blocks(&self) -> u32 {
        self.free.len() as u32
    }

    pub fn used_blocks(&self) -> u32 {
        self.total - self.free_blocks()
    }

    pub fn peak_used(&self) -> u32 {
        self.peak_used
    }

    /// Allocate `n` blocks, or None if the pool can't hold them (the
    /// opportunistic gate's first hard rejection: CPU capacity).
    pub fn alloc(&mut self, n: u32) -> Option<Vec<CpuBlockId>> {
        if (self.free.len() as u32) < n {
            return None;
        }
        let at = self.free.len() - n as usize;
        let blocks = self.free.split_off(at);
        self.peak_used = self.peak_used.max(self.used_blocks());
        Some(blocks)
    }

    /// Return blocks to the free list (never to the OS).
    pub fn release(&mut self, blocks: Vec<CpuBlockId>) {
        self.free.extend(blocks);
        debug_assert!(self.free.len() as u32 <= self.total);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_recycles_ids() {
        let mut p = CpuBlockPool::new(10);
        let a = p.alloc(4).unwrap();
        assert_eq!(p.used_blocks(), 4);
        p.release(a.clone());
        let b = p.alloc(4).unwrap();
        // Recycled from the free list, not fresh ids.
        assert_eq!(a, b);
    }

    #[test]
    fn refuses_overflow() {
        let mut p = CpuBlockPool::new(3);
        assert!(p.alloc(4).is_none());
        let x = p.alloc(3).unwrap();
        assert!(p.alloc(1).is_none());
        p.release(x);
        assert!(p.alloc(1).is_some());
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut p = CpuBlockPool::new(10);
        let a = p.alloc(7).unwrap();
        p.release(a);
        p.alloc(2).unwrap();
        assert_eq!(p.peak_used(), 7);
    }
}
