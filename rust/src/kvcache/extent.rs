//! Extent-based block bookkeeping.
//!
//! The seed tracked GPU blocks as `Vec<BlockId>` — one entry per block —
//! so allocating/freeing a k-block request was O(k) pushes and pops, and
//! a long-context request's block list was k words of memory walked on
//! every transfer. Block ids are opaque to every policy (only *counts*
//! reach scheduling decisions), so the natural representation is a list
//! of contiguous **extents** `[start, start+len)`: alloc/free become
//! O(extents touched), and a request's whole KV footprint is typically
//! one or two extents regardless of context length.

use super::BlockId;

/// A contiguous run of physical blocks `[start, start + len)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Extent {
    pub start: u32,
    pub len: u32,
}

impl Extent {
    #[inline]
    pub fn end(&self) -> u32 {
        self.start + self.len
    }
}

/// A compact set of GPU blocks held as coalesced extents, in the order
/// they were granted. Replaces per-block `Vec<BlockId>` lists on
/// requests, upload reservations, and the migration ledger.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BlockSet {
    extents: Vec<Extent>,
    total: u32,
}

impl BlockSet {
    pub fn new() -> Self {
        Self::default()
    }

    /// A set covering one contiguous run (tests, single-grant paths).
    pub fn from_extent(start: u32, len: u32) -> Self {
        let mut s = Self::new();
        s.push(Extent { start, len });
        s
    }

    /// Total blocks in the set.
    #[inline]
    pub fn len(&self) -> u32 {
        self.total
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// The coalesced extents, in grant order.
    pub fn extents(&self) -> &[Extent] {
        &self.extents
    }

    pub fn extent_count(&self) -> usize {
        self.extents.len()
    }

    /// First block of the set (the real engine's block==slot mapping).
    pub fn first(&self) -> Option<BlockId> {
        self.extents.first().map(|e| BlockId(e.start))
    }

    /// Append an extent, merging with the tail when physically adjacent.
    pub fn push(&mut self, e: Extent) {
        if e.len == 0 {
            return;
        }
        self.total += e.len;
        if let Some(last) = self.extents.last_mut() {
            if last.end() == e.start {
                last.len += e.len;
                return;
            }
        }
        self.extents.push(e);
    }

    /// Append every extent of `other` (a later grant growing this set);
    /// `push` merges at the seam when the runs are adjacent.
    pub fn absorb(&mut self, other: BlockSet) {
        for e in other.extents {
            self.push(e);
        }
    }

    /// Take the whole set, leaving this one empty.
    pub fn take(&mut self) -> BlockSet {
        std::mem::take(self)
    }

    /// Split off the first `n` blocks into a new set (n ≤ len).
    pub fn take_prefix(&mut self, n: u32) -> BlockSet {
        debug_assert!(n <= self.total, "take_prefix past end");
        let mut out = BlockSet::new();
        while out.total < n {
            let need = n - out.total;
            let e = self.extents[0];
            if e.len <= need {
                self.extents.remove(0);
                self.total -= e.len;
                out.push(e);
            } else {
                self.extents[0].start += need;
                self.extents[0].len -= need;
                self.total -= need;
                out.push(Extent {
                    start: e.start,
                    len: need,
                });
            }
        }
        out
    }

    /// A copy of the first `n` blocks, without mutating this set
    /// (n ≤ len). The read-only sibling of [`Self::take_prefix`].
    pub fn clone_prefix(&self, n: u32) -> BlockSet {
        debug_assert!(n <= self.total, "clone_prefix past end");
        let mut out = BlockSet::new();
        for e in &self.extents {
            if out.total >= n {
                break;
            }
            let need = n - out.total;
            out.push(Extent {
                start: e.start,
                len: e.len.min(need),
            });
        }
        out
    }

    /// Iterate the individual block ids (tests, invariant checks).
    pub fn iter_blocks(&self) -> impl Iterator<Item = BlockId> + '_ {
        self.extents
            .iter()
            .flat_map(|e| (e.start..e.end()).map(BlockId))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_merges_adjacent() {
        let mut s = BlockSet::new();
        s.push(Extent { start: 0, len: 4 });
        s.push(Extent { start: 4, len: 2 });
        s.push(Extent { start: 10, len: 1 });
        assert_eq!(s.len(), 7);
        assert_eq!(s.extent_count(), 2);
        assert_eq!(s.extents()[0], Extent { start: 0, len: 6 });
        assert_eq!(s.first(), Some(BlockId(0)));
    }

    #[test]
    fn absorb_merges_at_seam() {
        let mut a = BlockSet::from_extent(0, 3);
        let b = BlockSet::from_extent(3, 3);
        a.absorb(b);
        assert_eq!(a.len(), 6);
        assert_eq!(a.extent_count(), 1);
        let ids: Vec<u32> = a.iter_blocks().map(|b| b.0).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn take_prefix_splits_extents() {
        let mut s = BlockSet::new();
        s.push(Extent { start: 0, len: 4 });
        s.push(Extent { start: 8, len: 4 });
        let head = {
            let mut s = s.clone();
            s.take_prefix(6)
        };
        assert_eq!(head.len(), 6);
        let ids: Vec<u32> = head.iter_blocks().map(|b| b.0).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 8, 9]);
        let mut rest = s;
        let head2 = rest.take_prefix(2);
        assert_eq!(head2.len(), 2);
        assert_eq!(rest.len(), 6);
        let rest_ids: Vec<u32> = rest.iter_blocks().map(|b| b.0).collect();
        assert_eq!(rest_ids, vec![2, 3, 8, 9, 10, 11]);
    }

    #[test]
    fn clone_prefix_is_read_only() {
        let mut s = BlockSet::new();
        s.push(Extent { start: 0, len: 4 });
        s.push(Extent { start: 8, len: 4 });
        let head = s.clone_prefix(6);
        assert_eq!(head.len(), 6);
        let ids: Vec<u32> = head.iter_blocks().map(|b| b.0).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 8, 9]);
        // The source is untouched.
        assert_eq!(s.len(), 8);
        assert_eq!(s.extent_count(), 2);
    }

    #[test]
    fn take_leaves_empty() {
        let mut s = BlockSet::from_extent(5, 5);
        let t = s.take();
        assert_eq!(t.len(), 5);
        assert!(s.is_empty());
        assert_eq!(s.extent_count(), 0);
    }
}
