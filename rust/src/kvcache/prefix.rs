//! Prefix-cache index (§6.3).
//!
//! Maps a token-prefix hash to cached KV blocks and their residency. The
//! standard lookup path is extended with CPU entries: a CPU hit avoids
//! recomputation but creates an H2D transfer debt that must complete
//! before the request can run.

use std::collections::HashMap;

/// Hash key of a token prefix. The engines key shared system prompts by
/// (graph template, agent type, prefix length); a real tokenizer path would
//  hash the token ids per block.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PrefixKey(pub u64);

impl PrefixKey {
    /// FNV-1a over an arbitrary byte string.
    pub fn of_bytes(bytes: &[u8]) -> Self {
        let mut h: u64 = 0xcbf29ce484222325;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        PrefixKey(h)
    }

    pub fn of_parts(template: &str, agent_type: &str, len: u32) -> Self {
        let mut buf = Vec::with_capacity(template.len() + agent_type.len() + 8);
        buf.extend_from_slice(template.as_bytes());
        buf.push(0);
        buf.extend_from_slice(agent_type.as_bytes());
        buf.push(0);
        buf.extend_from_slice(&len.to_le_bytes());
        Self::of_bytes(&buf)
    }
}

/// Where a cached prefix currently lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefixLocation {
    Gpu,
    Cpu,
}

#[derive(Debug, Clone)]
struct Entry {
    blocks: u32,
    tokens: u32,
    location: PrefixLocation,
    last_use_us: u64,
    hits: u64,
}

/// The index itself: key → (blocks, residency, recency).
#[derive(Debug, Clone, Default)]
pub struct PrefixIndex {
    entries: HashMap<PrefixKey, Entry>,
}

/// Result of a lookup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixHit {
    pub blocks: u32,
    pub tokens: u32,
    pub location: PrefixLocation,
}

impl PrefixIndex {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record (or refresh) a cached prefix.
    pub fn insert(
        &mut self,
        key: PrefixKey,
        blocks: u32,
        tokens: u32,
        location: PrefixLocation,
        now_us: u64,
    ) {
        let e = self.entries.entry(key).or_insert(Entry {
            blocks,
            tokens,
            location,
            last_use_us: now_us,
            hits: 0,
        });
        e.blocks = blocks;
        e.tokens = tokens;
        e.location = location;
        e.last_use_us = now_us;
    }

    /// Look up a prefix; refreshes recency and counts the hit.
    pub fn lookup(&mut self, key: PrefixKey, now_us: u64) -> Option<PrefixHit> {
        let e = self.entries.get_mut(&key)?;
        e.last_use_us = now_us;
        e.hits += 1;
        Some(PrefixHit {
            blocks: e.blocks,
            tokens: e.tokens,
            location: e.location,
        })
    }

    /// Change residency after an offload/upload of the backing blocks.
    pub fn set_location(&mut self, key: PrefixKey, location: PrefixLocation) {
        if let Some(e) = self.entries.get_mut(&key) {
            e.location = location;
        }
    }

    /// Drop an entry (blocks evicted entirely).
    pub fn remove(&mut self, key: PrefixKey) {
        self.entries.remove(&key);
    }

    /// Evict the least-recently-used entry, returning its key and size.
    pub fn evict_lru(&mut self) -> Option<(PrefixKey, u32)> {
        let key = self
            .entries
            .iter()
            .min_by_key(|(_, e)| e.last_use_us)
            .map(|(k, _)| *k)?;
        let blocks = self.entries.remove(&key).map(|e| e.blocks)?;
        Some((key, blocks))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn total_hits(&self) -> u64 {
        self.entries.values().map(|e| e.hits).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_is_stable_and_distinct() {
        let a = PrefixKey::of_parts("code-writer", "programmer", 384);
        let b = PrefixKey::of_parts("code-writer", "programmer", 384);
        let c = PrefixKey::of_parts("code-writer", "reviewer", 384);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn lookup_hits_and_misses() {
        let mut ix = PrefixIndex::new();
        let k = PrefixKey::of_bytes(b"hello");
        assert!(ix.lookup(k, 0).is_none());
        ix.insert(k, 4, 64, PrefixLocation::Gpu, 10);
        let hit = ix.lookup(k, 20).unwrap();
        assert_eq!(hit.blocks, 4);
        assert_eq!(hit.location, PrefixLocation::Gpu);
        assert_eq!(ix.total_hits(), 1);
    }

    #[test]
    fn cpu_residency_transition() {
        let mut ix = PrefixIndex::new();
        let k = PrefixKey::of_bytes(b"x");
        ix.insert(k, 2, 32, PrefixLocation::Gpu, 0);
        ix.set_location(k, PrefixLocation::Cpu);
        assert_eq!(ix.lookup(k, 1).unwrap().location, PrefixLocation::Cpu);
    }

    #[test]
    fn lru_eviction_order() {
        let mut ix = PrefixIndex::new();
        let k1 = PrefixKey::of_bytes(b"1");
        let k2 = PrefixKey::of_bytes(b"2");
        ix.insert(k1, 1, 16, PrefixLocation::Cpu, 100);
        ix.insert(k2, 2, 32, PrefixLocation::Cpu, 200);
        ix.lookup(k1, 300); // refresh k1; k2 is now LRU
        let (evicted, blocks) = ix.evict_lru().unwrap();
        assert_eq!(evicted, k2);
        assert_eq!(blocks, 2);
        assert_eq!(ix.len(), 1);
    }
}
