//! Prefix-cache index (§6.3) with an owned-backing lifecycle.
//!
//! Maps a token-prefix hash to cached KV blocks and their residency. The
//! index *owns* its backing: a GPU-resident entry holds the pinned
//! [`BlockSet`] extents (carved out of the finishing request that
//! recorded it), a CPU-resident entry holds its [`CpuBlockId`]s, and a
//! remote entry is a pointer into another shard's index maintained by the
//! cluster prefix directory. A hit therefore always references blocks
//! that exist; nothing else may free index-held blocks.
//!
//! ## Lifecycle contract
//!
//! * **Insert** — only `spatial::record_prefix` (request finish, local
//!   GPU backing) and `cluster::prefix_dir` (remote pointers / replicas)
//!   create entries; a CI grep enforces the call-site set. Freshest copy
//!   wins: inserting over an existing entry displaces the old backing,
//!   which is returned to the caller to free — unless the entry is
//!   pinned, in which case the *offered* backing is returned instead.
//! * **Evict / demote** — reclaim (admission pressure, decode growth,
//!   deadlock rescue) walks the `(last_use, key)`-ordered secondary
//!   indices: O(log n), ties broken on the key so eviction order never
//!   depends on `HashMap` storage order.
//! * **Pin** — a CPU entry being read by an in-flight H2D prefix upload
//!   is pinned (`readers > 0`): it cannot be evicted or displaced until
//!   the transfer completes and unpins it.
//! * **Residency** — `Gpu → Cpu` via [`PrefixIndex::demote_to_cpu`]
//!   (the D2H ride goes through the migration ledger at the call site);
//!   `Cpu → Gpu` by a fresh local insert displacing the CPU copy.
//!
//! The standard lookup path is extended with CPU and remote entries: a
//! CPU hit creates an H2D transfer debt that must complete before the
//! request can run, and a remote hit prices that debt at the cluster
//! interconnect factor.

use std::collections::{BTreeSet, HashMap};

use super::{BlockSet, CpuBlockId};

/// Hash key of a token prefix. The engines key shared system prompts by
/// (graph template, agent type, prefix length); a real tokenizer path would
/// hash the token ids per block.
#[derive(
    Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord,
)]
pub struct PrefixKey(pub u64);

impl PrefixKey {
    /// FNV-1a over an arbitrary byte string.
    pub fn of_bytes(bytes: &[u8]) -> Self {
        let mut h: u64 = 0xcbf29ce484222325;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        PrefixKey(h)
    }

    pub fn of_parts(template: &str, agent_type: &str, len: u32) -> Self {
        let mut buf = Vec::with_capacity(template.len() + agent_type.len() + 8);
        buf.extend_from_slice(template.as_bytes());
        buf.push(0);
        buf.extend_from_slice(agent_type.as_bytes());
        buf.push(0);
        buf.extend_from_slice(&len.to_le_bytes());
        Self::of_bytes(&buf)
    }
}

/// Where a cached prefix currently lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefixLocation {
    Gpu,
    Cpu,
    /// Held on another shard; the cluster prefix directory seeded this
    /// pointer so admission can hit it at interconnect price.
    Remote,
}

/// Physical backing an entry owns (or, for `Remote`, points at).
#[derive(Debug, Clone)]
pub enum PrefixBacking {
    Gpu(BlockSet),
    Cpu(Vec<CpuBlockId>),
    Remote,
}

impl PrefixBacking {
    pub fn location(&self) -> PrefixLocation {
        match self {
            PrefixBacking::Gpu(_) => PrefixLocation::Gpu,
            PrefixBacking::Cpu(_) => PrefixLocation::Cpu,
            PrefixBacking::Remote => PrefixLocation::Remote,
        }
    }
}

#[derive(Debug, Clone)]
struct Entry {
    blocks: u32,
    tokens: u32,
    backing: PrefixBacking,
    /// H2D price multiplier for CPU/remote hits (1.0 local; the cluster
    /// interconnect factor for remote pointers).
    upload_factor: f64,
    last_use_us: u64,
    hits: u64,
    /// In-flight H2D prefix uploads reading this entry's CPU backing.
    /// A pinned entry cannot be evicted, demoted, or displaced.
    readers: u32,
    /// QoS tier index of the template that produced the prefix
    /// ([`crate::qos::Tier`]; 1 = Standard when unknown). Reclaim
    /// under pressure prefers the highest tier index — Batch prefixes
    /// yield before Interactive ones.
    tier: u8,
}

/// The index itself: key → (backing, residency, recency), plus
/// `(last_use, key)`-ordered secondary indices per residency tier so LRU
/// eviction is O(log n) and deterministic (key breaks recency ties).
#[derive(Debug, Clone, Default)]
pub struct PrefixIndex {
    entries: HashMap<PrefixKey, Entry>,
    lru_gpu: BTreeSet<(u64, PrefixKey)>,
    lru_cpu: BTreeSet<(u64, PrefixKey)>,
    resident_gpu: u32,
    resident_cpu: u32,
}

/// Result of a lookup.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefixHit {
    pub blocks: u32,
    pub tokens: u32,
    pub location: PrefixLocation,
    /// H2D price multiplier a CPU/remote hit pays on the upload debt.
    pub upload_factor: f64,
}

impl PrefixIndex {
    pub fn new() -> Self {
        Self::default()
    }

    fn index_add(&mut self, key: PrefixKey, e: &Entry) {
        match e.backing {
            PrefixBacking::Gpu(_) => {
                self.lru_gpu.insert((e.last_use_us, key));
                self.resident_gpu += e.blocks;
            }
            PrefixBacking::Cpu(_) => {
                self.lru_cpu.insert((e.last_use_us, key));
                self.resident_cpu += e.blocks;
            }
            PrefixBacking::Remote => {}
        }
    }

    fn index_remove(&mut self, key: PrefixKey, e: &Entry) {
        match e.backing {
            PrefixBacking::Gpu(_) => {
                self.lru_gpu.remove(&(e.last_use_us, key));
                self.resident_gpu -= e.blocks;
            }
            PrefixBacking::Cpu(_) => {
                self.lru_cpu.remove(&(e.last_use_us, key));
                self.resident_cpu -= e.blocks;
            }
            PrefixBacking::Remote => {}
        }
    }

    /// Refresh an entry's recency in its tier's LRU index and on the
    /// entry itself — the single place (last_use, key) pairs move.
    fn touch(&mut self, key: PrefixKey, now_us: u64) {
        let Some(e) = self.entries.get(&key) else { return };
        let old = (e.last_use_us, key);
        match e.backing.location() {
            PrefixLocation::Gpu => {
                self.lru_gpu.remove(&old);
                self.lru_gpu.insert((now_us, key));
            }
            PrefixLocation::Cpu => {
                self.lru_cpu.remove(&old);
                self.lru_cpu.insert((now_us, key));
            }
            PrefixLocation::Remote => {}
        }
        self.entries
            .get_mut(&key)
            .expect("touch: entry vanished between recency probe and write")
            .last_use_us = now_us;
    }

    /// Record a cached prefix whose backing the index takes ownership of.
    /// Freshest copy wins: an existing entry's backing is displaced and
    /// returned for the caller to free; a *pinned* entry is kept and the
    /// offered backing is handed back instead. Only `spatial` and
    /// `cluster::prefix_dir` may call this (CI-enforced).
    pub fn insert(
        &mut self,
        key: PrefixKey,
        blocks: u32,
        tokens: u32,
        backing: PrefixBacking,
        upload_factor: f64,
        now_us: u64,
    ) -> Option<PrefixBacking> {
        self.insert_tiered(
            key,
            blocks,
            tokens,
            backing,
            upload_factor,
            now_us,
            1, // Standard: tier-neutral callers (directory replicas)
        )
    }

    /// [`Self::insert`] carrying the producing template's QoS tier
    /// index, so reclaim under pressure can prefer Batch-tier victims.
    #[allow(clippy::too_many_arguments)]
    pub fn insert_tiered(
        &mut self,
        key: PrefixKey,
        blocks: u32,
        tokens: u32,
        backing: PrefixBacking,
        upload_factor: f64,
        now_us: u64,
        tier: u8,
    ) -> Option<PrefixBacking> {
        debug_assert!(
            match &backing {
                PrefixBacking::Gpu(b) => b.len() == blocks,
                PrefixBacking::Cpu(v) => v.len() as u32 == blocks,
                PrefixBacking::Remote => true,
            },
            "insert: backing does not cover the declared block count"
        );
        if self.is_pinned(key) {
            // Pinned: an in-flight upload reads the backing. Refresh
            // recency only; reject the offered copy.
            self.touch(key, now_us);
            return Some(backing);
        }
        let displaced = self.entries.remove(&key).map(|old| {
            self.index_remove(key, &old);
            old.backing
        });
        let e = Entry {
            blocks,
            tokens,
            backing,
            upload_factor,
            last_use_us: now_us,
            hits: 0,
            readers: 0,
            tier,
        };
        self.index_add(key, &e);
        self.entries.insert(key, e);
        displaced
    }

    /// Look up a prefix; refreshes recency and counts the hit.
    pub fn lookup(&mut self, key: PrefixKey, now_us: u64) -> Option<PrefixHit> {
        self.entries.get(&key)?;
        self.touch(key, now_us);
        let e = self
            .entries
            .get_mut(&key)
            .expect("lookup: recency touch must never remove the entry");
        e.hits += 1;
        Some(PrefixHit {
            blocks: e.blocks,
            tokens: e.tokens,
            location: e.backing.location(),
            upload_factor: e.upload_factor,
        })
    }

    /// Least-recently-used GPU-resident entry (key breaks recency ties).
    pub fn peek_lru_gpu(&self) -> Option<(PrefixKey, u32)> {
        let &(_, key) = self.lru_gpu.iter().next()?;
        Some((key, self.entries[&key].blocks))
    }

    /// Tier-aware reclaim victim: the LRU entry of the *highest* tier
    /// index present (Batch yields before Standard before
    /// Interactive), LRU within a tier. The LRU index iterates in
    /// ascending `(last_use, key)` order, so the first entry seen per
    /// tier is that tier's LRU — fully deterministic. Degenerates to
    /// [`Self::peek_lru_gpu`] when every entry shares one tier.
    pub fn peek_lru_gpu_tiered(&self) -> Option<(PrefixKey, u32)> {
        let mut best: Option<(u8, PrefixKey, u32)> = None;
        for &(_, key) in &self.lru_gpu {
            let e = &self.entries[&key];
            if best.map(|(t, _, _)| e.tier > t).unwrap_or(true) {
                best = Some((e.tier, key, e.blocks));
            }
        }
        best.map(|(_, key, blocks)| (key, blocks))
    }

    /// QoS tier index of an entry (1 = Standard when the key is
    /// unknown), for tier-aware orderings outside the index — e.g. the
    /// autoscaler's drain evacuation relocating Interactive sole
    /// copies before Batch ones.
    pub fn tier_of(&self, key: PrefixKey) -> u8 {
        self.entries.get(&key).map(|e| e.tier).unwrap_or(1)
    }

    /// Least-recently-used *unpinned* CPU-resident entry.
    pub fn peek_lru_cpu_unpinned(&self) -> Option<(PrefixKey, u32)> {
        for &(_, key) in &self.lru_cpu {
            let e = &self.entries[&key];
            if e.readers == 0 {
                return Some((key, e.blocks));
            }
        }
        None
    }

    /// Gpu → Cpu residency transition: the index takes ownership of the
    /// CPU blocks and hands the GPU backing to the caller (who rides it
    /// through the pending-free + migration-ledger D2H path). The entry
    /// reprices to local (`upload_factor` 1.0).
    pub fn demote_to_cpu(
        &mut self,
        key: PrefixKey,
        cpu_blocks: Vec<CpuBlockId>,
    ) -> Option<BlockSet> {
        let e = self.entries.get(&key)?;
        let PrefixBacking::Gpu(_) = e.backing else {
            return None;
        };
        let mut old = self
            .entries
            .remove(&key)
            .expect("demote_to_cpu: entry vanished after residency probe");
        self.index_remove(key, &old);
        let PrefixBacking::Gpu(gpu) =
            std::mem::replace(&mut old.backing, PrefixBacking::Cpu(cpu_blocks))
        else {
            unreachable!()
        };
        old.upload_factor = 1.0;
        self.index_add(key, &old);
        self.entries.insert(key, old);
        Some(gpu)
    }

    /// Drop an entry, returning its backing for the caller to free.
    /// Pinned entries refuse (returns None, entry kept).
    pub fn remove(&mut self, key: PrefixKey) -> Option<PrefixBacking> {
        if self.entries.get(&key)?.readers > 0 {
            return None;
        }
        let e = self
            .entries
            .remove(&key)
            .expect("remove: entry vanished after the pin check");
        self.index_remove(key, &e);
        Some(e.backing)
    }

    /// Drop a remote pointer (no backing to free); real copies are kept.
    /// Used by the cluster directory when the last holder evicts.
    pub fn remove_pointer(&mut self, key: PrefixKey) -> bool {
        let is_pointer = matches!(
            self.entries.get(&key),
            Some(e) if matches!(e.backing, PrefixBacking::Remote)
        );
        if is_pointer {
            self.entries.remove(&key);
        }
        is_pointer
    }

    /// Crash purge: remove *every* entry — real copies and pointers,
    /// pinned or not (a shard crash outlives any in-flight read) — and
    /// return the key-sorted backings for the caller to free. The LRU
    /// indices and residency counters reset to empty.
    pub fn drain_all(&mut self) -> Vec<(PrefixKey, PrefixBacking)> {
        let mut out: Vec<(PrefixKey, PrefixBacking)> = self
            .entries
            .drain()
            .map(|(k, e)| (k, e.backing))
            .collect();
        out.sort_by_key(|&(k, _)| k);
        self.lru_gpu.clear();
        self.lru_cpu.clear();
        self.resident_gpu = 0;
        self.resident_cpu = 0;
        out
    }

    /// Pin an entry against eviction/displacement (in-flight H2D read).
    pub fn pin(&mut self, key: PrefixKey) {
        if let Some(e) = self.entries.get_mut(&key) {
            e.readers += 1;
        }
    }

    /// Release one pin.
    pub fn unpin(&mut self, key: PrefixKey) {
        if let Some(e) = self.entries.get_mut(&key) {
            e.readers = e.readers.saturating_sub(1);
        }
    }

    /// Is the entry pinned by an in-flight read?
    pub fn is_pinned(&self, key: PrefixKey) -> bool {
        self.entries.get(&key).map(|e| e.readers > 0).unwrap_or(false)
    }

    /// GPU blocks the index currently pins (pool-conservation term:
    /// `free + request-held + pending-free + prefix-resident == total`).
    pub fn resident_gpu_blocks(&self) -> u32 {
        self.resident_gpu
    }

    /// CPU blocks the index currently pins.
    pub fn resident_cpu_blocks(&self) -> u32 {
        self.resident_cpu
    }

    pub fn location_of(&self, key: PrefixKey) -> Option<PrefixLocation> {
        self.entries.get(&key).map(|e| e.backing.location())
    }

    /// Key-sorted snapshot of every entry with *local* backing (GPU or
    /// CPU): `(key, location, blocks, tokens, pinned)`. The cluster
    /// drain path enumerates these to evacuate a retiring shard's
    /// cache; sorting keeps the evacuation order independent of
    /// `HashMap` storage.
    pub fn local_entries(
        &self,
    ) -> Vec<(PrefixKey, PrefixLocation, u32, u32, bool)> {
        let mut out: Vec<_> = self
            .entries
            .iter()
            .filter_map(|(k, e)| match e.backing.location() {
                PrefixLocation::Remote => None,
                loc => {
                    Some((*k, loc, e.blocks, e.tokens, e.readers > 0))
                }
            })
            .collect();
        out.sort_by_key(|&(k, ..)| k);
        out
    }

    /// Every GPU extent the index pins (tests / invariant checks).
    pub fn resident_gpu_extents(&self) -> Vec<super::Extent> {
        let mut out = Vec::new();
        for e in self.entries.values() {
            if let PrefixBacking::Gpu(b) = &e.backing {
                out.extend_from_slice(b.extents());
            }
        }
        out
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn total_hits(&self) -> u64 {
        self.entries.values().map(|e| e.hits).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpu(start: u32, len: u32) -> PrefixBacking {
        PrefixBacking::Gpu(BlockSet::from_extent(start, len))
    }

    #[test]
    fn key_is_stable_and_distinct() {
        let a = PrefixKey::of_parts("code-writer", "programmer", 384);
        let b = PrefixKey::of_parts("code-writer", "programmer", 384);
        let c = PrefixKey::of_parts("code-writer", "reviewer", 384);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn lookup_hits_and_misses() {
        let mut ix = PrefixIndex::new();
        let k = PrefixKey::of_bytes(b"hello");
        assert!(ix.lookup(k, 0).is_none());
        assert!(ix.insert(k, 4, 64, gpu(0, 4), 1.0, 10).is_none());
        let hit = ix.lookup(k, 20).unwrap();
        assert_eq!(hit.blocks, 4);
        assert_eq!(hit.location, PrefixLocation::Gpu);
        assert_eq!(ix.total_hits(), 1);
        assert_eq!(ix.resident_gpu_blocks(), 4);
    }

    #[test]
    fn gpu_cpu_gpu_residency_round_trip() {
        let mut ix = PrefixIndex::new();
        let k = PrefixKey::of_bytes(b"x");
        ix.insert(k, 2, 32, gpu(5, 2), 1.0, 0);
        // Gpu → Cpu: the GPU backing comes back out for the D2H ride.
        let freed = ix
            .demote_to_cpu(k, vec![CpuBlockId(0), CpuBlockId(1)])
            .unwrap();
        assert_eq!(freed.len(), 2);
        assert_eq!(ix.lookup(k, 1).unwrap().location, PrefixLocation::Cpu);
        assert_eq!(ix.resident_gpu_blocks(), 0);
        assert_eq!(ix.resident_cpu_blocks(), 2);
        // Cpu → Gpu: a fresh local insert displaces the CPU copy.
        let displaced = ix.insert(k, 2, 32, gpu(9, 2), 1.0, 2).unwrap();
        assert!(matches!(displaced, PrefixBacking::Cpu(v) if v.len() == 2));
        assert_eq!(ix.lookup(k, 3).unwrap().location, PrefixLocation::Gpu);
        assert_eq!(ix.resident_cpu_blocks(), 0);
        assert_eq!(ix.resident_gpu_blocks(), 2);
    }

    #[test]
    fn lru_eviction_order_and_deterministic_tie_break() {
        let mut ix = PrefixIndex::new();
        let k1 = PrefixKey(1);
        let k2 = PrefixKey(2);
        ix.insert(k1, 1, 16, gpu(0, 1), 1.0, 100);
        ix.insert(k2, 2, 32, gpu(1, 2), 1.0, 200);
        ix.lookup(k1, 300); // refresh k1; k2 is now LRU
        assert_eq!(ix.peek_lru_gpu(), Some((k2, 2)));
        let b = ix.remove(k2).unwrap();
        assert!(matches!(b, PrefixBacking::Gpu(s) if s.len() == 2));
        assert_eq!(ix.len(), 1);
        // Exact recency tie: the smaller key evicts first, regardless of
        // HashMap storage order.
        let mut ix = PrefixIndex::new();
        ix.insert(PrefixKey(9), 1, 16, gpu(0, 1), 1.0, 50);
        ix.insert(PrefixKey(3), 1, 16, gpu(1, 1), 1.0, 50);
        ix.insert(PrefixKey(7), 1, 16, gpu(2, 1), 1.0, 50);
        assert_eq!(ix.peek_lru_gpu(), Some((PrefixKey(3), 1)));
    }

    #[test]
    fn tiered_reclaim_prefers_batch_then_lru_within_tier() {
        let mut ix = PrefixIndex::new();
        // Interactive (0) is the oldest entry — plain LRU would take
        // it — but tier-aware reclaim prefers the Batch (2) entries,
        // LRU-first among themselves.
        ix.insert_tiered(PrefixKey(1), 1, 16, gpu(0, 1), 1.0, 10, 0);
        ix.insert_tiered(PrefixKey(2), 2, 32, gpu(1, 2), 1.0, 20, 2);
        ix.insert_tiered(PrefixKey(3), 1, 16, gpu(3, 1), 1.0, 30, 2);
        assert_eq!(ix.peek_lru_gpu(), Some((PrefixKey(1), 1)));
        assert_eq!(ix.peek_lru_gpu_tiered(), Some((PrefixKey(2), 2)));
        ix.remove(PrefixKey(2)).unwrap();
        assert_eq!(ix.peek_lru_gpu_tiered(), Some((PrefixKey(3), 1)));
        ix.remove(PrefixKey(3)).unwrap();
        // Only the Interactive entry left: it is the victim of last
        // resort, and the untiered `insert` defaults to Standard.
        assert_eq!(ix.peek_lru_gpu_tiered(), Some((PrefixKey(1), 1)));
        ix.insert(PrefixKey(4), 1, 16, gpu(5, 1), 1.0, 40);
        assert_eq!(ix.peek_lru_gpu_tiered(), Some((PrefixKey(4), 1)));
    }

    #[test]
    fn pinned_entries_refuse_eviction_and_displacement() {
        let mut ix = PrefixIndex::new();
        let k = PrefixKey(4);
        let cpu = PrefixBacking::Cpu(vec![CpuBlockId(0), CpuBlockId(1)]);
        ix.insert(k, 2, 32, cpu, 1.0, 0);
        ix.pin(k);
        assert!(ix.remove(k).is_none(), "pinned entry must not evict");
        assert!(ix.peek_lru_cpu_unpinned().is_none());
        // Displacement rejected: the offered backing bounces back.
        let offered = ix.insert(k, 2, 32, gpu(0, 2), 1.0, 5);
        assert!(matches!(offered, Some(PrefixBacking::Gpu(_))));
        assert_eq!(ix.location_of(k), Some(PrefixLocation::Cpu));
        ix.unpin(k);
        assert!(ix.remove(k).is_some());
        assert_eq!(ix.resident_cpu_blocks(), 0);
    }

    #[test]
    fn drain_all_empties_even_pinned_entries_in_key_order() {
        let mut ix = PrefixIndex::new();
        ix.insert(PrefixKey(9), 1, 16, gpu(0, 1), 1.0, 10);
        ix.insert(
            PrefixKey(2),
            2,
            32,
            PrefixBacking::Cpu(vec![CpuBlockId(0), CpuBlockId(1)]),
            1.0,
            20,
        );
        ix.insert(PrefixKey(5), 3, 48, PrefixBacking::Remote, 2.0, 30);
        ix.pin(PrefixKey(2));
        let drained = ix.drain_all();
        let keys: Vec<u64> = drained.iter().map(|(k, _)| k.0).collect();
        assert_eq!(keys, vec![2, 5, 9]);
        assert!(ix.is_empty());
        assert_eq!(ix.resident_gpu_blocks(), 0);
        assert_eq!(ix.resident_cpu_blocks(), 0);
        assert!(ix.peek_lru_gpu().is_none());
        assert!(ix.peek_lru_cpu_unpinned().is_none());
    }

    #[test]
    fn remote_pointers_have_no_backing() {
        let mut ix = PrefixIndex::new();
        let k = PrefixKey(11);
        ix.insert(k, 3, 48, PrefixBacking::Remote, 2.0, 0);
        assert_eq!(ix.resident_gpu_blocks(), 0);
        assert_eq!(ix.resident_cpu_blocks(), 0);
        let hit = ix.lookup(k, 1).unwrap();
        assert_eq!(hit.location, PrefixLocation::Remote);
        assert_eq!(hit.upload_factor, 2.0);
        assert!(ix.remove_pointer(k));
        assert!(ix.is_empty());
        // remove_pointer never drops a real copy.
        ix.insert(k, 1, 16, gpu(0, 1), 1.0, 2);
        assert!(!ix.remove_pointer(k));
        assert_eq!(ix.len(), 1);
    }
}
