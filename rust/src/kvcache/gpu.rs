//! GPU KV block pool with dynamic shared/reserved partitioning (§5.1).
//!
//! Reservation is *accounting*, not physical partitioning: any free block
//! can serve any request, but the pool guarantees that the unused part of
//! each critical agent type's quota is never handed to shared allocations.
//! This matches the paper: non-critical work cannot exhaust the blocks the
//! Spatial Scheduler set aside for critical-path agents.

use std::collections::HashMap;

use super::{AgentTypeId, BlockId};

/// Which capacity region an allocation is charged to (§3.2 phase 4:
/// "routing each waiting request to shared capacity, reserved capacity,
/// or deferral").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Charge the globally shared pool only.
    Shared,
    /// Allow drawing from this type's reserved quota (then shared).
    Reserved(AgentTypeId),
}

/// Result of an allocation attempt.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AllocOutcome {
    /// Blocks granted; `reserved_charged` of them count against the type's
    /// quota and must be reported back on free.
    Granted {
        blocks: Vec<BlockId>,
        reserved_charged: u32,
    },
    /// Not enough capacity on the requested route.
    Deferred,
}

/// The GPU KV block pool.
#[derive(Debug, Clone)]
pub struct GpuPool {
    total: u32,
    free: Vec<BlockId>,
    /// Blocks released by their owner but still being read by an in-flight
    /// D2H transfer (§6.3 pending-free protocol).
    pending_free: u32,
    /// Reserved quota per critical agent type (blocks).
    quotas: HashMap<AgentTypeId, u32>,
    /// Blocks currently allocated under each type's quota.
    quota_used: HashMap<AgentTypeId, u32>,
}

impl GpuPool {
    pub fn new(total: u32) -> Self {
        Self {
            total,
            free: (0..total).rev().map(BlockId).collect(),
            pending_free: 0,
            quotas: HashMap::new(),
            quota_used: HashMap::new(),
        }
    }

    pub fn total(&self) -> u32 {
        self.total
    }

    /// Physically free blocks (includes reserved headroom; excludes
    /// pending-free).
    pub fn free_blocks(&self) -> u32 {
        self.free.len() as u32
    }

    /// Blocks in pending-free limbo (unreusable until transfer completes).
    pub fn pending_free_blocks(&self) -> u32 {
        self.pending_free
    }

    /// Blocks currently allocated to live requests (excludes pending-free).
    pub fn used_blocks(&self) -> u32 {
        self.total - self.free_blocks() - self.pending_free
    }

    /// Occupancy fraction counting pending-free as occupied (they are).
    pub fn usage(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        1.0 - self.free_blocks() as f64 / self.total as f64
    }

    /// Unused reserved headroom across all types.
    pub fn outstanding_reserved(&self) -> u32 {
        self.quotas
            .iter()
            .map(|(t, q)| q.saturating_sub(self.quota_used(*t)))
            .sum()
    }

    /// Free blocks available to *shared* allocations.
    pub fn shared_free(&self) -> u32 {
        self.free_blocks().saturating_sub(self.outstanding_reserved())
    }

    pub fn quota(&self, t: AgentTypeId) -> u32 {
        self.quotas.get(&t).copied().unwrap_or(0)
    }

    pub fn quota_used(&self, t: AgentTypeId) -> u32 {
        self.quota_used.get(&t).copied().unwrap_or(0)
    }

    /// Reserved headroom for a type.
    pub fn headroom(&self, t: AgentTypeId) -> u32 {
        self.quota(t).saturating_sub(self.quota_used(t))
    }

    /// Total reserved quota across all types.
    pub fn total_quota(&self) -> u32 {
        self.quotas.values().sum()
    }

    /// Install a new reservation plan (Algorithm 2, step 3 output).
    /// Quotas are clamped so outstanding headroom never exceeds what the
    /// pool could actually deliver.
    pub fn set_quotas(&mut self, plan: &[(AgentTypeId, u32)]) {
        self.quotas.clear();
        for &(t, q) in plan {
            if q > 0 {
                self.quotas.insert(t, q);
            }
        }
        // Drop stale usage entries for types no longer reserved (their
        // in-flight blocks keep counting until freed, tracked separately).
        self.quota_used.retain(|_, used| *used > 0);
    }

    /// Capacity visible to a request on the given route.
    pub fn available_for(&self, route: Route) -> u32 {
        match route {
            Route::Shared => self.shared_free(),
            Route::Reserved(t) => {
                // Own headroom is usable in addition to the shared region,
                // but never more than physically free.
                (self.shared_free() + self.headroom(t))
                    .min(self.free_blocks())
            }
        }
    }

    /// Try to allocate `n` blocks on a route.
    pub fn alloc(&mut self, n: u32, route: Route) -> AllocOutcome {
        if n == 0 {
            return AllocOutcome::Granted {
                blocks: Vec::new(),
                reserved_charged: 0,
            };
        }
        if self.available_for(route) < n || self.free_blocks() < n {
            return AllocOutcome::Deferred;
        }
        let reserved_charged = match route {
            Route::Shared => 0,
            Route::Reserved(t) => {
                let charge = n.min(self.headroom(t));
                *self.quota_used.entry(t).or_insert(0) += charge;
                charge
            }
        };
        let blocks = self.pop_n(n);
        AllocOutcome::Granted {
            blocks,
            reserved_charged,
        }
    }

    fn pop_n(&mut self, n: u32) -> Vec<BlockId> {
        let at = self.free.len() - n as usize;
        self.free.split_off(at)
    }

    /// Return blocks to the pool, un-charging any reserved accounting.
    pub fn free(
        &mut self,
        blocks: Vec<BlockId>,
        charged: u32,
        t: Option<AgentTypeId>,
    ) {
        if charged > 0 {
            let t = t.expect("reserved charge without a type");
            let used = self.quota_used.entry(t).or_insert(0);
            *used = used.saturating_sub(charged);
        }
        self.free.extend(blocks);
        debug_assert!(
            self.free.len() as u32 + self.pending_free + self.used_blocks()
                == self.total
        );
    }

    /// Move blocks into pending-free: owner released them, but an in-flight
    /// D2H copy still reads them. Reserved accounting is released now (the
    /// request no longer occupies quota) but the physical blocks return to
    /// the free list only via [`Self::complete_pending`].
    pub fn mark_pending_free(
        &mut self,
        blocks: &[BlockId],
        charged: u32,
        t: Option<AgentTypeId>,
    ) {
        if charged > 0 {
            let t = t.expect("reserved charge without a type");
            let used = self.quota_used.entry(t).or_insert(0);
            *used = used.saturating_sub(charged);
        }
        self.pending_free += blocks.len() as u32;
    }

    /// Transfer finished: pending-free blocks become reusable.
    pub fn complete_pending(&mut self, blocks: Vec<BlockId>) {
        self.pending_free -= blocks.len() as u32;
        self.free.extend(blocks);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_roundtrip() {
        let mut p = GpuPool::new(100);
        assert_eq!(p.free_blocks(), 100);
        let out = p.alloc(10, Route::Shared);
        let AllocOutcome::Granted {
            blocks,
            reserved_charged,
        } = out
        else {
            panic!()
        };
        assert_eq!(blocks.len(), 10);
        assert_eq!(reserved_charged, 0);
        assert_eq!(p.used_blocks(), 10);
        p.free(blocks, 0, None);
        assert_eq!(p.free_blocks(), 100);
    }

    #[test]
    fn shared_cannot_touch_reserved_headroom() {
        let mut p = GpuPool::new(100);
        p.set_quotas(&[(1, 30)]);
        assert_eq!(p.shared_free(), 70);
        // 71 shared blocks must be refused even though 100 are free.
        assert_eq!(p.alloc(71, Route::Shared), AllocOutcome::Deferred);
        // 70 succeed.
        assert!(matches!(
            p.alloc(70, Route::Shared),
            AllocOutcome::Granted { .. }
        ));
        // Critical type can still take its 30.
        assert!(matches!(
            p.alloc(30, Route::Reserved(1)),
            AllocOutcome::Granted {
                reserved_charged: 30,
                ..
            }
        ));
        assert_eq!(p.free_blocks(), 0);
    }

    #[test]
    fn reserved_route_draws_quota_then_shared() {
        let mut p = GpuPool::new(100);
        p.set_quotas(&[(1, 20)]);
        // Type 1 asks for 50: 20 charged to quota, 30 from shared.
        let AllocOutcome::Granted {
            reserved_charged, ..
        } = p.alloc(50, Route::Reserved(1))
        else {
            panic!()
        };
        assert_eq!(reserved_charged, 20);
        assert_eq!(p.headroom(1), 0);
        assert_eq!(p.shared_free(), 50);
    }

    #[test]
    fn other_types_cannot_use_foreign_quota() {
        let mut p = GpuPool::new(50);
        p.set_quotas(&[(1, 30)]);
        // Type 2 has no quota: behaves like shared.
        assert_eq!(p.available_for(Route::Reserved(2)), 20);
        assert_eq!(p.alloc(25, Route::Reserved(2)), AllocOutcome::Deferred);
    }

    #[test]
    fn free_releases_quota_charge() {
        let mut p = GpuPool::new(40);
        p.set_quotas(&[(7, 10)]);
        let AllocOutcome::Granted {
            blocks,
            reserved_charged,
        } = p.alloc(10, Route::Reserved(7))
        else {
            panic!()
        };
        assert_eq!(p.headroom(7), 0);
        p.free(blocks, reserved_charged, Some(7));
        assert_eq!(p.headroom(7), 10);
    }

    #[test]
    fn pending_free_blocks_not_reusable_until_complete() {
        let mut p = GpuPool::new(20);
        let AllocOutcome::Granted { blocks, .. } = p.alloc(15, Route::Shared)
        else {
            panic!()
        };
        p.mark_pending_free(&blocks, 0, None);
        assert_eq!(p.free_blocks(), 5);
        assert_eq!(p.pending_free_blocks(), 15);
        assert_eq!(p.usage(), 1.0 - 5.0 / 20.0);
        assert_eq!(p.alloc(10, Route::Shared), AllocOutcome::Deferred);
        p.complete_pending(blocks);
        assert_eq!(p.free_blocks(), 20);
        assert!(matches!(
            p.alloc(10, Route::Shared),
            AllocOutcome::Granted { .. }
        ));
    }

    #[test]
    fn quota_update_respects_inflight_usage() {
        let mut p = GpuPool::new(100);
        p.set_quotas(&[(1, 30)]);
        let AllocOutcome::Granted { .. } = p.alloc(30, Route::Reserved(1))
        else {
            panic!()
        };
        // Quota shrinks below current use: headroom clamps to zero, no
        // underflow.
        p.set_quotas(&[(1, 10)]);
        assert_eq!(p.headroom(1), 0);
        assert_eq!(p.outstanding_reserved(), 0);
    }

    #[test]
    fn zero_alloc_is_trivially_granted() {
        let mut p = GpuPool::new(1);
        assert!(matches!(
            p.alloc(0, Route::Shared),
            AllocOutcome::Granted { .. }
        ));
    }
}
