//! GPU KV block pool with dynamic shared/reserved partitioning (§5.1).
//!
//! Reservation is *accounting*, not physical partitioning: any free block
//! can serve any request, but the pool guarantees that the unused part of
//! each critical agent type's quota is never handed to shared allocations.
//! This matches the paper: non-critical work cannot exhaust the blocks the
//! Spatial Scheduler set aside for critical-path agents.
//!
//! The free list is an ordered **extent map** (start → run length,
//! coalesced on free), so allocating or freeing a k-block request costs
//! O(extents touched) instead of O(k) per-block pushes, and every grant
//! comes back as a compact [`BlockSet`].

use std::collections::{BTreeMap, HashMap};

use super::{AgentTypeId, BlockSet, Extent};

/// Which capacity region an allocation is charged to (§3.2 phase 4:
/// "routing each waiting request to shared capacity, reserved capacity,
/// or deferral").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Charge the globally shared pool only.
    Shared,
    /// Allow drawing from this type's reserved quota (then shared).
    Reserved(AgentTypeId),
}

/// Result of an allocation attempt.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AllocOutcome {
    /// Blocks granted; `reserved_charged` of them count against the type's
    /// quota and must be reported back on free.
    Granted {
        blocks: BlockSet,
        reserved_charged: u32,
    },
    /// Not enough capacity on the requested route.
    Deferred,
}

/// The GPU KV block pool.
#[derive(Debug, Clone)]
pub struct GpuPool {
    total: u32,
    /// Free extents: start → length, sorted, coalesced, non-overlapping.
    free: BTreeMap<u32, u32>,
    /// Cached Σ lengths of `free` (kept exact by every mutation).
    free_blocks: u32,
    /// Blocks released by their owner but still being read by an in-flight
    /// D2H transfer (§6.3 pending-free protocol).
    pending_free: u32,
    /// Reserved quota per critical agent type (blocks).
    quotas: HashMap<AgentTypeId, u32>,
    /// Blocks currently allocated under each type's quota.
    quota_used: HashMap<AgentTypeId, u32>,
}

impl GpuPool {
    pub fn new(total: u32) -> Self {
        let mut free = BTreeMap::new();
        if total > 0 {
            free.insert(0, total);
        }
        Self {
            total,
            free,
            free_blocks: total,
            pending_free: 0,
            quotas: HashMap::new(),
            quota_used: HashMap::new(),
        }
    }

    pub fn total(&self) -> u32 {
        self.total
    }

    /// Physically free blocks (includes reserved headroom; excludes
    /// pending-free).
    pub fn free_blocks(&self) -> u32 {
        self.free_blocks
    }

    /// Blocks in pending-free limbo (unreusable until transfer completes).
    pub fn pending_free_blocks(&self) -> u32 {
        self.pending_free
    }

    /// Blocks currently allocated to live requests (excludes pending-free).
    pub fn used_blocks(&self) -> u32 {
        self.total - self.free_blocks() - self.pending_free
    }

    /// Occupancy fraction counting pending-free as occupied (they are).
    pub fn usage(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        1.0 - self.free_blocks() as f64 / self.total as f64
    }

    /// Unused reserved headroom across all types.
    pub fn outstanding_reserved(&self) -> u32 {
        self.quotas
            .iter()
            .map(|(t, q)| q.saturating_sub(self.quota_used(*t)))
            .sum()
    }

    /// Free blocks available to *shared* allocations.
    pub fn shared_free(&self) -> u32 {
        self.free_blocks().saturating_sub(self.outstanding_reserved())
    }

    pub fn quota(&self, t: AgentTypeId) -> u32 {
        self.quotas.get(&t).copied().unwrap_or(0)
    }

    pub fn quota_used(&self, t: AgentTypeId) -> u32 {
        self.quota_used.get(&t).copied().unwrap_or(0)
    }

    /// Reserved headroom for a type.
    pub fn headroom(&self, t: AgentTypeId) -> u32 {
        self.quota(t).saturating_sub(self.quota_used(t))
    }

    /// Total reserved quota across all types.
    pub fn total_quota(&self) -> u32 {
        self.quotas.values().sum()
    }

    /// Install a new reservation plan (Algorithm 2, step 3 output).
    /// Quotas are clamped so outstanding headroom never exceeds what the
    /// pool could actually deliver.
    pub fn set_quotas(&mut self, plan: &[(AgentTypeId, u32)]) {
        self.quotas.clear();
        for &(t, q) in plan {
            if q > 0 {
                self.quotas.insert(t, q);
            }
        }
        // Drop stale usage entries for types no longer reserved (their
        // in-flight blocks keep counting until freed, tracked separately).
        self.quota_used.retain(|_, used| *used > 0);
    }

    /// Capacity visible to a request on the given route.
    pub fn available_for(&self, route: Route) -> u32 {
        match route {
            Route::Shared => self.shared_free(),
            Route::Reserved(t) => {
                // Own headroom is usable in addition to the shared region,
                // but never more than physically free.
                (self.shared_free() + self.headroom(t))
                    .min(self.free_blocks())
            }
        }
    }

    /// Try to allocate `n` blocks on a route.
    pub fn alloc(&mut self, n: u32, route: Route) -> AllocOutcome {
        if n == 0 {
            return AllocOutcome::Granted {
                blocks: BlockSet::new(),
                reserved_charged: 0,
            };
        }
        if self.available_for(route) < n || self.free_blocks() < n {
            return AllocOutcome::Deferred;
        }
        let reserved_charged = match route {
            Route::Shared => 0,
            Route::Reserved(t) => {
                let charge = n.min(self.headroom(t));
                *self.quota_used.entry(t).or_insert(0) += charge;
                charge
            }
        };
        let blocks = self.pop_n(n);
        AllocOutcome::Granted {
            blocks,
            reserved_charged,
        }
    }

    /// Take `n` blocks, carving from the LOW end of the highest-start
    /// free extent: successive growth allocations of one request are
    /// then handed ascending-adjacent runs, which [`BlockSet::absorb`]
    /// merges — a context that grows k blocks stays a single extent
    /// while the region is contiguous. O(extents consumed).
    fn pop_n(&mut self, n: u32) -> BlockSet {
        let mut out = BlockSet::new();
        let mut need = n;
        while need > 0 {
            let (&start, &len) = self
                .free
                .iter()
                .next_back()
                .expect("pop_n: free list underflow");
            if len <= need {
                self.free.remove(&start);
                out.push(Extent { start, len });
                need -= len;
            } else {
                self.free.remove(&start);
                self.free.insert(start + need, len - need);
                out.push(Extent { start, len: need });
                need = 0;
            }
        }
        self.free_blocks -= n;
        out
    }

    /// Insert one extent into the free map, coalescing with both
    /// neighbors. Overlap (double free) trips the debug assertions.
    fn insert_extent(&mut self, e: Extent) {
        if e.len == 0 {
            return;
        }
        let mut start = e.start;
        let mut len = e.len;
        if let Some((&ps, &pl)) = self.free.range(..=start).next_back() {
            debug_assert!(ps + pl <= start, "double free below {start}");
            if ps + pl == start {
                self.free.remove(&ps);
                start = ps;
                len += pl;
            }
        }
        if let Some((&ns, &nl)) = self.free.range(start..).next() {
            debug_assert!(start + len <= ns, "double free above {start}");
            if start + len == ns {
                self.free.remove(&ns);
                len += nl;
            }
        }
        self.free.insert(start, len);
        self.free_blocks += e.len;
    }

    /// Return blocks to the pool, un-charging any reserved accounting.
    pub fn free(
        &mut self,
        blocks: BlockSet,
        charged: u32,
        t: Option<AgentTypeId>,
    ) {
        if charged > 0 {
            let t = t.expect("reserved charge without a type");
            let used = self.quota_used.entry(t).or_insert(0);
            *used = used.saturating_sub(charged);
        }
        for &e in blocks.extents() {
            self.insert_extent(e);
        }
        debug_assert_eq!(
            self.free.values().sum::<u32>(),
            self.free_blocks,
            "free-list accounting drift"
        );
    }

    /// Move blocks into pending-free: owner released them, but an in-flight
    /// D2H copy still reads them. Reserved accounting is released now (the
    /// request no longer occupies quota) but the physical blocks return to
    /// the free list only via [`Self::complete_pending`].
    pub fn mark_pending_free(
        &mut self,
        blocks: &BlockSet,
        charged: u32,
        t: Option<AgentTypeId>,
    ) {
        if charged > 0 {
            let t = t.expect("reserved charge without a type");
            let used = self.quota_used.entry(t).or_insert(0);
            *used = used.saturating_sub(charged);
        }
        self.pending_free += blocks.len();
    }

    /// Transfer finished: pending-free blocks become reusable.
    pub fn complete_pending(&mut self, blocks: BlockSet) {
        self.pending_free -= blocks.len();
        for &e in blocks.extents() {
            self.insert_extent(e);
        }
    }

    /// Snapshot of the free extents (tests / invariant checks).
    pub fn free_extents(&self) -> Vec<Extent> {
        self.free
            .iter()
            .map(|(&start, &len)| Extent { start, len })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_roundtrip() {
        let mut p = GpuPool::new(100);
        assert_eq!(p.free_blocks(), 100);
        let out = p.alloc(10, Route::Shared);
        let AllocOutcome::Granted {
            blocks,
            reserved_charged,
        } = out
        else {
            panic!()
        };
        assert_eq!(blocks.len(), 10);
        assert_eq!(reserved_charged, 0);
        assert_eq!(p.used_blocks(), 10);
        p.free(blocks, 0, None);
        assert_eq!(p.free_blocks(), 100);
        // Everything coalesced back into one extent.
        assert_eq!(p.free_extents().len(), 1);
    }

    #[test]
    fn shared_cannot_touch_reserved_headroom() {
        let mut p = GpuPool::new(100);
        p.set_quotas(&[(1, 30)]);
        assert_eq!(p.shared_free(), 70);
        // 71 shared blocks must be refused even though 100 are free.
        assert_eq!(p.alloc(71, Route::Shared), AllocOutcome::Deferred);
        // 70 succeed.
        assert!(matches!(
            p.alloc(70, Route::Shared),
            AllocOutcome::Granted { .. }
        ));
        // Critical type can still take its 30.
        assert!(matches!(
            p.alloc(30, Route::Reserved(1)),
            AllocOutcome::Granted {
                reserved_charged: 30,
                ..
            }
        ));
        assert_eq!(p.free_blocks(), 0);
    }

    #[test]
    fn reserved_route_draws_quota_then_shared() {
        let mut p = GpuPool::new(100);
        p.set_quotas(&[(1, 20)]);
        // Type 1 asks for 50: 20 charged to quota, 30 from shared.
        let AllocOutcome::Granted {
            reserved_charged, ..
        } = p.alloc(50, Route::Reserved(1))
        else {
            panic!()
        };
        assert_eq!(reserved_charged, 20);
        assert_eq!(p.headroom(1), 0);
        assert_eq!(p.shared_free(), 50);
    }

    #[test]
    fn other_types_cannot_use_foreign_quota() {
        let mut p = GpuPool::new(50);
        p.set_quotas(&[(1, 30)]);
        // Type 2 has no quota: behaves like shared.
        assert_eq!(p.available_for(Route::Reserved(2)), 20);
        assert_eq!(p.alloc(25, Route::Reserved(2)), AllocOutcome::Deferred);
    }

    #[test]
    fn free_releases_quota_charge() {
        let mut p = GpuPool::new(40);
        p.set_quotas(&[(7, 10)]);
        let AllocOutcome::Granted {
            blocks,
            reserved_charged,
        } = p.alloc(10, Route::Reserved(7))
        else {
            panic!()
        };
        assert_eq!(p.headroom(7), 0);
        p.free(blocks, reserved_charged, Some(7));
        assert_eq!(p.headroom(7), 10);
    }

    #[test]
    fn pending_free_blocks_not_reusable_until_complete() {
        let mut p = GpuPool::new(20);
        let AllocOutcome::Granted { blocks, .. } = p.alloc(15, Route::Shared)
        else {
            panic!()
        };
        p.mark_pending_free(&blocks, 0, None);
        assert_eq!(p.free_blocks(), 5);
        assert_eq!(p.pending_free_blocks(), 15);
        assert_eq!(p.usage(), 1.0 - 5.0 / 20.0);
        assert_eq!(p.alloc(10, Route::Shared), AllocOutcome::Deferred);
        p.complete_pending(blocks);
        assert_eq!(p.free_blocks(), 20);
        assert!(matches!(
            p.alloc(10, Route::Shared),
            AllocOutcome::Granted { .. }
        ));
    }

    #[test]
    fn quota_update_respects_inflight_usage() {
        let mut p = GpuPool::new(100);
        p.set_quotas(&[(1, 30)]);
        let AllocOutcome::Granted { .. } = p.alloc(30, Route::Reserved(1))
        else {
            panic!()
        };
        // Quota shrinks below current use: headroom clamps to zero, no
        // underflow.
        p.set_quotas(&[(1, 10)]);
        assert_eq!(p.headroom(1), 0);
        assert_eq!(p.outstanding_reserved(), 0);
    }

    #[test]
    fn zero_alloc_is_trivially_granted() {
        let mut p = GpuPool::new(1);
        assert!(matches!(
            p.alloc(0, Route::Shared),
            AllocOutcome::Granted { .. }
        ));
    }

    #[test]
    fn interleaved_free_coalesces_extents() {
        let mut p = GpuPool::new(32);
        let AllocOutcome::Granted { blocks: a, .. } =
            p.alloc(8, Route::Shared)
        else {
            panic!()
        };
        let AllocOutcome::Granted { blocks: b, .. } =
            p.alloc(8, Route::Shared)
        else {
            panic!()
        };
        let AllocOutcome::Granted { blocks: c, .. } =
            p.alloc(8, Route::Shared)
        else {
            panic!()
        };
        // Free the middle slice first: no coalescing possible yet.
        p.free(b, 0, None);
        assert_eq!(p.free_extents().len(), 2);
        // Freeing its neighbors merges everything back into one run.
        p.free(a, 0, None);
        p.free(c, 0, None);
        assert_eq!(p.free_blocks(), 32);
        assert_eq!(p.free_extents().len(), 1);
        assert_eq!(p.free_extents()[0], Extent { start: 0, len: 32 });
    }
}
