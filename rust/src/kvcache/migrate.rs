//! Migration ledger: in-flight D2H/H2D transfers with pending-free
//! semantics (§6.3 "CPU Migration Infrastructure").
//!
//! All migration is issued asynchronously on a dedicated stream; source GPU
//! blocks are marked pending-free immediately and return to the free pool
//! only when the copy completes. The ledger owns that bookkeeping plus the
//! swap-volume statistics the ablation study reports (§7.3). GPU blocks
//! ride the ledger as compact [`BlockSet`] extents, so a transfer record
//! is O(extents), not O(blocks).

use std::collections::HashMap;

use super::{BlockSet, CpuBlockId, PrefixKey};

/// Transfer identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TransferId(pub u64);

/// Transfer direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// GPU → CPU (offload).
    D2H,
    /// CPU → GPU (upload).
    H2D,
}

/// What the transfer moves — the completion handler dispatches on this.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferKind {
    /// A request's KV cache (the classic offload/upload path).
    Request,
    /// Prefix-cache demotion: index-owned GPU backing riding D2H while
    /// the entry already answers lookups from its CPU copy.
    PrefixEvict { key: PrefixKey },
    /// Prefix-cache CPU/remote hit materializing into an admitted
    /// request's own blocks (H2D debt that gates the request's start).
    /// `pinned` records whether issuing the hit pinned the source entry
    /// (CPU-resident sources only — remote pointers have no local
    /// backing to pin), so completion/cancel unpins exactly once.
    PrefixHit { key: PrefixKey, pinned: bool },
}

/// One in-flight block migration.
#[derive(Debug, Clone)]
pub struct Transfer {
    pub id: TransferId,
    pub req_id: u64,
    pub dir: Direction,
    pub kind: TransferKind,
    pub gpu_blocks: BlockSet,
    pub cpu_blocks: Vec<CpuBlockId>,
    pub issued_us: u64,
    pub completes_us: u64,
}

impl Transfer {
    pub fn blocks(&self) -> u32 {
        self.gpu_blocks.len()
    }
}

/// Ledger of in-flight transfers + lifetime statistics.
#[derive(Debug, Default)]
pub struct MigrationLedger {
    next_id: u64,
    inflight: HashMap<TransferId, Transfer>,
    // ---- lifetime stats ----
    pub offload_count: u64,
    pub upload_count: u64,
    pub offload_blocks: u64,
    pub upload_blocks: u64,
}

impl MigrationLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a new request-KV transfer; returns its id.
    #[allow(clippy::too_many_arguments)]
    pub fn issue(
        &mut self,
        req_id: u64,
        dir: Direction,
        gpu_blocks: BlockSet,
        cpu_blocks: Vec<CpuBlockId>,
        issued_us: u64,
        completes_us: u64,
    ) -> TransferId {
        self.issue_tagged(
            TransferKind::Request,
            req_id,
            dir,
            gpu_blocks,
            cpu_blocks,
            issued_us,
            completes_us,
        )
    }

    /// Register a transfer with an explicit kind (prefix-cache traffic
    /// rides the same ledger and the same bandwidth accounting).
    #[allow(clippy::too_many_arguments)]
    pub fn issue_tagged(
        &mut self,
        kind: TransferKind,
        req_id: u64,
        dir: Direction,
        gpu_blocks: BlockSet,
        cpu_blocks: Vec<CpuBlockId>,
        issued_us: u64,
        completes_us: u64,
    ) -> TransferId {
        let id = TransferId(self.next_id);
        self.next_id += 1;
        let n = gpu_blocks.len() as u64;
        match dir {
            Direction::D2H => {
                self.offload_count += 1;
                self.offload_blocks += n;
            }
            Direction::H2D => {
                self.upload_count += 1;
                self.upload_blocks += n;
            }
        }
        self.inflight.insert(
            id,
            Transfer {
                id,
                req_id,
                dir,
                kind,
                gpu_blocks,
                cpu_blocks,
                issued_us,
                completes_us,
            },
        );
        id
    }

    /// Complete a transfer, removing it from the in-flight set.
    pub fn complete(&mut self, id: TransferId) -> Option<Transfer> {
        self.inflight.remove(&id)
    }

    pub fn get(&self, id: TransferId) -> Option<&Transfer> {
        self.inflight.get(&id)
    }

    pub fn inflight_count(&self) -> usize {
        self.inflight.len()
    }

    /// Total blocks currently being uploaded for a request (upload debt in
    /// the pressure snapshot).
    pub fn inflight_upload_blocks(&self) -> u32 {
        self.inflight
            .values()
            .filter(|t| t.dir == Direction::H2D)
            .map(|t| t.blocks())
            .sum()
    }

    /// Total blocks currently leaving the GPU (D2H). The batched offload
    /// planner caps this: `cap − inflight` is the bandwidth budget a
    /// planning event may spend on new victims, so a burst of stalls
    /// drains as one bounded multi-victim batch instead of an unbounded
    /// fan-out of parallel transfers.
    pub fn inflight_offload_blocks(&self) -> u32 {
        self.inflight
            .values()
            .filter(|t| t.dir == Direction::D2H)
            .map(|t| t.blocks())
            .sum()
    }

    /// Total swap volume in blocks, both directions (§7.3's metric).
    pub fn swap_volume_blocks(&self) -> u64 {
        self.offload_blocks + self.upload_blocks
    }

    /// Take every in-flight transfer out of the ledger at once, sorted
    /// by id (issue order) so callers iterate deterministically. Crash
    /// recovery uses this: a dead shard's wire traffic must be closed
    /// in one sweep, not completed one event at a time.
    pub fn drain_inflight(&mut self) -> Vec<Transfer> {
        let mut out: Vec<Transfer> =
            self.inflight.drain().map(|(_, t)| t).collect();
        out.sort_by_key(|t| t.id.0);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn issue_complete_roundtrip() {
        let mut l = MigrationLedger::new();
        let id = l.issue(
            7,
            Direction::D2H,
            BlockSet::from_extent(1, 2),
            vec![CpuBlockId(0), CpuBlockId(1)],
            100,
            300,
        );
        assert_eq!(l.inflight_count(), 1);
        let t = l.complete(id).unwrap();
        assert_eq!(t.req_id, 7);
        assert_eq!(t.blocks(), 2);
        assert_eq!(t.completes_us, 300);
        assert_eq!(l.inflight_count(), 0);
        assert!(l.complete(id).is_none());
    }

    #[test]
    fn stats_accumulate() {
        let mut l = MigrationLedger::new();
        let a = l.issue(
            1,
            Direction::D2H,
            BlockSet::from_extent(0, 1),
            vec![],
            0,
            1,
        );
        let b = l.issue(
            1,
            Direction::H2D,
            BlockSet::from_extent(0, 1),
            vec![CpuBlockId(9)],
            2,
            3,
        );
        assert_eq!(l.offload_count, 1);
        assert_eq!(l.upload_count, 1);
        assert_eq!(l.swap_volume_blocks(), 2);
        assert_eq!(l.inflight_upload_blocks(), 1);
        assert_eq!(l.inflight_offload_blocks(), 1);
        l.complete(a);
        l.complete(b);
        // Stats survive completion.
        assert_eq!(l.swap_volume_blocks(), 2);
        assert_eq!(l.inflight_upload_blocks(), 0);
        assert_eq!(l.inflight_offload_blocks(), 0);
    }

    #[test]
    fn tagged_transfers_carry_kind() {
        let mut l = MigrationLedger::new();
        let key = PrefixKey(7);
        let id = l.issue_tagged(
            TransferKind::PrefixEvict { key },
            u64::MAX,
            Direction::D2H,
            BlockSet::from_extent(0, 3),
            vec![],
            0,
            5,
        );
        let t = l.complete(id).unwrap();
        assert_eq!(t.kind, TransferKind::PrefixEvict { key });
        // The untagged path defaults to the request kind.
        let id = l.issue(1, Direction::H2D, BlockSet::new(), vec![], 0, 1);
        assert_eq!(l.get(id).unwrap().kind, TransferKind::Request);
    }

    #[test]
    fn drain_inflight_sorted_and_empties() {
        let mut l = MigrationLedger::new();
        for i in 0..5u64 {
            l.issue(
                i,
                Direction::D2H,
                BlockSet::from_extent(i as u32, 1),
                vec![],
                0,
                10,
            );
        }
        let drained = l.drain_inflight();
        assert_eq!(drained.len(), 5);
        assert!(drained.windows(2).all(|w| w[0].id.0 < w[1].id.0));
        assert_eq!(l.inflight_count(), 0);
        // Lifetime stats survive the drain.
        assert_eq!(l.offload_blocks, 5);
    }

    #[test]
    fn ids_unique() {
        let mut l = MigrationLedger::new();
        let a = l.issue(1, Direction::D2H, BlockSet::new(), vec![], 0, 1);
        let b = l.issue(2, Direction::D2H, BlockSet::new(), vec![], 0, 1);
        assert_ne!(a, b);
    }
}
