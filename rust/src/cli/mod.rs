//! Hand-rolled argument parsing (clap is not vendored offline).
//!
//! Grammar: `tokencake <command> [--flag value]... [--switch]...`

use std::collections::HashMap;

/// Parsed command line: one subcommand + flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(
        args: I,
    ) -> Result<Args, String> {
        let mut it = args.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        if command.starts_with('-') {
            return Err(format!("expected a command, got flag {command}"));
        }
        let mut out = Args {
            command,
            ..Default::default()
        };
        while let Some(a) = it.next() {
            let Some(name) = a.strip_prefix("--") else {
                return Err(format!("unexpected positional argument {a:?}"));
            };
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                }
                _ => out.switches.push(name.to_string()),
            }
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: bad number {v:?}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: bad integer {v:?}")),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
            || self.flags.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<Args, String> {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_command_flags_switches() {
        let a = parse("bench --qps 0.5 --apps 20 --verbose").unwrap();
        assert_eq!(a.command, "bench");
        assert_eq!(a.get("qps"), Some("0.5"));
        assert_eq!(a.get_u64("apps", 0).unwrap(), 20);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
        assert_eq!(a.get_f64("missing", 1.5).unwrap(), 1.5);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("--qps 1").is_err());
        assert!(parse("bench positional").is_err());
        assert!(parse("bench --qps notanumber")
            .unwrap()
            .get_f64("qps", 0.0)
            .is_err());
    }

    #[test]
    fn empty_defaults_to_help() {
        let a = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.command, "help");
    }
}
