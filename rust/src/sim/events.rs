//! Time-ordered event queue for the discrete-event engine.
//!
//! Generic over the payload so the engine defines its own event alphabet
//! (arrivals, tool completions, transfer completions) without circular
//! module dependencies. Ties are broken by insertion order (FIFO), which
//! keeps runs deterministic.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event scheduled at an absolute simulation time.
#[derive(Debug, Clone)]
pub struct Event<T> {
    pub at_us: u64,
    pub seq: u64,
    pub payload: T,
}

/// Engine event alphabet used by the sim engine (re-exported for tests).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EventKind {
    /// A new application instance arrives.
    AppArrival { app_idx: u32 },
    /// A function call (tool) completes for a request.
    ToolFinish { req_id: u64 },
    /// A D2H/H2D block transfer completes.
    TransferDone { xfer_id: u64 },
}

struct HeapEntry<T> {
    at_us: u64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for HeapEntry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.at_us == other.at_us && self.seq == other.seq
    }
}
impl<T> Eq for HeapEntry<T> {}
impl<T> PartialOrd for HeapEntry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for HeapEntry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        other
            .at_us
            .cmp(&self.at_us)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Min-heap of events ordered by (time, insertion sequence).
pub struct EventQueue<T> {
    heap: BinaryHeap<HeapEntry<T>>,
    next_seq: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            next_seq: 0,
        }
    }

    pub fn push(&mut self, at_us: u64, payload: T) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(HeapEntry {
            at_us,
            seq,
            payload,
        });
    }

    /// Timestamp of the earliest pending event.
    pub fn peek_time(&self) -> Option<u64> {
        self.heap.peek().map(|e| e.at_us)
    }

    /// Pop the earliest event if its time is <= `now_us`.
    pub fn pop_due(&mut self, now_us: u64) -> Option<Event<T>> {
        if self.peek_time()? <= now_us {
            let e = self.heap.pop().unwrap();
            Some(Event {
                at_us: e.at_us,
                seq: e.seq,
                payload: e.payload,
            })
        } else {
            None
        }
    }

    /// Pop the earliest event unconditionally.
    pub fn pop(&mut self) -> Option<Event<T>> {
        self.heap.pop().map(|e| Event {
            at_us: e.at_us,
            seq: e.seq,
            payload: e.payload,
        })
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time_then_fifo() {
        let mut q = EventQueue::new();
        q.push(20, "b");
        q.push(10, "a1");
        q.push(10, "a2");
        q.push(30, "c");
        assert_eq!(q.pop().unwrap().payload, "a1");
        assert_eq!(q.pop().unwrap().payload, "a2");
        assert_eq!(q.pop().unwrap().payload, "b");
        assert_eq!(q.pop().unwrap().payload, "c");
        assert!(q.pop().is_none());
    }

    #[test]
    fn pop_due_respects_now() {
        let mut q = EventQueue::new();
        q.push(100, 1u32);
        q.push(200, 2u32);
        assert!(q.pop_due(50).is_none());
        assert_eq!(q.pop_due(150).unwrap().payload, 1);
        assert!(q.pop_due(150).is_none());
        assert_eq!(q.peek_time(), Some(200));
    }

    #[test]
    fn len_tracks() {
        let mut q: EventQueue<()> = EventQueue::new();
        assert!(q.is_empty());
        q.push(1, ());
        q.push(2, ());
        assert_eq!(q.len(), 2);
        q.pop();
        assert_eq!(q.len(), 1);
    }
}
