//! Latency / arrival distributions used by the workload generator and the
//! tool simulator (Table 1 of the paper).

use super::rng::Rng;

/// A sampleable duration/interval distribution (microseconds or abstract).
#[derive(Debug, Clone, PartialEq)]
pub enum Dist {
    /// Always the same value.
    Constant(f64),
    /// Uniform in [lo, hi).
    Uniform(f64, f64),
    /// Lognormal parameterized by the *target* median and sigma (of the
    /// underlying normal). Heavy-tailed — matches web-search / AI-generation
    /// tool latencies in Table 1.
    LogNormal(LogNormal),
    /// Exponential with the given mean (Poisson inter-arrival times).
    Exp(f64),
}

#[derive(Debug, Clone, PartialEq)]
pub struct LogNormal {
    pub median: f64,
    pub sigma: f64,
}

impl Dist {
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match self {
            Dist::Constant(v) => *v,
            Dist::Uniform(lo, hi) => rng.range_f64(*lo, *hi),
            Dist::LogNormal(LogNormal { median, sigma }) => {
                (median.ln() + sigma * rng.normal()).exp()
            }
            Dist::Exp(mean) => -mean * (1.0 - rng.next_f64()).ln(),
        }
    }

    /// Expected value (used by forecasting defaults and tests).
    pub fn mean(&self) -> f64 {
        match self {
            Dist::Constant(v) => *v,
            Dist::Uniform(lo, hi) => 0.5 * (lo + hi),
            Dist::LogNormal(LogNormal { median, sigma }) => {
                median * (sigma * sigma / 2.0).exp()
            }
            Dist::Exp(mean) => *mean,
        }
    }
}

/// Poisson arrival process at `rate` events per second; yields successive
/// arrival timestamps in microseconds.
#[derive(Debug, Clone)]
pub struct Poisson {
    inter: Dist,
    next_us: f64,
}

impl Poisson {
    pub fn new(rate_per_s: f64) -> Self {
        assert!(rate_per_s > 0.0);
        Self {
            inter: Dist::Exp(1e6 / rate_per_s),
            next_us: 0.0,
        }
    }

    pub fn next_arrival_us(&mut self, rng: &mut Rng) -> u64 {
        self.next_us += self.inter.sample(rng);
        self.next_us as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let mut rng = Rng::new(1);
        let d = Dist::Constant(42.0);
        assert_eq!(d.sample(&mut rng), 42.0);
        assert_eq!(d.mean(), 42.0);
    }

    #[test]
    fn uniform_within_bounds_and_mean() {
        let mut rng = Rng::new(2);
        let d = Dist::Uniform(10.0, 20.0);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = d.sample(&mut rng);
            assert!((10.0..20.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 15.0).abs() < 0.1);
    }

    #[test]
    fn lognormal_median_close() {
        let mut rng = Rng::new(3);
        let d = Dist::LogNormal(LogNormal {
            median: 100.0,
            sigma: 0.5,
        });
        let mut xs: Vec<f64> = (0..10_001).map(|_| d.sample(&mut rng)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[5000];
        assert!((med / 100.0 - 1.0).abs() < 0.1, "median={med}");
    }

    #[test]
    fn exp_mean_close() {
        let mut rng = Rng::new(4);
        let d = Dist::Exp(50.0);
        let n = 50_000;
        let mean: f64 =
            (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64;
        assert!((mean - 50.0).abs() < 1.5, "mean={mean}");
    }

    #[test]
    fn poisson_rate_close() {
        let mut rng = Rng::new(5);
        let mut p = Poisson::new(2.0); // 2 arrivals/s
        let mut last = 0;
        let n = 10_000;
        for _ in 0..n {
            last = p.next_arrival_us(&mut rng);
        }
        let rate = n as f64 / (last as f64 / 1e6);
        assert!((rate - 2.0).abs() < 0.1, "rate={rate}");
    }
}
