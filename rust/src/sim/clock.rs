//! Simulation clock: monotonically advancing microsecond time.

/// Microseconds per second (all sim time is `u64` µs).
pub const MICROS_PER_SEC: u64 = 1_000_000;

/// A monotonically advancing simulation clock.
///
/// The engine owns the clock and advances it to the timestamp of each event
/// it dequeues; schedulers only ever read it. Attempting to move time
/// backwards panics — that is always an engine bug.
#[derive(Debug, Clone, Default)]
pub struct Clock {
    now_us: u64,
}

impl Clock {
    pub fn new() -> Self {
        Self { now_us: 0 }
    }

    /// Current simulation time in microseconds.
    #[inline]
    pub fn now_us(&self) -> u64 {
        self.now_us
    }

    /// Current simulation time in seconds (for reporting only).
    #[inline]
    pub fn now_s(&self) -> f64 {
        self.now_us as f64 / MICROS_PER_SEC as f64
    }

    /// Advance to an absolute timestamp. Panics on time travel.
    pub fn advance_to(&mut self, t_us: u64) {
        assert!(
            t_us >= self.now_us,
            "clock moved backwards: {} -> {}",
            self.now_us,
            t_us
        );
        self.now_us = t_us;
    }

    /// Advance by a relative duration. Panics on `u64` overflow — a
    /// wrapped clock would silently violate monotonicity, the same bug
    /// class [`Clock::advance_to`]'s time-travel guard catches.
    pub fn advance_by(&mut self, dt_us: u64) {
        self.now_us = self
            .now_us
            .checked_add(dt_us)
            .expect("clock overflow: advance_by past u64::MAX");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_monotonically() {
        let mut c = Clock::new();
        assert_eq!(c.now_us(), 0);
        c.advance_to(10);
        c.advance_by(5);
        assert_eq!(c.now_us(), 15);
        assert!((c.now_s() - 15e-6).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "clock moved backwards")]
    fn rejects_time_travel() {
        let mut c = Clock::new();
        c.advance_to(10);
        c.advance_to(9);
    }

    #[test]
    #[should_panic(expected = "clock overflow")]
    fn rejects_overflow_wrap() {
        let mut c = Clock::new();
        c.advance_to(u64::MAX - 1);
        c.advance_by(2);
    }
}
