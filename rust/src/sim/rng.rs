//! Seeded xorshift64* RNG — deterministic, dependency-free randomness.
//!
//! Every stochastic component (arrival process, tool latencies, corpus
//! synthesis, noise injection) takes an explicit `Rng` so experiments are
//! reproducible and sub-streams can be decorrelated by seed folding.

/// xorshift64* generator (Vigna 2016). Not cryptographic; plenty for
/// workload synthesis.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seed must be non-zero; 0 is mapped to a fixed constant.
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Derive an independent sub-stream (e.g. per request / per tool type).
    pub fn fold(&self, salt: u64) -> Rng {
        // SplitMix64 step over (state ^ salt) gives a well-mixed new seed.
        let mut z = self.state ^ salt.wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        Rng::new(z ^ (z >> 31))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi) — panics if lo >= hi.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range [{lo}, {hi})");
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Pick an index with the given (unnormalized, non-negative) weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "all weights zero");
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fold_decorrelates() {
        let base = Rng::new(42);
        let mut a = base.fold(1);
        let mut b = base.fold(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.range_u64(5, 10);
            assert!((5..10).contains(&x));
        }
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted_index(&[1.0, 0.0, 3.0])] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }
}
