//! Discrete-event simulation core.
//!
//! The paper's experiments run on A100/H20 GPUs; this environment has none,
//! so the evaluation substrate is a discrete-event simulator with faithful
//! block-level KV-cache accounting (DESIGN.md §3). Everything the schedulers
//! observe — time, transfer completions, tool completions, decode iteration
//! boundaries — flows through this module.
//!
//! Time is `u64` microseconds. All randomness is an explicitly seeded
//! xorshift generator so every experiment is reproducible bit-for-bit.

mod clock;
mod dist;
mod events;
mod rng;

pub use clock::{Clock, MICROS_PER_SEC};
pub use dist::{Dist, LogNormal, Poisson};
pub use events::{Event, EventKind, EventQueue};
pub use rng::Rng;
