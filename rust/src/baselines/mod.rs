//! Baseline serving policies reproduced for §7 (Table 2's comparison).
//!
//! The baselines share TokenCake's engine and block pools — only the
//! *policies* differ (see `config::Mode` for the capability matrix):
//!
//! * **vLLM** — FCFS continuous batching, paged blocks, recompute-on-evict.
//!   Entirely expressed by `Mode::Vllm` flags in `spatial::admit` and the
//!   engine's eviction path; no code here.
//! * **vLLM-Prefix** — + prefix-cache reuse (`Mode::VllmPrefix`).
//! * **Mooncake** — remote/CPU KV store with *reactive* offload: pressure-
//!   triggered, LRU victims, reactive upload on resumption
//!   ([`mooncake_reactive_phase`]).
//! * **Parrot** — agent-aware priority scheduling, compute-centric: no
//!   reservation, no offload, worst-case up-front allocation (its own
//!   engine predates paged growth) — see `spatial::admission_alloc_blocks`.
//! * **InferCept** — FC-triggered reactive swap without the cost model
//!   (gate shortcut in `temporal::gate`).

use crate::coordination::{PressureSnapshot, ReqState, RequestId, ServeState};
use crate::temporal::{issue_offload, try_immediate_upload};

/// Epoch-gated entry to the Mooncake reactive phase: skipped when no
/// temporal event landed, nothing is CPU-resident, and GPU usage sits
/// below the reactive threshold — exactly the ticks on which
/// [`mooncake_reactive_phase`] is a no-op. Returns whether it ran.
pub fn maybe_mooncake_phase(st: &mut ServeState, now_us: u64) -> bool {
    let due = st.epochs.temporal != st.planned.temporal
        || !st.offloaded_ids.is_empty()
        || st.gpu.usage() >= st.cfg.policy.reactive_usage_threshold;
    if !due {
        st.metrics.counters.planner_skips += 1;
        return false;
    }
    st.metrics.counters.planner_runs += 1;
    let snap = st.snapshot();
    mooncake_reactive_phase(st, &snap, now_us);
    st.planned.temporal = st.epochs.temporal;
    true
}

/// Mooncake-style reactive memory management (phase 3 replacement).
///
/// * Upload: retried every step for any CPU-resident cache whose tool has
///   returned (no prediction, no gradual reservation — the request simply
///   stalls until blocks appear).
/// * Offload: triggered only when GPU usage exceeds the reactive
///   threshold; victims are stalled requests in LRU order (oldest
///   `call_start` first), enough to bring usage back under the line.
pub fn mooncake_reactive_phase(
    st: &mut ServeState,
    snap: &PressureSnapshot,
    now_us: u64,
) {
    // ---- Reactive uploads (session resumption). ----
    // The offloaded index iterates in id order, so upload order is
    // deterministic without a per-step full-table scan + sort.
    let ready: Vec<RequestId> = st
        .offloaded_ids
        .iter()
        .copied()
        .filter(|rid| {
            let r = &st.reqs[rid];
            r.state == ReqState::Offloaded
                && r.fc.as_ref().map(|f| f.tool_done).unwrap_or(false)
        })
        .collect();
    for rid in ready {
        // May fail under pressure; retried next step.
        let _ = try_immediate_upload(st, rid, now_us);
    }

    // ---- Reactive offload under memory pressure. ----
    let threshold = st.cfg.policy.reactive_usage_threshold;
    if snap.usage < threshold {
        return;
    }
    let excess_blocks = ((snap.usage - threshold)
        * st.gpu.total() as f64)
        .ceil() as u32;

    // LRU victims: stalled the longest (walked off the stalled index,
    // O(stalled) instead of O(all requests)).
    let mut victims: Vec<(RequestId, u64, u32)> = st
        .stalled_ids
        .iter()
        .filter_map(|rid| {
            let r = &st.reqs[rid];
            if r.state != ReqState::Stalled {
                return None;
            }
            Some((
                r.id,
                r.fc.as_ref().map(|f| f.started_us).unwrap_or(0),
                r.blocks.len(),
            ))
        })
        .collect();
    victims.sort_by_key(|&(rid, started, _)| (started, rid));

    let mut freed = 0u32;
    for (rid, _, blocks) in victims {
        if freed >= excess_blocks {
            break;
        }
        if st.cpu.free_blocks() < blocks {
            break;
        }
        if issue_offload(st, rid, now_us) {
            freed += blocks;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Mode, ServeConfig};
    use crate::coordination::FcRt;
    use crate::graph::templates;
    use crate::kvcache::{AllocOutcome, Route};
    use crate::workload::SampledLengths;

    fn mooncake_state() -> ServeState {
        let mut cfg = ServeConfig::default();
        cfg.mode = Mode::Mooncake;
        let mut st = ServeState::new(cfg);
        let g = templates::code_writer();
        st.register_graph(&g);
        st
    }

    fn stall_request(st: &mut ServeState, started_us: u64, blocks: u32)
        -> RequestId {
        let scales = SampledLengths {
            prompt_scale: 1.0,
            gen_scale: 1.0,
        };
        let (app, _) = st.spawn_app(0, scales, 0);
        let rid = st.apps[&app].node_req[0].unwrap();
        st.waiting.retain(|&x| x != rid);
        let AllocOutcome::Granted { blocks, .. } =
            st.gpu.alloc(blocks, Route::Shared)
        else {
            panic!()
        };
        {
            let r = st.reqs.get_mut(&rid).unwrap();
            r.blocks = blocks;
            r.fc = Some(FcRt {
                name: "web_search".into(),
                started_us,
                predicted_end_us: started_us + 5_000_000,
                tool_done: false,
                finished_us: 0,
                result_tokens: 480,
                user_estimate_us: None,
            });
        }
        st.set_req_state(rid, ReqState::Stalled);
        rid
    }

    #[test]
    fn no_offload_below_threshold() {
        let mut st = mooncake_state();
        stall_request(&mut st, 0, 100);
        let snap = st.snapshot();
        mooncake_reactive_phase(&mut st, &snap, 1000);
        assert_eq!(st.metrics.offload_count, 0);
    }

    #[test]
    fn offloads_lru_victim_under_pressure() {
        let mut st = mooncake_state();
        let old = stall_request(&mut st, 0, 400);
        let new = stall_request(&mut st, 9_999, 400);
        // Fill to ~93%: excess over the 0.90 threshold is ~390 blocks,
        // covered by offloading the single oldest victim (400 blocks).
        let fill = (st.gpu.total() as f64 * 0.93) as u32 - 800;
        st.gpu.alloc(fill, Route::Shared);
        let snap = st.snapshot();
        mooncake_reactive_phase(&mut st, &snap, 10_000);
        assert!(st.metrics.offload_count >= 1);
        // The OLDER stall goes first (LRU).
        assert_eq!(st.reqs[&old].state, ReqState::PendingOffload);
        // The newer one only if needed — one victim covered the excess.
        assert_eq!(st.reqs[&new].state, ReqState::Stalled);
    }

    #[test]
    fn reactive_upload_on_tool_done() {
        let mut st = mooncake_state();
        let rid = stall_request(&mut st, 0, 50);
        // Manually park it on CPU with the tool finished.
        {
            let blocks = {
                let r = st.reqs.get_mut(&rid).unwrap();
                r.blocks.take()
            };
            st.gpu.free(blocks, 0, None);
            let cpu = st.cpu.alloc(50).unwrap();
            let r = st.reqs.get_mut(&rid).unwrap();
            r.cpu_blocks = cpu;
            r.fc.as_mut().unwrap().tool_done = true;
        }
        st.set_req_state(rid, ReqState::Offloaded);
        let snap = st.snapshot();
        mooncake_reactive_phase(&mut st, &snap, 1000);
        assert_eq!(st.reqs[&rid].state, ReqState::PendingUpload);
    }
}
