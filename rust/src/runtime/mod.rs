//! PJRT runtime: load the AOT artifacts produced by `python/compile/aot.py`
//! and execute them from Rust — no Python anywhere near the request path.
//!
//! Interchange is HLO **text** (xla_extension 0.5.1 rejects jax≥0.5's
//! 64-bit-id protos; the text parser reassigns ids). See
//! /opt/xla-example/README.md and DESIGN.md §7.

mod manifest;
mod tinyqwen;

pub use manifest::{Manifest, ParamEntry};
pub use tinyqwen::{DecodeOut, PrefillOut, TinyQwen};

use anyhow::Result;

/// Load an HLO-text artifact and compile it on a PJRT client.
pub fn compile_hlo_text(
    client: &xla::PjRtClient,
    path: &std::path::Path,
) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

/// Default artifacts directory: `$TOKENCAKE_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("TOKENCAKE_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
