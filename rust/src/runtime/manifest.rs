//! Parser for `artifacts/manifest.txt` — the contract between the Python
//! AOT path and the Rust loader (param ordering, shapes, offsets, model
//! hyperparameters).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// One weight tensor's location in `params.bin`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamEntry {
    pub name: String,
    pub dims: Vec<usize>,
    pub offset_bytes: u64,
}

impl ParamEntry {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }
}

/// Parsed manifest: model config + ordered parameter table + artifacts.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub config: HashMap<String, i64>,
    pub params: Vec<ParamEntry>,
    /// logical name → file name (e.g. "prefill" → "prefill_t128.hlo.txt").
    pub artifacts: HashMap<String, String>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut config = HashMap::new();
        let mut params = Vec::new();
        let mut artifacts = HashMap::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            match it.next() {
                Some("config") => {
                    for kv in it {
                        let (k, v) = kv
                            .split_once('=')
                            .with_context(|| format!("line {}: {kv}", i + 1))?;
                        config.insert(k.to_string(), v.parse::<i64>()?);
                    }
                }
                Some("param") => {
                    let name = it.next().context("param name")?.to_string();
                    let dims_s = it.next().context("param dims")?;
                    let dtype = it.next().context("param dtype")?;
                    if dtype != "f32" {
                        bail!("unsupported dtype {dtype}");
                    }
                    let offset_bytes =
                        it.next().context("param offset")?.parse()?;
                    let dims = dims_s
                        .split('x')
                        .map(|d| d.parse::<usize>())
                        .collect::<Result<Vec<_>, _>>()?;
                    params.push(ParamEntry {
                        name,
                        dims,
                        offset_bytes,
                    });
                }
                Some("artifact") => {
                    let name = it.next().context("artifact name")?;
                    let file = it.next().context("artifact file")?;
                    artifacts.insert(name.to_string(), file.to_string());
                }
                Some(other) => bail!("line {}: unknown entry {other}", i + 1),
                None => {}
            }
        }
        if params.is_empty() {
            bail!("manifest has no params");
        }
        Ok(Self {
            config,
            params,
            artifacts,
        })
    }

    pub fn cfg(&self, key: &str) -> Result<i64> {
        self.config
            .get(key)
            .copied()
            .with_context(|| format!("manifest missing config key {key}"))
    }

    /// Read all parameter tensors from `params.bin` as f32 vectors,
    /// verifying offsets and total size.
    pub fn read_params(&self, dir: &Path) -> Result<Vec<Vec<f32>>> {
        let bin = std::fs::read(dir.join("params.bin"))?;
        let mut out = Vec::with_capacity(self.params.len());
        for p in &self.params {
            let n = p.element_count();
            let start = p.offset_bytes as usize;
            let end = start + n * 4;
            if end > bin.len() {
                bail!(
                    "param {} [{start}, {end}) beyond params.bin ({})",
                    p.name,
                    bin.len()
                );
            }
            let mut v = Vec::with_capacity(n);
            for chunk in bin[start..end].chunks_exact(4) {
                v.push(f32::from_le_bytes(chunk.try_into().unwrap()));
            }
            out.push(v);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
config vocab=512 n_layers=2 decode_batch=8
param embed 512x128 f32 0
param layer0.wq 128x128 f32 262144
artifact prefill prefill_t128.hlo.txt
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.cfg("vocab").unwrap(), 512);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].dims, vec![512, 128]);
        assert_eq!(m.params[0].element_count(), 65536);
        assert_eq!(m.params[1].offset_bytes, 262144);
        assert_eq!(m.artifacts["prefill"], "prefill_t128.hlo.txt");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("bogus line").is_err());
        assert!(Manifest::parse("param x 2x2 f64 0").is_err());
        assert!(Manifest::parse("# only comments").is_err());
    }

    #[test]
    fn missing_key_errors() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.cfg("nope").is_err());
    }
}
