//! TinyQwen executor: the L2 model compiled to two PJRT executables
//! (prefill + batched decode step) plus the standalone paged-attention
//! kernel artifact.
//!
//! The KV cache crosses the PJRT boundary as literals each decode step in
//! the baseline path; `decode_buffers` keeps the cache device-resident
//! between steps (`execute_b`), which is the optimized hot path measured
//! in EXPERIMENTS.md §Perf.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::manifest::Manifest;

/// Prefill result: logits for the last valid prompt token + the prompt's
/// KV cache ([n_layers, prefill_len, n_heads, head_dim], row-major).
#[derive(Debug, Clone)]
pub struct PrefillOut {
    pub logits: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

/// Decode-step result: per-slot logits + the updated batched cache
/// ([n_layers, decode_batch, max_len, n_heads, head_dim]).
#[derive(Debug, Clone)]
pub struct DecodeOut {
    pub logits: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

/// The compiled TinyQwen model.
pub struct TinyQwen {
    client: xla::PjRtClient,
    prefill_exe: xla::PjRtLoadedExecutable,
    decode_exe: xla::PjRtLoadedExecutable,
    paged_exe: Option<xla::PjRtLoadedExecutable>,
    params: Vec<xla::Literal>,
    pub vocab: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub max_len: usize,
    pub prefill_len: usize,
    pub decode_batch: usize,
}

fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

impl TinyQwen {
    /// Load manifest + params + HLO artifacts and compile on the CPU PJRT
    /// client.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;

        let art = |name: &str| -> Result<std::path::PathBuf> {
            Ok(dir.join(manifest.artifacts.get(name).with_context(
                || format!("manifest missing artifact {name}"),
            )?))
        };
        let prefill_exe = super::compile_hlo_text(&client, &art("prefill")?)?;
        let decode_exe = super::compile_hlo_text(&client, &art("decode")?)?;
        let paged_exe = match manifest.artifacts.get("paged_attn") {
            Some(f) => {
                Some(super::compile_hlo_text(&client, &dir.join(f))?)
            }
            None => None,
        };

        let raw = manifest.read_params(dir)?;
        let params: Vec<xla::Literal> = manifest
            .params
            .iter()
            .zip(raw.iter())
            .map(|(p, data)| {
                let dims: Vec<i64> =
                    p.dims.iter().map(|&d| d as i64).collect();
                lit_f32(data, &dims)
            })
            .collect::<Result<_>>()?;

        Ok(Self {
            client,
            prefill_exe,
            decode_exe,
            paged_exe,
            params,
            vocab: manifest.cfg("vocab")? as usize,
            n_layers: manifest.cfg("n_layers")? as usize,
            n_heads: manifest.cfg("n_heads")? as usize,
            head_dim: manifest.cfg("head_dim")? as usize,
            max_len: manifest.cfg("max_len")? as usize,
            prefill_len: manifest.cfg("prefill_len")? as usize,
            decode_batch: manifest.cfg("decode_batch")? as usize,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Size of one slot's flattened per-layer cache row
    /// (max_len × n_heads × head_dim).
    pub fn slot_stride(&self) -> usize {
        self.max_len * self.n_heads * self.head_dim
    }

    /// Total length of a decode cache tensor.
    pub fn cache_len(&self) -> usize {
        self.n_layers * self.decode_batch * self.slot_stride()
    }

    /// Run prefill on a prompt (≤ prefill_len tokens; padded internally).
    pub fn prefill(&self, tokens: &[i32]) -> Result<PrefillOut> {
        if tokens.is_empty() || tokens.len() > self.prefill_len {
            bail!(
                "prompt length {} outside [1, {}]",
                tokens.len(),
                self.prefill_len
            );
        }
        let mut padded = vec![0i32; self.prefill_len];
        padded[..tokens.len()].copy_from_slice(tokens);
        let mut args: Vec<&xla::Literal> = self.params.iter().collect();
        let tok = lit_i32(&padded, &[1, self.prefill_len as i64])?;
        let tl = lit_i32(&[tokens.len() as i32], &[1])?;
        args.push(&tok);
        args.push(&tl);
        let out = self.prefill_exe.execute::<&xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let (logits, k, v) = out.to_tuple3()?;
        Ok(PrefillOut {
            logits: logits.to_vec::<f32>()?,
            k: k.to_vec::<f32>()?,
            v: v.to_vec::<f32>()?,
        })
    }

    /// One batched decode step over host-resident caches.
    ///
    /// `tokens`/`lens`: per-slot next token and current cache length;
    /// `k`/`v`: [n_layers, decode_batch, max_len, n_heads, head_dim].
    /// Slots with `lens[b] = 0` and token 0 are inactive (garbage logits).
    pub fn decode(
        &self,
        tokens: &[i32],
        k: &[f32],
        v: &[f32],
        lens: &[i32],
    ) -> Result<DecodeOut> {
        let b = self.decode_batch;
        if tokens.len() != b || lens.len() != b {
            bail!("decode expects exactly {b} slots");
        }
        if k.len() != self.cache_len() || v.len() != self.cache_len() {
            bail!("cache length mismatch");
        }
        let dims = [
            self.n_layers as i64,
            b as i64,
            self.max_len as i64,
            self.n_heads as i64,
            self.head_dim as i64,
        ];
        let mut args: Vec<&xla::Literal> = self.params.iter().collect();
        let tok = lit_i32(tokens, &[b as i64])?;
        let kl = lit_f32(k, &dims)?;
        let vl = lit_f32(v, &dims)?;
        let ll = lit_i32(lens, &[b as i64])?;
        args.push(&tok);
        args.push(&kl);
        args.push(&vl);
        args.push(&ll);
        let out = self.decode_exe.execute::<&xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let (logits, k2, v2) = out.to_tuple3()?;
        Ok(DecodeOut {
            logits: logits.to_vec::<f32>()?,
            k: k2.to_vec::<f32>()?,
            v: v2.to_vec::<f32>()?,
        })
    }

    /// Run the standalone paged-attention kernel artifact.
    ///
    /// Shapes fixed at AOT time: q [B,H,D], pages [P,page,H,D],
    /// table [B,PPS] i32, lens [B] i32 → out [B,H,D].
    #[allow(clippy::too_many_arguments)]
    pub fn paged_attn(
        &self,
        q: &[f32],
        k_pages: &[f32],
        v_pages: &[f32],
        table: &[i32],
        lens: &[i32],
        shape: (usize, usize, usize, usize, usize), // (B, P, page, H, D)
    ) -> Result<Vec<f32>> {
        let exe = self
            .paged_exe
            .as_ref()
            .context("paged_attn artifact not loaded")?;
        let (b, p, page, h, d) = shape;
        let pps = table.len() / b;
        let tl = lit_i32(table, &[b as i64, pps as i64])?;
        let ll = lit_i32(lens, &[b as i64])?;
        let ql = lit_f32(q, &[b as i64, h as i64, d as i64])?;
        let kd = [p as i64, page as i64, h as i64, d as i64];
        let kl = lit_f32(k_pages, &kd)?;
        let vl = lit_f32(v_pages, &kd)?;
        let out = exe
            .execute::<&xla::Literal>(&[&tl, &ll, &ql, &kl, &vl])?[0][0]
            .to_literal_sync()?;
        Ok(out.to_tuple1()?.to_vec::<f32>()?)
    }

    /// Greedy argmax over a logits row.
    pub fn argmax(&self, logits: &[f32]) -> i32 {
        let mut best = 0usize;
        for (i, &x) in logits.iter().enumerate() {
            if x > logits[best] {
                best = i;
            }
        }
        best as i32
    }
}
