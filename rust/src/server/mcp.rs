//! The unified MCPManager (§6.2): per-request function-call lifecycle
//! state behind the `call_start`/`call_finish` endpoints. State moves
//! through the paper's five stages: running → pending-offload → offloaded
//! → pending-upload → uploaded.

use std::collections::HashMap;
use std::time::Instant;

/// The five MCP lifecycle states plus the stalled entry state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum McpState {
    /// FC in flight, cache resident (pre-offload-decision).
    Stalled,
    PendingOffload,
    Offloaded,
    PendingUpload,
    Uploaded,
}

#[derive(Debug, Clone)]
struct Entry {
    func: String,
    state: McpState,
    started: Instant,
    predicted_us: u64,
}

/// Tracks every in-flight function call by request id.
pub struct McpManager {
    entries: HashMap<u64, Entry>,
    running: u64,
    completed: u64,
}

impl McpManager {
    pub fn new() -> Self {
        Self {
            entries: HashMap::new(),
            running: 0,
            completed: 0,
        }
    }

    /// A request announced a function call.
    pub fn call_start(
        &mut self,
        req: u64,
        func: &str,
        predicted_us: u64,
    ) -> Result<(), String> {
        if self.entries.contains_key(&req) {
            return Err(format!("request {req} already in a call"));
        }
        self.entries.insert(
            req,
            Entry {
                func: func.to_string(),
                state: McpState::Stalled,
                started: Instant::now(),
                predicted_us,
            },
        );
        Ok(())
    }

    /// The tool returned; yields (func name, measured elapsed µs).
    pub fn call_finish(&mut self, req: u64) -> Result<(String, u64), String> {
        let e = self
            .entries
            .remove(&req)
            .ok_or_else(|| format!("request {req} has no open call"))?;
        self.completed += 1;
        Ok((e.func, e.started.elapsed().as_micros() as u64))
    }

    /// Scheduler feedback: the cache's residency changed.
    pub fn set_state(&mut self, req: u64, state: McpState) -> Result<(), String> {
        let e = self
            .entries
            .get_mut(&req)
            .ok_or_else(|| format!("request {req} has no open call"))?;
        e.state = state;
        Ok(())
    }

    pub fn state_of(&self, req: u64) -> Option<McpState> {
        self.entries.get(&req).map(|e| e.state)
    }

    pub fn predicted_us(&self, req: u64) -> Option<u64> {
        self.entries.get(&req).map(|e| e.predicted_us)
    }

    pub fn note_running(&mut self, n: u64) {
        self.running = n;
    }

    /// Lifecycle counts for the /state endpoint.
    pub fn render_counts(&self) -> String {
        let count = |s: McpState| {
            self.entries.values().filter(|e| e.state == s).count()
        };
        format!(
            "running={}\nstalled={}\npending_offload={}\noffloaded={}\n\
             pending_upload={}\nuploaded={}\ncompleted_calls={}\n",
            self.running,
            count(McpState::Stalled),
            count(McpState::PendingOffload),
            count(McpState::Offloaded),
            count(McpState::PendingUpload),
            count(McpState::Uploaded),
            self.completed,
        )
    }
}

impl Default for McpManager {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_transitions() {
        let mut m = McpManager::new();
        m.call_start(1, "git", 500_000).unwrap();
        assert_eq!(m.state_of(1), Some(McpState::Stalled));
        assert!(m.call_start(1, "git", 1).is_err());
        m.set_state(1, McpState::PendingOffload).unwrap();
        m.set_state(1, McpState::Offloaded).unwrap();
        assert_eq!(m.state_of(1), Some(McpState::Offloaded));
        let (func, elapsed) = m.call_finish(1).unwrap();
        assert_eq!(func, "git");
        assert!(elapsed < 5_000_000);
        assert!(m.call_finish(1).is_err());
        assert!(m.set_state(1, McpState::Uploaded).is_err());
    }

    #[test]
    fn counts_render() {
        let mut m = McpManager::new();
        m.call_start(1, "a", 1).unwrap();
        m.call_start(2, "b", 1).unwrap();
        m.set_state(2, McpState::Offloaded).unwrap();
        let s = m.render_counts();
        assert!(s.contains("stalled=1"));
        assert!(s.contains("offloaded=1"));
    }
}
