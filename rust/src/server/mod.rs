//! The serving frontend (§6.1–§6.2): graph registration plus the
//! `call_start` / `call_finish` endpoints that drive the Temporal
//! Scheduler, served over a dependency-free HTTP/1.1 implementation
//! (tokio is not vendored offline; std::net + threads carry the same
//! architecture: a dedicated acceptor with per-connection workers).
//!
//! Endpoints (bodies are `key=value` lines, responses likewise):
//!
//! | Method/path        | Body                          | Effect |
//! |--------------------|-------------------------------|--------|
//! | `POST /graphs`     | graph DSL (see [`parse_graph_dsl`]) | register a DAG |
//! | `POST /apps`       | `graph=<id>`                  | instantiate an app |
//! | `POST /call_start` | `req=<id>` `estimate_us=<n>` `func=<name>` | request stalls on an FC |
//! | `POST /call_finish`| `req=<id>` `elapsed_us=<n>`   | tool returned |
//! | `GET  /state`      | —                             | MCP lifecycle counts |
//! | `GET  /healthz`    | —                             | liveness |

mod dsl;
mod http;
mod mcp;

pub use dsl::parse_graph_dsl;
pub use http::{Request, Response};
pub use mcp::{McpManager, McpState};

use std::collections::HashMap;
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::graph::AppGraph;
use crate::temporal::Forecaster;

/// Shared server state behind the endpoints.
pub struct ServerCore {
    pub graphs: Vec<AppGraph>,
    pub mcp: McpManager,
    pub forecaster: Forecaster,
    next_app: u64,
    pub apps: HashMap<u64, usize>,
}

impl ServerCore {
    pub fn new() -> Self {
        Self {
            graphs: Vec::new(),
            mcp: McpManager::new(),
            forecaster: Forecaster::new(0.4, 0.3, 2_000_000),
            next_app: 0,
            apps: HashMap::new(),
        }
    }

    /// Dispatch one parsed request (also used directly by tests).
    pub fn handle(&mut self, req: &Request) -> Response {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/healthz") => Response::ok("ok\n"),
            ("POST", "/graphs") => match parse_graph_dsl(&req.body) {
                Ok(g) => {
                    self.graphs.push(g);
                    Response::ok(&format!("graph={}\n", self.graphs.len() - 1))
                }
                Err(e) => Response::bad_request(&format!("error={e}\n")),
            },
            ("POST", "/apps") => {
                let kv = body_kv(&req.body);
                let Some(gid) = kv.get("graph").and_then(|v| v.parse().ok())
                else {
                    return Response::bad_request("error=missing graph\n");
                };
                if gid >= self.graphs.len() {
                    return Response::bad_request("error=unknown graph\n");
                }
                let id = self.next_app;
                self.next_app += 1;
                self.apps.insert(id, gid);
                Response::ok(&format!("app={id}\n"))
            }
            ("POST", "/call_start") => {
                let kv = body_kv(&req.body);
                let Some(rid) = kv.get("req").and_then(|v| v.parse().ok())
                else {
                    return Response::bad_request("error=missing req\n");
                };
                let func = kv
                    .get("func")
                    .cloned()
                    .unwrap_or_else(|| "unknown".to_string());
                let est = kv.get("estimate_us").and_then(|v| v.parse().ok());
                let predicted =
                    self.forecaster.predict_us(&func, est);
                match self.mcp.call_start(rid, &func, predicted) {
                    Ok(()) => {
                        Response::ok(&format!("predicted_us={predicted}\n"))
                    }
                    Err(e) => Response::bad_request(&format!("error={e}\n")),
                }
            }
            ("POST", "/call_finish") => {
                let kv = body_kv(&req.body);
                let Some(rid) = kv.get("req").and_then(|v| v.parse().ok())
                else {
                    return Response::bad_request("error=missing req\n");
                };
                match self.mcp.call_finish(rid) {
                    Ok((func, elapsed)) => {
                        // Feed the per-function-type forecasting model
                        // (Eq. 1) exactly as §6.2 describes.
                        let observed = kv
                            .get("elapsed_us")
                            .and_then(|v| v.parse().ok())
                            .unwrap_or(elapsed);
                        self.forecaster.observe_us(&func, observed);
                        Response::ok(&format!("observed_us={observed}\n"))
                    }
                    Err(e) => Response::bad_request(&format!("error={e}\n")),
                }
            }
            ("GET", "/state") => Response::ok(&self.mcp.render_counts()),
            _ => Response::not_found(),
        }
    }
}

impl Default for ServerCore {
    fn default() -> Self {
        Self::new()
    }
}

fn body_kv(body: &str) -> HashMap<String, String> {
    body.lines()
        .filter_map(|l| {
            let (k, v) = l.split_once('=')?;
            Some((k.trim().to_string(), v.trim().to_string()))
        })
        .collect()
}

/// A running HTTP server (thread-per-connection on std::net).
pub struct Server {
    pub addr: std::net::SocketAddr,
    core: Arc<Mutex<ServerCore>>,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving on `127.0.0.1:port` (0 = ephemeral).
    pub fn start(port: u16) -> std::io::Result<Self> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let core = Arc::new(Mutex::new(ServerCore::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let (core2, stop2) = (core.clone(), stop.clone());
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let core3 = core2.clone();
                        std::thread::spawn(move || {
                            let _ = serve_conn(stream, core3);
                        });
                    }
                    Err(ref e)
                        if e.kind() == std::io::ErrorKind::WouldBlock =>
                    {
                        std::thread::sleep(
                            std::time::Duration::from_millis(5),
                        );
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(Self {
            addr,
            core,
            stop,
            handle: Some(handle),
        })
    }

    pub fn core(&self) -> Arc<Mutex<ServerCore>> {
        self.core.clone()
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_conn(
    mut stream: TcpStream,
    core: Arc<Mutex<ServerCore>>,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_secs(5)))?;
    let req = http::read_request(&mut stream)?;
    let resp = handle_locked(&core, &req);
    stream.write_all(resp.to_bytes().as_slice())?;
    Ok(())
}

/// Dispatch under the core mutex. A connection thread that panicked
/// mid-`handle` poisons the lock; unwrapping here would then crash
/// *every* later connection's thread and silently drop their sockets.
/// The core carries no half-applied cross-field invariants worth that:
/// recover the guard and answer 500 so the client can retry, keeping
/// the process serving.
fn handle_locked(
    core: &Arc<Mutex<ServerCore>>,
    req: &Request,
) -> Response {
    match core.lock() {
        Ok(mut guard) => guard.handle(req),
        Err(poisoned) => {
            drop(poisoned.into_inner());
            Response::internal_error("error=server state poisoned\n")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn post(path: &str, body: &str) -> Request {
        Request {
            method: "POST".into(),
            path: path.into(),
            body: body.into(),
        }
    }

    #[test]
    fn register_graph_and_app() {
        let mut core = ServerCore::new();
        let dsl = "\
graph rag
agent retriever retriever 256 48,96 web_search 3000000
agent generator generator 192 384
edge retriever generator
";
        let r = core.handle(&post("/graphs", dsl));
        assert_eq!(r.status, 200, "{}", r.body);
        assert!(r.body.contains("graph=0"));
        let r = core.handle(&post("/apps", "graph=0"));
        assert!(r.body.contains("app=0"));
        let r = core.handle(&post("/apps", "graph=9"));
        assert_eq!(r.status, 400);
    }

    #[test]
    fn call_lifecycle_feeds_forecaster() {
        let mut core = ServerCore::new();
        let r = core.handle(&post(
            "/call_start",
            "req=7\nfunc=web_search\nestimate_us=1000000",
        ));
        assert_eq!(r.status, 200);
        assert!(r.body.contains("predicted_us=1000000"));
        let r = core.handle(&post(
            "/call_finish",
            "req=7\nelapsed_us=2000000",
        ));
        assert_eq!(r.status, 200);
        // Second call: EWMA history (2 s) now blends with the estimate:
        // 0.4·1 s + 0.6·2 s = 1.6 s.
        let r = core.handle(&post(
            "/call_start",
            "req=8\nfunc=web_search\nestimate_us=1000000",
        ));
        assert!(r.body.contains("predicted_us=1600000"), "{}", r.body);
    }

    #[test]
    fn state_reports_lifecycle_counts() {
        let mut core = ServerCore::new();
        core.handle(&post("/call_start", "req=1\nfunc=git"));
        let r = core.handle(&Request {
            method: "GET".into(),
            path: "/state".into(),
            body: String::new(),
        });
        assert!(r.body.contains("running=0"));
        assert!(r.body.contains("stalled=1"), "{}", r.body);
    }

    /// A handler thread that panics while holding the core poisons the
    /// mutex. Later connections must get a 500, not a thread crash
    /// that silently drops their socket.
    #[test]
    fn poisoned_core_answers_500_not_panic() {
        let core = Arc::new(Mutex::new(ServerCore::new()));
        let poisoner = core.clone();
        let _ = std::thread::spawn(move || {
            let _guard = poisoner.lock().unwrap();
            panic!("simulated handler panic");
        })
        .join();
        assert!(core.lock().is_err(), "mutex should be poisoned");
        let r = handle_locked(&core, &post("/apps", "graph=0"));
        assert_eq!(r.status, 500);
        assert!(r.body.contains("poisoned"), "{}", r.body);
    }

    #[test]
    fn unknown_route_404s() {
        let mut core = ServerCore::new();
        let r = core.handle(&Request {
            method: "GET".into(),
            path: "/nope".into(),
            body: String::new(),
        });
        assert_eq!(r.status, 404);
    }
}
