//! Minimal HTTP/1.1 request/response handling — just enough for the
//! frontend endpoints (no chunked encoding, no keep-alive).

use std::io::{BufRead, BufReader, Read};
use std::net::TcpStream;

/// A parsed request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: String,
}

/// A response under construction.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Response {
    pub status: u16,
    pub body: String,
}

impl Response {
    pub fn ok(body: &str) -> Self {
        Self {
            status: 200,
            body: body.to_string(),
        }
    }

    pub fn bad_request(body: &str) -> Self {
        Self {
            status: 400,
            body: body.to_string(),
        }
    }

    pub fn not_found() -> Self {
        Self {
            status: 404,
            body: "not found\n".to_string(),
        }
    }

    pub fn internal_error(body: &str) -> Self {
        Self {
            status: 500,
            body: body.to_string(),
        }
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            _ => "Internal Server Error",
        }
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        format!(
            "HTTP/1.1 {} {}\r\ncontent-type: text/plain\r\n\
             content-length: {}\r\nconnection: close\r\n\r\n{}",
            self.status,
            self.reason(),
            self.body.len(),
            self.body
        )
        .into_bytes()
    }
}

/// Parse one request from a stream.
pub fn read_request(stream: &mut TcpStream) -> std::io::Result<Request> {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();

    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_length.min(1 << 20)];
    if content_length > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(Request {
        method,
        path,
        body: String::from_utf8_lossy(&body).to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_bytes_wellformed() {
        let b = Response::ok("hello").to_bytes();
        let s = String::from_utf8(b).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("content-length: 5"));
        assert!(s.ends_with("hello"));
    }

    #[test]
    fn status_reasons() {
        assert_eq!(Response::not_found().status, 404);
        assert_eq!(Response::bad_request("x").status, 400);
    }
}
