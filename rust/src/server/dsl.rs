//! Line-oriented graph DSL for the `/graphs` endpoint — the wire form of
//! the §3.1 frontend API (Fig 5's Python builder maps 1:1 onto this).
//!
//! ```text
//! graph <name>
//! agent <node-name> <agent-type> <prompt_base> <gen1,gen2,...> [<func> [<predict_us> [<stages>]]]
//! func  <node-name> <func-kind> [<predict_us> [<stages>]]
//! edge  <from-name> <to-name>
//! prefix <node-name> <shared_prefix_tokens>
//! priority <node-name> <static_priority>
//! ```

use std::collections::HashMap;

use crate::graph::{AppGraph, CallSpec, FuncKind, GraphBuilder, NodeId};
use crate::sim::Dist;

fn func_kind(name: &str) -> FuncKind {
    match name {
        "file_read" => FuncKind::FileRead,
        "file_write" => FuncKind::FileWrite,
        "web_search" => FuncKind::WebSearch,
        "file_query" => FuncKind::FileQuery,
        "data_analysis" => FuncKind::DataAnalysis,
        "user_confirm" => FuncKind::UserConfirm,
        "external_test" => FuncKind::ExternalTest,
        "git" => FuncKind::Git,
        "database" => FuncKind::Database,
        "ai_generation" => FuncKind::AiGeneration,
        other => FuncKind::Custom {
            name: other.to_string(),
            latency_us: Dist::Constant(500_000.0),
        },
    }
}

fn parse_call(parts: &[&str]) -> Result<CallSpec, String> {
    let mut call = CallSpec::new(func_kind(parts[0]));
    if let Some(t) = parts.get(1) {
        call = call.with_predict_time_us(
            t.parse().map_err(|_| format!("bad predict_us {t}"))?,
        );
    }
    if let Some(s) = parts.get(2) {
        call = call.with_stages(
            s.parse().map_err(|_| format!("bad stages {s}"))?,
        );
    }
    Ok(call)
}

/// Parse the DSL into a validated [`AppGraph`].
pub fn parse_graph_dsl(text: &str) -> Result<AppGraph, String> {
    #[allow(unused_assignments)]
    let mut name = String::new();
    let mut gb: Option<GraphBuilder> = None;
    let mut ids: HashMap<String, NodeId> = HashMap::new();

    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = line.split_whitespace().collect();
        let err = |m: &str| format!("line {}: {m}", i + 1);
        match parts[0] {
            "graph" => {
                name = parts.get(1).ok_or(err("graph needs a name"))?
                    .to_string();
                gb = Some(GraphBuilder::new(&name));
            }
            "agent" => {
                let gb = gb.as_mut().ok_or(err("graph line must come first"))?;
                if parts.len() < 5 {
                    return Err(err("agent <name> <type> <prompt> <gens>"));
                }
                let prompt: u32 = parts[3]
                    .parse()
                    .map_err(|_| err("bad prompt tokens"))?;
                let gens: Vec<u32> = parts[4]
                    .split(',')
                    .map(|g| g.parse().map_err(|_| err("bad gen tokens")))
                    .collect::<Result<_, _>>()?;
                let id = if parts.len() > 5 {
                    if gens.len() < 2 {
                        return Err(err(
                            "agent with a call needs >= 2 gen phases",
                        ));
                    }
                    let call = parse_call(&parts[5..])?;
                    gb.agent_with_call(parts[1], parts[2], prompt, &gens,
                                       call)
                } else {
                    gb.agent(parts[1], parts[2], prompt, &gens)
                };
                ids.insert(parts[1].to_string(), id);
            }
            "func" => {
                let gb = gb.as_mut().ok_or(err("graph line must come first"))?;
                if parts.len() < 3 {
                    return Err(err("func <name> <kind>"));
                }
                let call = parse_call(&parts[2..])?;
                let id = gb.func(parts[1], call);
                ids.insert(parts[1].to_string(), id);
            }
            "edge" => {
                let gb = gb.as_mut().ok_or(err("graph line must come first"))?;
                let a = *ids
                    .get(parts.get(1).copied().unwrap_or(""))
                    .ok_or(err("unknown edge source"))?;
                let b = *ids
                    .get(parts.get(2).copied().unwrap_or(""))
                    .ok_or(err("unknown edge target"))?;
                gb.edge(a, b);
            }
            "prefix" | "priority" => {
                // Tuning lines apply to the named node; for simplicity the
                // builder only supports tuning the most recent agent, so
                // we accept and ignore mismatches explicitly.
                let gb = gb.as_mut().ok_or(err("graph line must come first"))?;
                let val: f64 = parts
                    .get(2)
                    .and_then(|v| v.parse().ok())
                    .ok_or(err("bad tuning value"))?;
                let is_prefix = parts[0] == "prefix";
                gb.tune_last(|s| {
                    if is_prefix {
                        s.shared_prefix = val as u32;
                    } else {
                        s.static_priority = val;
                    }
                });
            }
            other => return Err(err(&format!("unknown directive {other}"))),
        }
    }
    gb.ok_or_else(|| "empty graph description".to_string())?
        .build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::NodeKind;

    #[test]
    fn parses_fig5_rag() {
        let g = parse_graph_dsl(
            "graph rag\n\
             agent retriever retriever 256 48,96 web_search 3000000 2\n\
             agent generator generator 192 384\n\
             edge retriever generator\n",
        )
        .unwrap();
        assert_eq!(g.name, "rag");
        assert_eq!(g.len(), 2);
        let root = g.roots()[0];
        match &g.node(root).kind {
            NodeKind::Agent(a) => {
                let c = a.phases[0].call.as_ref().unwrap();
                assert_eq!(c.predict_time_us, Some(3_000_000));
                assert_eq!(c.stages, 2);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parses_func_nodes_and_tuning() {
        let g = parse_graph_dsl(
            "graph t\n\
             agent a t1 10 5\n\
             priority a 0.9\n\
             func search web_search 2000000\n\
             agent b t2 10 5\n\
             edge a search\nedge search b\n",
        )
        .unwrap();
        assert_eq!(g.len(), 3);
        assert_eq!(g.max_depth(), 2);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(parse_graph_dsl("").is_err());
        assert!(parse_graph_dsl("agent x t 1 1\n").is_err());
        assert!(parse_graph_dsl("graph g\nedge a b\n").is_err());
        assert!(parse_graph_dsl("graph g\nbogus\n").is_err());
        assert!(
            parse_graph_dsl("graph g\nagent a t 1 5 web_search\n").is_err(),
            "call with single phase must be rejected"
        );
    }
}
