//! Fluent builder for [`AppGraph`] — the Rust equivalent of the Python
//! frontend in Fig 5 of the paper.
//!
//! ```no_run
//! use tokencake::graph::{GraphBuilder, CallSpec, FuncKind};
//!
//! let mut gb = GraphBuilder::new("rag");
//! let retriever = gb.agent_with_call(
//!     "retriever", "retriever", 256, &[64, 128],
//!     CallSpec::new(FuncKind::WebSearch).with_predict_time_us(3_000_000),
//! );
//! let synthesizer = gb.agent("synthesizer", "synthesizer", 128, &[512]);
//! gb.edge(retriever, synthesizer);
//! let graph = gb.build().unwrap();
//! assert_eq!(graph.len(), 2);
//! ```

use super::{AgentSpec, AppGraph, CallSpec, Node, NodeId, NodeKind, Phase};

/// Incrementally assembles a validated [`AppGraph`].
pub struct GraphBuilder {
    name: String,
    nodes: Vec<Node>,
    edges: Vec<(NodeId, NodeId)>,
}

impl GraphBuilder {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            nodes: Vec::new(),
            edges: Vec::new(),
        }
    }

    fn push(&mut self, name: &str, kind: NodeKind) -> NodeId {
        let id = NodeId(self.nodes.len() as u32);
        self.nodes.push(Node {
            id,
            name: name.to_string(),
            kind,
        });
        id
    }

    /// Add an agent with one generation phase per entry of `gen_tokens`
    /// (no function calls between phases).
    pub fn agent(
        &mut self,
        name: &str,
        agent_type: &str,
        prompt_base: u32,
        gen_tokens: &[u32],
    ) -> NodeId {
        let phases = gen_tokens
            .iter()
            .map(|&g| Phase {
                gen_tokens: g,
                call: None,
            })
            .collect();
        self.push(
            name,
            NodeKind::Agent(AgentSpec {
                agent_type: agent_type.to_string(),
                prompt_base,
                shared_prefix: 0,
                inherit_frac: 0.5,
                phases,
                static_priority: 0.5,
            }),
        )
    }

    /// Add an agent whose phases are separated by one function call: the
    /// call fires after every phase except the last (the paper's
    /// `LLM1 → FC → LLM2` lifecycle when `gen_tokens.len() == 2`).
    pub fn agent_with_call(
        &mut self,
        name: &str,
        agent_type: &str,
        prompt_base: u32,
        gen_tokens: &[u32],
        call: CallSpec,
    ) -> NodeId {
        assert!(
            gen_tokens.len() >= 2,
            "agent_with_call needs >= 2 phases to embed a call"
        );
        let last = gen_tokens.len() - 1;
        let phases = gen_tokens
            .iter()
            .enumerate()
            .map(|(i, &g)| Phase {
                gen_tokens: g,
                call: if i < last { Some(call.clone()) } else { None },
            })
            .collect();
        self.push(
            name,
            NodeKind::Agent(AgentSpec {
                agent_type: agent_type.to_string(),
                prompt_base,
                shared_prefix: 0,
                inherit_frac: 0.5,
                phases,
                static_priority: 0.5,
            }),
        )
    }

    /// Add a fully specified agent.
    pub fn agent_spec(&mut self, name: &str, spec: AgentSpec) -> NodeId {
        self.push(name, NodeKind::Agent(spec))
    }

    /// Add a standalone (non-LLM) function node.
    pub fn func(&mut self, name: &str, call: CallSpec) -> NodeId {
        self.push(name, NodeKind::Func(call))
    }

    /// Declare a dependency `from → to`.
    pub fn edge(&mut self, from: NodeId, to: NodeId) {
        self.edges.push((from, to));
    }

    /// Chain a sequence of nodes with edges.
    pub fn chain(&mut self, nodes: &[NodeId]) {
        for w in nodes.windows(2) {
            self.edge(w[0], w[1]);
        }
    }

    /// Mutate the most recently added agent spec (set prefix, priority, …).
    pub fn tune_last(&mut self, f: impl FnOnce(&mut AgentSpec)) {
        if let Some(Node {
            kind: NodeKind::Agent(spec),
            ..
        }) = self.nodes.last_mut()
        {
            f(spec);
        } else {
            panic!("tune_last: last node is not an agent");
        }
    }

    /// Validate and build.
    pub fn build(self) -> Result<AppGraph, String> {
        if self.nodes.is_empty() {
            return Err("empty graph".to_string());
        }
        AppGraph::new(self.name, self.nodes, self.edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::FuncKind;

    #[test]
    fn chain_builds_linear_graph() {
        let mut gb = GraphBuilder::new("chain");
        let ids: Vec<NodeId> = (0..4)
            .map(|i| gb.agent(&format!("n{i}"), "t", 10, &[5]))
            .collect();
        gb.chain(&ids);
        let g = gb.build().unwrap();
        assert_eq!(g.max_depth(), 3);
        assert_eq!(g.roots(), vec![ids[0]]);
    }

    #[test]
    fn empty_rejected() {
        assert!(GraphBuilder::new("e").build().is_err());
    }

    #[test]
    fn tune_last_sets_prefix() {
        let mut gb = GraphBuilder::new("t");
        gb.agent("a", "t", 10, &[5]);
        gb.tune_last(|s| {
            s.shared_prefix = 123;
            s.static_priority = 0.9;
        });
        let g = gb.build().unwrap();
        match &g.node(NodeId(0)).kind {
            NodeKind::Agent(a) => {
                assert_eq!(a.shared_prefix, 123);
                assert_eq!(a.static_priority, 0.9);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn call_embeds_between_phases() {
        let mut gb = GraphBuilder::new("c");
        gb.agent_with_call("a", "t", 10, &[5, 7, 9],
                           CallSpec::new(FuncKind::Git));
        let g = gb.build().unwrap();
        match &g.node(NodeId(0)).kind {
            NodeKind::Agent(a) => {
                assert_eq!(a.phases.len(), 3);
                assert!(a.phases[0].call.is_some());
                assert!(a.phases[1].call.is_some());
                assert!(a.phases[2].call.is_none());
                assert_eq!(a.call_count(), 2);
                assert_eq!(a.total_gen_tokens(), 21);
            }
            _ => panic!(),
        }
    }
}
