//! The paper's two benchmark applications (Fig 1, §7.1) as graph templates.
//!
//! * [`code_writer`] — 11 agent types orchestrating plan → implement →
//!   review → test → debug → document → release, with frequent function
//!   calls to file I/O, git, search and external test tools. High memory
//!   pressure from many concurrent KV states.
//! * [`deep_research`] — fewer agents but deeper dependency chains
//!   (plan → search → summarize → verify → synthesize → edit), stressing
//!   critical-path optimization.

use super::{CallSpec, FuncKind, GraphBuilder, AppGraph};

/// Code-Writer: 11 agent types, call-heavy, wide then joining (Fig 1a).
pub fn code_writer() -> AppGraph {
    let mut gb = GraphBuilder::new("code-writer");

    let planner = gb.agent("planner", "planner", 420, &[180]);
    gb.tune_last(|s| {
        s.shared_prefix = 256;
        s.static_priority = 0.9;
    });

    let architect = gb.agent_with_call(
        "architect",
        "architect",
        380,
        &[160, 120],
        CallSpec::new(FuncKind::FileQuery).with_predict_time_us(100_000),
    );
    gb.tune_last(|s| {
        s.shared_prefix = 256;
        s.static_priority = 0.85;
    });

    // Two parallel programmers — the critical implementation work.
    let prog_core = gb.agent_with_call(
        "programmer-core",
        "programmer",
        520,
        &[420, 260],
        CallSpec::new(FuncKind::FileWrite).with_predict_time_us(120_000),
    );
    gb.tune_last(|s| {
        s.shared_prefix = 384;
        s.static_priority = 0.95;
    });
    // Copilot-style codegen subcall: Table 1's heaviest tool class.
    let prog_aux = gb.agent_with_call(
        "programmer-aux",
        "programmer-aux",
        480,
        &[360, 200],
        CallSpec::new(FuncKind::AiGeneration)
            .with_predict_time_us(12_000_000)
            .with_stages(3),
    );
    gb.tune_last(|s| s.shared_prefix = 384);

    let searcher = gb.agent_with_call(
        "api-searcher",
        "searcher",
        300,
        &[90, 140],
        CallSpec::new(FuncKind::WebSearch)
            .with_predict_time_us(2_500_000)
            .with_stages(2),
    );

    // Review sign-off waits on a human (UserConfirm, Table 3).
    let reviewer = gb.agent_with_call(
        "code-reviewer",
        "reviewer",
        440,
        &[150, 180],
        CallSpec::new(FuncKind::UserConfirm).with_predict_time_us(5_000_000),
    );
    gb.tune_last(|s| {
        s.shared_prefix = 256;
        s.static_priority = 0.8;
    });

    let test_writer = gb.agent_with_call(
        "test-writer",
        "test-writer",
        400,
        &[240, 120],
        CallSpec::new(FuncKind::FileWrite).with_predict_time_us(120_000),
    );

    let test_runner = gb.agent_with_call(
        "test-runner",
        "test-runner",
        260,
        &[60, 150],
        CallSpec::new(FuncKind::ExternalTest)
            .with_predict_time_us(3_500_000)
            .with_stages(2),
    );
    gb.tune_last(|s| s.static_priority = 0.85);

    let debugger = gb.agent_with_call(
        "debugger",
        "debugger",
        460,
        &[200, 220],
        CallSpec::new(FuncKind::ExternalTest).with_predict_time_us(3_500_000),
    );
    gb.tune_last(|s| s.static_priority = 0.9);

    let doc_writer = gb.agent_with_call(
        "doc-writer",
        "doc-writer",
        340,
        &[280, 80],
        CallSpec::new(FuncKind::FileWrite).with_predict_time_us(120_000),
    );
    gb.tune_last(|s| s.static_priority = 0.3);

    let release = gb.agent_with_call(
        "release-manager",
        "release-manager",
        300,
        &[120, 100],
        CallSpec::new(FuncKind::Git).with_predict_time_us(400_000),
    );
    gb.tune_last(|s| s.static_priority = 0.8);

    gb.edge(planner, architect);
    gb.edge(architect, prog_core);
    gb.edge(architect, prog_aux);
    gb.edge(architect, searcher);
    gb.edge(searcher, prog_core);
    gb.edge(prog_core, reviewer);
    gb.edge(prog_aux, reviewer);
    gb.edge(architect, test_writer);
    gb.edge(reviewer, test_runner);
    gb.edge(test_writer, test_runner);
    gb.edge(test_runner, debugger);
    gb.edge(prog_core, doc_writer);
    gb.edge(debugger, release);
    gb.edge(doc_writer, release);

    gb.build().expect("code_writer template is valid")
}

/// Deep-Research: a deep chain with a parallel search fan (Fig 1b).
pub fn deep_research() -> AppGraph {
    let mut gb = GraphBuilder::new("deep-research");

    let planner = gb.agent("query-planner", "planner", 380, &[160]);
    gb.tune_last(|s| {
        s.shared_prefix = 256;
        s.static_priority = 0.9;
    });

    // Parallel searchers hitting the web-search tool (long, variable).
    let search_a = gb.agent_with_call(
        "searcher-a",
        "searcher",
        320,
        &[80, 200],
        CallSpec::new(FuncKind::WebSearch)
            .with_predict_time_us(2_500_000)
            .with_stages(2),
    );
    let search_b = gb.agent_with_call(
        "searcher-b",
        "searcher",
        320,
        &[80, 200],
        CallSpec::new(FuncKind::WebSearch)
            .with_predict_time_us(2_500_000)
            .with_stages(2),
    );

    let summarizer = gb.agent("summarizer", "summarizer", 520, &[420]);
    gb.tune_last(|s| s.static_priority = 0.75);

    let fact_checker = gb.agent_with_call(
        "fact-checker",
        "fact-checker",
        420,
        &[120, 180],
        CallSpec::new(FuncKind::Database).with_predict_time_us(600_000),
    );
    gb.tune_last(|s| s.static_priority = 0.8);

    let analyst = gb.agent_with_call(
        "analyst",
        "analyst",
        460,
        &[180, 260],
        CallSpec::new(FuncKind::DataAnalysis)
            .with_predict_time_us(5_000_000)
            .with_stages(4),
    );
    gb.tune_last(|s| s.static_priority = 0.85);

    let synthesizer = gb.agent("synthesizer", "synthesizer", 620, &[560]);
    gb.tune_last(|s| s.static_priority = 0.95);

    let editor = gb.agent("editor", "editor", 380, &[260]);
    gb.tune_last(|s| s.static_priority = 0.7);

    gb.edge(planner, search_a);
    gb.edge(planner, search_b);
    gb.edge(search_a, summarizer);
    gb.edge(search_b, summarizer);
    gb.edge(summarizer, fact_checker);
    gb.edge(fact_checker, analyst);
    gb.edge(analyst, synthesizer);
    gb.edge(synthesizer, editor);

    gb.build().expect("deep_research template is valid")
}

/// A minimal RAG app — the Fig 5 example, used by quickstart/docs.
pub fn rag() -> AppGraph {
    let mut gb = GraphBuilder::new("rag");
    let retriever = gb.agent_with_call(
        "retriever",
        "retriever",
        256,
        &[48, 96],
        CallSpec::new(FuncKind::WebSearch)
            .with_predict_time_us(3_000_000)
            .with_stages(2),
    );
    let generator = gb.agent("generator", "generator", 192, &[384]);
    gb.tune_last(|s| s.static_priority = 0.9);
    gb.edge(retriever, generator);
    gb.build().expect("rag template is valid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::NodeKind;

    #[test]
    fn templates_are_acyclic_and_connected() {
        for g in [code_writer(), deep_research(), rag()] {
            assert!(!g.is_empty());
            assert_eq!(g.topo_order().len(), g.len());
            // Single root component: every non-root node reachable.
            let roots = g.roots();
            assert_eq!(roots.len(), 1, "{} roots", g.name);
            assert_eq!(
                g.downstream_count(roots[0]),
                g.len() - 1,
                "{} disconnected",
                g.name
            );
        }
    }

    #[test]
    fn deep_research_deeper_than_wide() {
        // §7.1: Deep-Research has fewer agents but *relatively* deeper
        // chains — nearly every node sits on one long dependency path.
        let dr = deep_research();
        let cw = code_writer();
        assert!(dr.len() < cw.len());
        let dr_ratio = dr.max_depth() as f64 / dr.len() as f64;
        let cw_ratio = cw.max_depth() as f64 / cw.len() as f64;
        assert!(dr_ratio > cw_ratio, "{dr_ratio} vs {cw_ratio}");
    }

    #[test]
    fn code_writer_has_parallel_programmers() {
        let g = code_writer();
        // The architect fans out to >= 3 children.
        let architect = g
            .nodes()
            .find(|n| n.name == "architect")
            .unwrap()
            .id;
        assert!(g.out_degree(architect) >= 3);
    }

    #[test]
    fn rag_matches_fig5() {
        let g = rag();
        assert_eq!(g.len(), 2);
        match &g.node(g.roots()[0]).kind {
            NodeKind::Agent(a) => {
                assert_eq!(a.call_count(), 1);
                let call = a.phases[0].call.as_ref().unwrap();
                assert_eq!(call.predict_time_us, Some(3_000_000));
            }
            _ => panic!(),
        }
    }
}
