//! Frontend API (§3.1): multi-agent applications as annotated DAGs.
//!
//! Users describe an application as a graph whose nodes are **agents**
//! (LLM inference with optional embedded function calls — the
//! `LLM1 → FC → LLM2` lifecycle of Fig 2b) or standalone **function nodes**
//! (non-LLM stages between agents). Edges are data dependencies. The graph
//! carries the three kinds of information the paper says serving systems
//! lack: structure, fine-grained function-call stages, and performance
//! metadata (`predict_time`).
//!
//! [`templates`] builds the two benchmark applications: Code-Writer
//! (11 agent types, §7.1) and Deep-Research.

mod builder;
mod func;
pub mod templates;

pub use builder::GraphBuilder;
pub use func::{FuncKind, ToolLatency};

use crate::sim::Dist;

/// Node identifier within one [`AppGraph`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

/// A function call embedded in an agent's generation (the `FuncNode`
/// decomposition of §3.1: `stages` gives the Temporal Scheduler a
/// progress view; `predict_time_us` is the user's estimate for Eq. 1).
#[derive(Debug, Clone, PartialEq)]
pub struct CallSpec {
    pub kind: FuncKind,
    /// User-supplied execution-time estimate (t_user in Eq. 1), if any.
    pub predict_time_us: Option<u64>,
    /// Sequential stage count (≥1). More stages → finer progress signal.
    pub stages: u32,
}

impl CallSpec {
    pub fn new(kind: FuncKind) -> Self {
        Self {
            kind,
            predict_time_us: None,
            stages: 1,
        }
    }

    pub fn with_predict_time_us(mut self, us: u64) -> Self {
        self.predict_time_us = Some(us);
        self
    }

    pub fn with_stages(mut self, stages: u32) -> Self {
        assert!(stages >= 1);
        self.stages = stages;
        self
    }
}

/// One generation phase of an agent: decode `gen_tokens` tokens, then
/// (optionally) issue a function call before the next phase.
#[derive(Debug, Clone, PartialEq)]
pub struct Phase {
    pub gen_tokens: u32,
    pub call: Option<CallSpec>,
}

/// An LLM agent node.
#[derive(Debug, Clone, PartialEq)]
pub struct AgentSpec {
    /// Agent type name ("programmer", "reviewer", …). Reservation (Eq. 6)
    /// operates per type.
    pub agent_type: String,
    /// Prompt tokens owned by this agent (instructions etc.).
    pub prompt_base: u32,
    /// Shared system-prefix tokens (prefix-cache reusable across instances
    /// of the same type).
    pub shared_prefix: u32,
    /// Fraction of each parent's produced tokens appended to the prompt.
    pub inherit_frac: f64,
    /// Generation phases, separated by function calls.
    pub phases: Vec<Phase>,
    /// Static priority hint (P_a's structural component).
    pub static_priority: f64,
}

impl AgentSpec {
    pub fn total_gen_tokens(&self) -> u32 {
        self.phases.iter().map(|p| p.gen_tokens).sum()
    }

    pub fn call_count(&self) -> usize {
        self.phases.iter().filter(|p| p.call.is_some()).count()
    }
}

/// Node payload.
#[derive(Debug, Clone, PartialEq)]
pub enum NodeKind {
    Agent(AgentSpec),
    /// A standalone non-LLM stage between agents (no KV cache).
    Func(CallSpec),
}

/// One node of an application DAG.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub kind: NodeKind,
}

/// A validated multi-agent application DAG.
#[derive(Debug, Clone)]
pub struct AppGraph {
    pub name: String,
    nodes: Vec<Node>,
    /// Adjacency: children[i] = nodes that depend on node i.
    children: Vec<Vec<NodeId>>,
    parents: Vec<Vec<NodeId>>,
    topo: Vec<NodeId>,
    depth: Vec<u32>,
    /// Longest-expected-time path membership (critical path).
    on_critical_path: Vec<bool>,
    max_depth: u32,
}

impl AppGraph {
    /// Construct and validate; panics on cycles (builder returns Result).
    pub(crate) fn new(
        name: String,
        nodes: Vec<Node>,
        edges: Vec<(NodeId, NodeId)>,
    ) -> Result<Self, String> {
        let n = nodes.len();
        let mut children = vec![Vec::new(); n];
        let mut parents = vec![Vec::new(); n];
        for &(a, b) in &edges {
            if a.0 as usize >= n || b.0 as usize >= n {
                return Err(format!("edge ({},{}) out of range", a.0, b.0));
            }
            if a == b {
                return Err(format!("self-loop at node {}", a.0));
            }
            children[a.0 as usize].push(b);
            parents[b.0 as usize].push(a);
        }

        // Kahn's algorithm: topo order + cycle detection.
        let mut indeg: Vec<usize> =
            parents.iter().map(|p| p.len()).collect();
        let mut queue: Vec<NodeId> = (0..n as u32)
            .filter(|&i| indeg[i as usize] == 0)
            .map(NodeId)
            .collect();
        let mut topo = Vec::with_capacity(n);
        let mut head = 0;
        while head < queue.len() {
            let u = queue[head];
            head += 1;
            topo.push(u);
            for &v in &children[u.0 as usize] {
                indeg[v.0 as usize] -= 1;
                if indeg[v.0 as usize] == 0 {
                    queue.push(v);
                }
            }
        }
        if topo.len() != n {
            return Err("graph has a cycle".to_string());
        }

        // Depth = longest edge-count path from any root.
        let mut depth = vec![0u32; n];
        for &u in &topo {
            for &v in &children[u.0 as usize] {
                depth[v.0 as usize] =
                    depth[v.0 as usize].max(depth[u.0 as usize] + 1);
            }
        }
        let max_depth = depth.iter().copied().max().unwrap_or(0);

        let mut g = Self {
            name,
            nodes,
            children,
            parents,
            topo,
            depth,
            on_critical_path: vec![false; n],
            max_depth,
        };
        g.compute_critical_path();
        Ok(g)
    }

    /// Expected wall time of a node (for critical-path analysis): LLM work
    /// approximated by token counts, calls by their latency means.
    fn expected_node_time(&self, id: NodeId) -> f64 {
        match &self.nodes[id.0 as usize].kind {
            NodeKind::Agent(a) => {
                let gen = a.total_gen_tokens() as f64 * 50_000.0; // ~50ms/tok
                let prompt = (a.prompt_base + a.shared_prefix) as f64 * 500.0;
                let calls: f64 = a
                    .phases
                    .iter()
                    .filter_map(|p| p.call.as_ref())
                    .map(|c| {
                        c.predict_time_us
                            .map(|t| t as f64)
                            .unwrap_or_else(|| c.kind.latency().mean_us())
                    })
                    .sum();
                gen + prompt + calls
            }
            NodeKind::Func(c) => c
                .predict_time_us
                .map(|t| t as f64)
                .unwrap_or_else(|| c.kind.latency().mean_us()),
        }
    }

    /// Mark nodes on the longest expected-time root→leaf path.
    fn compute_critical_path(&mut self) {
        let n = self.nodes.len();
        if n == 0 {
            return;
        }
        // dist[i] = longest expected time of a path ending at i (inclusive).
        let mut dist = vec![0f64; n];
        let mut pred: Vec<Option<NodeId>> = vec![None; n];
        for &u in &self.topo {
            let ui = u.0 as usize;
            dist[ui] += self.expected_node_time(u);
            for &v in &self.children[ui] {
                let vi = v.0 as usize;
                if dist[ui] > dist[vi] {
                    dist[vi] = dist[ui];
                    pred[vi] = Some(u);
                }
            }
        }
        let mut cur = NodeId(
            (0..n).max_by(|&a, &b| dist[a].total_cmp(&dist[b])).unwrap()
                as u32,
        );
        loop {
            self.on_critical_path[cur.0 as usize] = true;
            match pred[cur.0 as usize] {
                Some(p) => cur = p,
                None => break,
            }
        }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0 as usize]
    }

    pub fn nodes(&self) -> impl Iterator<Item = &Node> {
        self.nodes.iter()
    }

    pub fn children(&self, id: NodeId) -> &[NodeId] {
        &self.children[id.0 as usize]
    }

    pub fn parents(&self, id: NodeId) -> &[NodeId] {
        &self.parents[id.0 as usize]
    }

    /// Topological order (roots first).
    pub fn topo_order(&self) -> &[NodeId] {
        &self.topo
    }

    pub fn depth(&self, id: NodeId) -> u32 {
        self.depth[id.0 as usize]
    }

    pub fn max_depth(&self) -> u32 {
        self.max_depth
    }

    pub fn in_degree(&self, id: NodeId) -> usize {
        self.parents[id.0 as usize].len()
    }

    pub fn out_degree(&self, id: NodeId) -> usize {
        self.children[id.0 as usize].len()
    }

    /// Is this node on the longest-expected-time (critical) path?
    pub fn is_critical(&self, id: NodeId) -> bool {
        self.on_critical_path[id.0 as usize]
    }

    /// Roots (no parents).
    pub fn roots(&self) -> Vec<NodeId> {
        (0..self.nodes.len() as u32)
            .map(NodeId)
            .filter(|&i| self.parents[i.0 as usize].is_empty())
            .collect()
    }

    /// Structural importance f_struct (Eq. 5): how much downstream work a
    /// node unlocks, from depth-remaining and fan-out, normalized to [0,1].
    pub fn f_struct(&self, id: NodeId) -> f64 {
        let d = self.depth(id) as f64;
        let maxd = self.max_depth.max(1) as f64;
        let depth_remaining = (maxd - d) / maxd;
        let fan = self.out_degree(id) as f64;
        let max_fan = (0..self.nodes.len() as u32)
            .map(|i| self.children[i as usize].len())
            .max()
            .unwrap_or(1)
            .max(1) as f64;
        0.6 * depth_remaining + 0.4 * (fan / max_fan)
    }

    /// Number of downstream (transitively reachable) nodes.
    pub fn downstream_count(&self, id: NodeId) -> usize {
        let mut seen = vec![false; self.nodes.len()];
        let mut stack = vec![id];
        let mut count = 0;
        while let Some(u) = stack.pop() {
            for &v in &self.children[u.0 as usize] {
                if !seen[v.0 as usize] {
                    seen[v.0 as usize] = true;
                    count += 1;
                    stack.push(v);
                }
            }
        }
        count
    }

    /// Distinct agent type names in the graph.
    pub fn agent_types(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self
            .nodes
            .iter()
            .filter_map(|n| match &n.kind {
                NodeKind::Agent(a) => Some(a.agent_type.as_str()),
                NodeKind::Func(_) => None,
            })
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Mean expected tool latency annotation (used as default Dist).
    pub fn expected_latency(&self) -> Dist {
        Dist::Constant(
            self.topo
                .iter()
                .map(|&u| self.expected_node_time(u))
                .sum::<f64>(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::templates;
    use super::*;

    fn diamond() -> AppGraph {
        // a -> b, a -> c, b -> d, c -> d ; b is heavier than c.
        let mut gb = GraphBuilder::new("diamond");
        let a = gb.agent("a", "root", 100, &[50]);
        let b = gb.agent_with_call(
            "b",
            "heavy",
            100,
            &[200, 100],
            CallSpec::new(FuncKind::WebSearch),
        );
        let c = gb.agent("c", "light", 50, &[20]);
        let d = gb.agent("d", "join", 100, &[50]);
        gb.edge(a, b);
        gb.edge(a, c);
        gb.edge(b, d);
        gb.edge(c, d);
        gb.build().unwrap()
    }

    #[test]
    fn topo_respects_edges() {
        let g = diamond();
        let pos: Vec<usize> = (0..4)
            .map(|i| {
                g.topo_order()
                    .iter()
                    .position(|&n| n == NodeId(i))
                    .unwrap()
            })
            .collect();
        assert!(pos[0] < pos[1] && pos[0] < pos[2]);
        assert!(pos[1] < pos[3] && pos[2] < pos[3]);
    }

    #[test]
    fn cycle_rejected() {
        let mut gb = GraphBuilder::new("cyc");
        let a = gb.agent("a", "t", 10, &[5]);
        let b = gb.agent("b", "t", 10, &[5]);
        gb.edge(a, b);
        gb.edge(b, a);
        assert!(gb.build().is_err());
    }

    #[test]
    fn self_loop_rejected() {
        let mut gb = GraphBuilder::new("loop");
        let a = gb.agent("a", "t", 10, &[5]);
        gb.edge(a, a);
        assert!(gb.build().is_err());
    }

    #[test]
    fn depth_and_degree() {
        let g = diamond();
        assert_eq!(g.depth(NodeId(0)), 0);
        assert_eq!(g.depth(NodeId(1)), 1);
        assert_eq!(g.depth(NodeId(3)), 2);
        assert_eq!(g.out_degree(NodeId(0)), 2);
        assert_eq!(g.in_degree(NodeId(3)), 2);
        assert_eq!(g.max_depth(), 2);
    }

    #[test]
    fn critical_path_takes_heavy_branch() {
        let g = diamond();
        assert!(g.is_critical(NodeId(0)));
        assert!(g.is_critical(NodeId(1)), "heavy branch b must be critical");
        assert!(!g.is_critical(NodeId(2)), "light branch c must not be");
        assert!(g.is_critical(NodeId(3)));
    }

    #[test]
    fn f_struct_root_exceeds_leaf() {
        let g = diamond();
        assert!(g.f_struct(NodeId(0)) > g.f_struct(NodeId(3)));
    }

    #[test]
    fn downstream_count() {
        let g = diamond();
        assert_eq!(g.downstream_count(NodeId(0)), 3);
        assert_eq!(g.downstream_count(NodeId(3)), 0);
    }

    #[test]
    fn code_writer_template_shape() {
        let g = templates::code_writer();
        // §7.1: 11 agent types with frequent function calls.
        assert_eq!(g.agent_types().len(), 11);
        let call_count: usize = g
            .nodes()
            .filter_map(|n| match &n.kind {
                NodeKind::Agent(a) => Some(a.call_count()),
                _ => None,
            })
            .sum();
        assert!(call_count >= 8, "Code-Writer must be call-heavy");
        assert!(g.max_depth() >= 4);
    }

    #[test]
    fn deep_research_template_shape() {
        let g = templates::deep_research();
        // Fewer agents, deeper chains (§7.1).
        assert!(g.agent_types().len() < 11);
        assert!(g.max_depth() >= 5, "depth={}", g.max_depth());
    }

    #[test]
    fn roots_found() {
        let g = diamond();
        assert_eq!(g.roots(), vec![NodeId(0)]);
    }
}
