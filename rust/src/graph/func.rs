//! Pre-built function-call node types (Table 3) with the MCP tool latency
//! characteristics of Table 1.

use crate::sim::{Dist, LogNormal};

/// Table 3's pre-built `FuncNode` types plus a custom escape hatch.
#[derive(Debug, Clone, PartialEq)]
pub enum FuncKind {
    /// Read the contents of a specified file.
    FileRead,
    /// Write content to a specified file.
    FileWrite,
    /// Perform a web search query.
    WebSearch,
    /// Query files under a specified path.
    FileQuery,
    /// Multi-stage analysis of large datasets.
    DataAnalysis,
    /// Request user confirmation.
    UserConfirm,
    /// Use external test tools.
    ExternalTest,
    /// Git operations (Table 1).
    Git,
    /// SQLite-style database query (Table 1).
    Database,
    /// GPU-side AI generation (Table 1's heaviest tool).
    AiGeneration,
    /// User-defined tool with an explicit latency distribution.
    Custom { name: String, latency_us: Dist },
}

/// Latency model of a tool: a distribution in microseconds (Table 1) and a
/// default user estimate used when the graph supplies none.
#[derive(Debug, Clone, PartialEq)]
pub struct ToolLatency {
    pub dist: Dist,
}

impl ToolLatency {
    pub fn mean_us(&self) -> f64 {
        self.dist.mean()
    }
}

impl FuncKind {
    /// Stable name (keys the per-function-type forecasting model, §4.1).
    pub fn name(&self) -> &str {
        match self {
            FuncKind::FileRead => "file_read",
            FuncKind::FileWrite => "file_write",
            FuncKind::WebSearch => "web_search",
            FuncKind::FileQuery => "file_query",
            FuncKind::DataAnalysis => "data_analysis",
            FuncKind::UserConfirm => "user_confirm",
            FuncKind::ExternalTest => "external_test",
            FuncKind::Git => "git",
            FuncKind::Database => "database",
            FuncKind::AiGeneration => "ai_generation",
            FuncKind::Custom { name, .. } => name,
        }
    }

    /// Table 1 latency models (µs). "Latency" column is the center,
    /// "Variability" column sets the spread.
    pub fn latency(&self) -> ToolLatency {
        let dist = match self {
            // File System: 100 ms ± 50 ms.
            FuncKind::FileRead | FuncKind::FileWrite | FuncKind::FileQuery => {
                Dist::Uniform(50_000.0, 150_000.0)
            }
            // Git: 100 ms, variability 100 ms–1 s (heavy tail).
            FuncKind::Git => Dist::LogNormal(LogNormal {
                median: 150_000.0,
                sigma: 0.9,
            }),
            // Database: 100–1000 ms, variability 500 ms.
            FuncKind::Database => Dist::Uniform(100_000.0, 1_000_000.0),
            // Web Search: 1–5 s, variability 1–10 s.
            FuncKind::WebSearch => Dist::LogNormal(LogNormal {
                median: 2_500_000.0,
                sigma: 0.7,
            }),
            // Multi-stage data analysis: seconds-scale.
            FuncKind::DataAnalysis => Dist::Uniform(2_000_000.0, 8_000_000.0),
            // User confirmation: human in the loop, seconds to tens of s.
            FuncKind::UserConfirm => Dist::LogNormal(LogNormal {
                median: 5_000_000.0,
                sigma: 0.8,
            }),
            // External test tools: compile+run, seconds.
            FuncKind::ExternalTest => Dist::Uniform(1_000_000.0, 6_000_000.0),
            // AI Generation: 5–30 s, variability 10–60 s.
            FuncKind::AiGeneration => Dist::LogNormal(LogNormal {
                median: 12_000_000.0,
                sigma: 0.8,
            }),
            FuncKind::Custom { latency_us, .. } => latency_us.clone(),
        };
        ToolLatency { dist }
    }

    /// Default internal stage decomposition (Table 3: each pre-built type
    /// bundles a stage count; DataAnalysis is explicitly multi-stage).
    pub fn default_stages(&self) -> u32 {
        match self {
            FuncKind::DataAnalysis => 4,
            FuncKind::WebSearch => 2,
            FuncKind::AiGeneration => 3,
            FuncKind::ExternalTest => 2,
            _ => 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Rng;

    #[test]
    fn names_unique() {
        let kinds = [
            FuncKind::FileRead,
            FuncKind::FileWrite,
            FuncKind::WebSearch,
            FuncKind::FileQuery,
            FuncKind::DataAnalysis,
            FuncKind::UserConfirm,
            FuncKind::ExternalTest,
            FuncKind::Git,
            FuncKind::Database,
            FuncKind::AiGeneration,
        ];
        let mut names: Vec<&str> = kinds.iter().map(|k| k.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), kinds.len());
    }

    #[test]
    fn table1_latency_bands() {
        // File system ~100ms; web search seconds; AI generation 10s-scale.
        let mut rng = Rng::new(1);
        let fs_mean = FuncKind::FileRead.latency().mean_us();
        assert!((90_000.0..110_000.0).contains(&fs_mean), "{fs_mean}");
        let ws = FuncKind::WebSearch.latency();
        let mean_ws = ws.mean_us();
        assert!(
            (1_000_000.0..5_000_000.0).contains(&mean_ws),
            "{mean_ws}"
        );
        let ai = FuncKind::AiGeneration.latency().mean_us();
        assert!(ai > 5_000_000.0, "{ai}");
        // Samples stay positive.
        for _ in 0..100 {
            assert!(ws.dist.sample(&mut rng) > 0.0);
        }
    }

    #[test]
    fn stage_defaults() {
        assert_eq!(FuncKind::DataAnalysis.default_stages(), 4);
        assert_eq!(FuncKind::FileRead.default_stages(), 1);
    }

    #[test]
    fn custom_tool() {
        let k = FuncKind::Custom {
            name: "my_tool".into(),
            latency_us: Dist::Constant(42.0),
        };
        assert_eq!(k.name(), "my_tool");
        assert_eq!(k.latency().mean_us(), 42.0);
    }
}
