//! The Temporal Scheduler (§4): event-driven offload and predictive upload.
//!
//! A function call makes both the idle interval *and* the resume point of a
//! KV cache explicitly visible. This module converts that signal into
//! memory decisions:
//!
//! * [`call_start`] / [`call_finish`] — the two runtime events (§4.1,
//!   mirrored by the HTTP endpoints in `server`);
//! * [`Forecaster`] — the Eq. 1 estimate blending user hints with an EWMA
//!   of observed durations;
//! * [`gate`] — the opportunistic offload policy (Algorithm 1 + scoring);
//! * [`upload`] — Eq. 3/Eq. 4 budgeted gradual reservation + transfer;
//! * [`on_transfer_done`] — completion of either transfer direction.

mod forecast;
pub mod gate;
pub mod upload;

pub use forecast::Forecaster;
pub use gate::{evaluate_offload, find_fit, OffloadDecision, RejectReason};
pub use upload::{
    next_upload_due_us, try_immediate_upload, upload_budget, upload_phase,
};


use crate::coordination::{
    Action, FcRt, PressureSnapshot, ReqState, RequestId, ServeState,
};
use crate::kvcache::{Direction, TransferId, TransferKind};
use crate::obs;

/// What the engine should do after a `call_finish` event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishDisposition {
    /// KV is on GPU — the request re-enters the waiting queue immediately.
    ResumeNow,
    /// KV is on CPU or in flight — resume happens when the upload lands.
    AwaitUpload,
}

/// `call_start` (§6.2): the request stalls on a function call. Predicts
/// the duration (Eq. 1), records the lifecycle state, and leaves the KV
/// resident — the offload decision happens in the next scheduling step.
pub fn call_start(
    st: &mut ServeState,
    rid: RequestId,
    name: &str,
    user_estimate_us: Option<u64>,
    result_tokens: u32,
    now_us: u64,
) {
    st.epochs.temporal += 1; // a new stall is a planning event
    let predicted =
        st.forecaster.predict_us(name, user_estimate_us);
    {
        let r = st.reqs.get_mut(&rid).unwrap();
        debug_assert!(matches!(r.state, ReqState::Running));
        r.state = ReqState::Stalled;
        r.offload_evaluated = false;
        r.fc = Some(FcRt {
            name: name.to_string(),
            started_us: now_us,
            predicted_end_us: now_us + predicted,
            tool_done: false,
            finished_us: 0,
            result_tokens,
            user_estimate_us,
        });
    }
    st.reindex_request(rid, ReqState::Stalled);
}

/// `call_finish` (§6.2): the tool returned. Feeds the forecaster and
/// resolves the request's residency.
pub fn call_finish(
    st: &mut ServeState,
    rid: RequestId,
    now_us: u64,
) -> FinishDisposition {
    st.epochs.temporal += 1; // a tool return is a planning event
    let (name, started, predicted_end, state) = {
        let r = st.reqs.get_mut(&rid).unwrap();
        let fc = r.fc.as_mut().expect("call_finish without call_start");
        fc.tool_done = true;
        fc.finished_us = now_us;
        (
            fc.name.clone(),
            fc.started_us,
            fc.predicted_end_us,
            r.state,
        )
    };
    st.forecaster.observe_us(&name, now_us - started);
    // Same observation, template-keyed: the autoscaler's KV-lifetime
    // predictor learns how long this template's calls stall its cache.
    st.note_fc_lifetime(rid, now_us - started);
    // Attribution: the stall stops being hideable at the return instant —
    // any residual absence-from-GPU after this point is *exposed*.
    st.note_tool_return(rid, now_us);

    match state {
        ReqState::Stalled => {
            resume_from_fc(st, rid, now_us);
            FinishDisposition::ResumeNow
        }
        ReqState::Uploaded => {
            resume_from_fc(st, rid, now_us);
            FinishDisposition::ResumeNow
        }
        ReqState::Offloaded => {
            // Tool returned earlier than predicted → immediate upload to
            // ensure correctness (§4.1).
            if now_us < predicted_end {
                st.metrics.counters.early_returns += 1;
            }
            try_immediate_upload(st, rid, now_us);
            FinishDisposition::AwaitUpload
        }
        ReqState::PendingOffload | ReqState::PendingUpload => {
            // Transfer in flight; the completion handler will chain the
            // upload / resume.
            FinishDisposition::AwaitUpload
        }
        other => unreachable!("call_finish in state {other:?}"),
    }
}

/// Move a finished function call's request back into the waiting queue:
/// the next generation phase begins, with the tool result appended to the
/// context (tokens that must be prefilled and may need new blocks — the
/// resume-time contention the Spatial Scheduler manages).
pub fn resume_from_fc(st: &mut ServeState, rid: RequestId, now_us: u64) {
    {
        let r = st.reqs.get_mut(&rid).unwrap();
        let fc = r.fc.take().expect("resume without fc");
        debug_assert!(fc.tool_done);
        r.cur_phase += 1;
        r.gen_in_phase = 0;
        r.context_tokens += fc.result_tokens;
        r.remaining_prefill += fc.result_tokens;
        r.state = ReqState::Waiting;
        r.queue_enter_us = now_us;
    }
    st.reindex_request(rid, ReqState::Waiting);
    st.waiting.push_back(rid);
}

/// How long the gate backs off when urgent upload work exists but the
/// planner could not move anything (no budget, no free blocks). A
/// transfer completion or any FC-lifecycle event bumps the epoch and
/// reopens the gate earlier; plain block frees deliberately do NOT
/// (see `ServeState::release_gpu`), so a free-driven retry waits at
/// most this backoff. It only bounds pure retry spin.
const RETRY_BACKOFF_US: u64 = 5_000;

/// Epoch/deadline-gated entry to the temporal planning phase (§3.2 phase
/// 3). This is the only way the tick loop may reach [`run_phase`] (CI
/// greps for direct calls): a steady-state decode tick — no stall, no
/// tool return, no transfer, no upload deadline — skips the planner and
/// never builds the pressure snapshot. Returns whether the planner ran.
pub fn maybe_run_phase(st: &mut ServeState, now_us: u64) -> bool {
    let due = st.epochs.temporal != st.planned.temporal
        || now_us >= st.temporal_next_due_us;
    if !due {
        st.metrics.counters.planner_skips += 1;
        return false;
    }
    st.metrics.counters.planner_runs += 1;
    st.trace_planner_run(obs::planner::TEMPORAL);
    let snap = st.snapshot();
    let progressed = run_phase(st, &snap, now_us);
    // The plan consumed everything up to and including its own
    // mutations; sync the watermark *after* the run.
    st.planned.temporal = st.epochs.temporal;
    let mut next = next_upload_due_us(st);
    if !progressed && next <= now_us {
        next = now_us.saturating_add(RETRY_BACKOFF_US);
    }
    st.temporal_next_due_us = next;
    true
}

/// Phase 3 of the scheduling step (§3.2): uploads first (they have
/// deadlines), then batched offload planning for newly stalled requests.
/// Returns whether anything moved (reservations, evaluations, offloads).
///
/// Offload is a *batch* decision: all pending candidates are scored once
/// against the same snapshot, then a bandwidth-capped multi-victim batch
/// is issued best-score-first, so a burst of stalls drains in one
/// planning event instead of trickling one victim per window. The cap is
/// on in-flight D2H blocks ([`crate::config::PolicyConfig::offload_inflight_cap_blocks`]);
/// victims that no longer fit stay unevaluated and the D2H completions
/// bump the epoch to resume the partial batch.
pub fn run_phase(
    st: &mut ServeState,
    snap: &PressureSnapshot,
    now_us: u64,
) -> bool {
    let mut progressed = upload_phase(st, snap, now_us);

    // Score every pending candidate once, off the id-ordered incremental
    // stalled index (O(stalled), order deterministic by construction).
    let newly_stalled: Vec<RequestId> = st
        .stalled_ids
        .iter()
        .copied()
        .filter(|rid| {
            let r = &st.reqs[rid];
            r.state == ReqState::Stalled && !r.offload_evaluated
        })
        .collect();
    let mut accepted: Vec<(RequestId, f64, u32, RequestId)> = Vec::new();
    for rid in newly_stalled {
        match evaluate_offload(st, snap, rid, now_us) {
            OffloadDecision::Accept { score, beneficiary } => {
                let blocks = st.reqs[&rid].blocks.len();
                accepted.push((rid, score, blocks, beneficiary));
            }
            OffloadDecision::Reject(_) => {
                st.reqs.get_mut(&rid).unwrap().offload_evaluated = true;
                st.metrics.counters.offloads_rejected += 1;
                progressed = true;
            }
        }
    }

    // Issue the bandwidth-capped batch, best score first (request id
    // breaks exact-score ties so storage order never decides).
    accepted.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    let cap = st.cfg.policy.offload_inflight_cap_blocks;
    let mut budget = cap.saturating_sub(st.ledger.inflight_offload_blocks());
    let mut victims = 0u64;
    for (rid, _score, blocks, beneficiary) in accepted {
        if blocks > cap {
            // Larger than the interconnect could ever carry at once —
            // reject instead of waiting forever for impossible budget.
            st.reqs.get_mut(&rid).unwrap().offload_evaluated = true;
            st.metrics.counters.offloads_rejected += 1;
            progressed = true;
            continue;
        }
        if blocks > budget {
            // Partial-batch fallback: the interconnect budget ran out.
            // The victim stays unevaluated; a D2H completion bumps the
            // temporal epoch and the next planning event resumes here.
            // Smaller later victims may still pack into the remainder.
            continue;
        }
        st.reqs.get_mut(&rid).unwrap().offload_evaluated = true;
        progressed = true;
        if !issue_offload(st, rid, now_us) {
            continue; // CPU full: evaluated + counted rejected
        }
        budget -= blocks;
        victims += 1;
        // The freed blocks exist *for* this waiting request: pull it to
        // the head of the queue so admission converts the offload into
        // scheduled work. (This is exactly where best_fit's reordering
        // disrupts the Spatial Scheduler's order — the §7.5 finding.)
        if beneficiary != rid {
            st.waiting.retain(|&x| x != beneficiary);
            st.waiting.push_front(beneficiary);
            if let Some(b) = st.reqs.get_mut(&beneficiary) {
                b.pulled = true;
            }
        }
    }
    if victims > 0 {
        st.metrics.counters.offload_batches += 1;
        st.metrics.counters.offload_batch_victims += victims;
    }
    progressed
}

/// Fire the D2H transfer: CPU blocks allocated, GPU blocks pending-free.
/// Returns false if the CPU pool filled up between gate and issue.
pub fn issue_offload(
    st: &mut ServeState,
    rid: RequestId,
    now_us: u64,
) -> bool {
    let n = st.reqs[&rid].blocks.len();
    // A live request's offload outranks cached prefixes squatting in the
    // CPU pool: drop LRU unpinned CPU prefix entries to make room first.
    if st.cpu.free_blocks() < n {
        crate::spatial::reclaim_prefix_cpu(st, n);
    }
    let Some(cpu_blocks) = st.cpu.alloc(n) else {
        // CPU filled up between gate and issue — abandon.
        st.metrics.counters.offloads_rejected += 1;
        return false;
    };
    let (gpu_blocks, charged, type_id) = {
        let r = st.reqs.get_mut(&rid).unwrap();
        debug_assert_eq!(r.state, ReqState::Stalled);
        r.state = ReqState::PendingOffload;
        r.cpu_blocks = cpu_blocks.clone();
        (
            r.blocks.take(),
            std::mem::take(&mut r.reserved_charged),
            r.type_id,
        )
    };
    st.reindex_request(rid, ReqState::PendingOffload);
    st.gpu.mark_pending_free(&gpu_blocks, charged, Some(type_id));
    let completes = now_us + st.cfg.profile.offload_us(n);
    let xfer = st.ledger.issue(
        rid.0,
        Direction::D2H,
        gpu_blocks,
        cpu_blocks,
        now_us,
        completes,
    );
    st.trace.transfer_start(
        xfer.0,
        rid.0,
        obs::xfer::REQUEST,
        true,
        n,
        completes - now_us,
    );
    st.metrics.offload_count += 1;
    st.outbox.push(Action::TransferIssued {
        xfer,
        completes_us: completes,
    });
    true
}

/// Handle a completed transfer (engine event). Returns a request that
/// became ready to resume, if any.
pub fn on_transfer_done(
    st: &mut ServeState,
    xfer: TransferId,
    now_us: u64,
) -> Option<RequestId> {
    // A completed transfer frees interconnect budget (and possibly
    // blocks) — the batched planner's partial batches resume on it.
    st.epochs.temporal += 1;
    let t = st.ledger.complete(xfer)?;
    st.metrics
        .wire_hist
        .record(t.completes_us.saturating_sub(t.issued_us));
    st.trace.transfer_end(
        xfer.0,
        t.req_id,
        matches!(t.dir, Direction::D2H),
    );
    match t.kind {
        TransferKind::Request => {}
        TransferKind::PrefixEvict { .. } => {
            // Prefix demotion D2H landed: the index's former GPU backing
            // becomes reusable; the entry already answers from its CPU
            // copy.
            st.gpu.complete_pending(t.gpu_blocks);
            return None;
        }
        TransferKind::PrefixHit { key, pinned } => {
            // Prefix upload landed: unpin the source entry (iff this
            // hit pinned it) and ungate the hitting request (its blocks
            // were already its own; a preempted request cancelled the
            // entry via `cancel_prefix_upload`, making this a no-op).
            if pinned {
                st.prefix.unpin(key);
            }
            let mut ungated = false;
            if let Some(r) = st.reqs.get_mut(&RequestId(t.req_id)) {
                if r.prefix_xfer == Some(xfer) {
                    r.prefix_xfer = None;
                    ungated = true;
                }
            }
            if ungated {
                // Attribution: prefix-fetch gating ends; prefill proper
                // starts at the landing instant.
                st.note_prefix_ready(RequestId(t.req_id));
            }
            return None;
        }
    }
    let rid = RequestId(t.req_id);
    match t.dir {
        Direction::D2H => {
            // Blocks become physically reusable.
            st.gpu.complete_pending(t.gpu_blocks);
            let tool_done = {
                let r = st.reqs.get_mut(&rid).unwrap();
                debug_assert_eq!(r.state, ReqState::PendingOffload);
                r.state = ReqState::Offloaded;
                r.fc.as_ref().map(|f| f.tool_done).unwrap_or(false)
            };
            st.reindex_request(rid, ReqState::Offloaded);
            if tool_done {
                // Tool already returned — immediate turnaround.
                try_immediate_upload(st, rid, now_us);
            }
            None
        }
        Direction::H2D => {
            // Destination blocks become the request's live KV.
            let tool_done = {
                let r = st.reqs.get_mut(&rid).unwrap();
                debug_assert_eq!(r.state, ReqState::PendingUpload);
                r.blocks = t.gpu_blocks;
                r.reserved_charged = r.upload_reserved_charged;
                r.upload_reserved_charged = 0;
                r.state = ReqState::Uploaded;
                r.migrations += 1;
                r.fc.as_ref().map(|f| f.tool_done).unwrap_or(false)
            };
            st.reindex_request(rid, ReqState::Uploaded);
            st.release_cpu(rid);
            if tool_done {
                resume_from_fc(st, rid, now_us);
                Some(rid)
            } else {
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Mode as M, ServeConfig};
    use crate::graph::templates;
    use crate::kvcache::{AllocOutcome, Route};
    use crate::workload::SampledLengths;

    fn running_state() -> (ServeState, RequestId) {
        let mut cfg = ServeConfig::default();
        cfg.mode = M::TokenCake;
        let mut st = ServeState::new(cfg);
        let g = templates::rag();
        let t = st.register_graph(&g);
        let scales = SampledLengths {
            prompt_scale: 1.0,
            gen_scale: 1.0,
        };
        let (app, _) = st.spawn_app(t, scales, 0);
        let rid = st.apps[&app].node_req[0].unwrap();
        st.waiting.retain(|&x| x != rid);
        // Simulate prior admission: allocate blocks, mark running.
        let n = st.cfg.profile.blocks_for_tokens(
            st.reqs[&rid].context_tokens,
        );
        let AllocOutcome::Granted { blocks, .. } =
            st.gpu.alloc(n, Route::Shared)
        else {
            panic!()
        };
        let r = st.reqs.get_mut(&rid).unwrap();
        r.blocks = blocks;
        r.remaining_prefill = 0;
        r.state = ReqState::Running;
        st.running.push(rid);
        (st, rid)
    }

    #[test]
    fn full_fc_lifecycle_without_offload() {
        let (mut st, rid) = running_state();
        st.running.remove(rid);
        call_start(&mut st, rid, "web_search", Some(3_000_000), 480, 1000);
        assert_eq!(st.reqs[&rid].state, ReqState::Stalled);
        assert_eq!(
            st.reqs[&rid].fc.as_ref().unwrap().predicted_end_us,
            3_001_000
        );
        let d = call_finish(&mut st, rid, 2_500_000);
        assert_eq!(d, FinishDisposition::ResumeNow);
        let r = &st.reqs[&rid];
        assert_eq!(r.state, ReqState::Waiting);
        assert_eq!(r.cur_phase, 1);
        assert_eq!(r.remaining_prefill, 480);
        assert!(st.waiting.contains(&rid));
        // Forecaster learned the observation.
        assert_eq!(st.forecaster.observations("web_search"), 1);
    }

    #[test]
    fn offload_then_upload_roundtrip() {
        let (mut st, rid) = running_state();
        st.running.remove(rid);
        call_start(&mut st, rid, "web_search", Some(30_000_000), 480, 0);
        assert!(st.stalled_ids.contains(&rid));
        let n_before = st.reqs[&rid].blocks.len();
        issue_offload(&mut st, rid, 0);
        assert_eq!(st.reqs[&rid].state, ReqState::PendingOffload);
        assert!(st.stalled_ids.is_empty());
        assert_eq!(st.gpu.pending_free_blocks(), n_before);
        // D2H completes.
        let xfer = match st.outbox.pop().unwrap() {
            Action::TransferIssued { xfer, .. } => xfer,
        };
        assert!(on_transfer_done(&mut st, xfer, 10_000).is_none());
        assert_eq!(st.reqs[&rid].state, ReqState::Offloaded);
        assert!(st.offloaded_ids.contains(&rid));
        assert_eq!(st.gpu.pending_free_blocks(), 0);
        assert_eq!(st.cpu.used_blocks(), n_before);
        // Tool returns early → immediate upload.
        let d = call_finish(&mut st, rid, 20_000);
        assert_eq!(d, FinishDisposition::AwaitUpload);
        assert_eq!(st.metrics.counters.early_returns, 1);
        assert_eq!(st.reqs[&rid].state, ReqState::PendingUpload);
        // H2D completes → resume.
        let xfer = match st.outbox.pop().unwrap() {
            Action::TransferIssued { xfer, .. } => xfer,
        };
        let resumed = on_transfer_done(&mut st, xfer, 30_000);
        assert_eq!(resumed, Some(rid));
        assert!(st.offloaded_ids.is_empty());
        let r = &st.reqs[&rid];
        assert_eq!(r.state, ReqState::Waiting);
        assert_eq!(r.blocks.len(), n_before);
        assert_eq!(r.migrations, 1);
        assert_eq!(st.cpu.used_blocks(), 0);
        assert_eq!(st.metrics.offload_count, 1);
        assert_eq!(st.metrics.upload_count, 1);
    }

    #[test]
    fn tool_finish_during_offload_chains_upload() {
        let (mut st, rid) = running_state();
        st.running.remove(rid);
        call_start(&mut st, rid, "git", Some(30_000_000), 96, 0);
        issue_offload(&mut st, rid, 0);
        // Tool returns while D2H still in flight.
        let d = call_finish(&mut st, rid, 5_000);
        assert_eq!(d, FinishDisposition::AwaitUpload);
        assert_eq!(st.reqs[&rid].state, ReqState::PendingOffload);
        // D2H lands → upload fires automatically.
        let xfer = match st.outbox.remove(0) {
            Action::TransferIssued { xfer, .. } => xfer,
        };
        on_transfer_done(&mut st, xfer, 10_000);
        assert_eq!(st.reqs[&rid].state, ReqState::PendingUpload);
    }

    #[test]
    fn run_phase_rejects_and_counts() {
        // Newly stalled under zero pressure → gate rejects, counted once.
        let (mut st, rid) = running_state();
        st.running.remove(rid);
        call_start(&mut st, rid, "web_search", Some(30_000_000), 480, 0);
        let snap = st.snapshot();
        run_phase(&mut st, &snap, 0);
        assert_eq!(st.metrics.counters.offloads_rejected, 1);
        assert!(st.reqs[&rid].offload_evaluated);
        // Second phase does not re-evaluate.
        let snap = st.snapshot();
        run_phase(&mut st, &snap, 1);
        assert_eq!(st.metrics.counters.offloads_rejected, 1);
    }

    /// Burst state: `n` stalled requests (40 blocks each, long stalls)
    /// under real waiting pressure, so every one passes the offload gate.
    fn burst_state(n: usize) -> (ServeState, Vec<RequestId>) {
        let mut cfg = ServeConfig::default();
        cfg.mode = M::TokenCake;
        cfg.gpu_mem_frac = 0.05; // 650 blocks
        let mut st = ServeState::new(cfg);
        let g = crate::graph::templates::code_writer();
        let t = st.register_graph(&g);
        let scales = SampledLengths {
            prompt_scale: 1.0,
            gen_scale: 1.0,
        };
        // Two waiting beneficiaries keep waiting pressure above the
        // watermark.
        st.spawn_app(t, scales, 0);
        st.spawn_app(t, scales, 0);
        // Fill the pool to ~0.9 usage; carve 40 blocks per victim out of
        // the fill so usage stays put.
        let total = st.gpu.total();
        let fill = (total as f64 * 0.9) as u32;
        let AllocOutcome::Granted { mut blocks, .. } =
            st.gpu.alloc(fill, Route::Shared)
        else {
            panic!()
        };
        let mut stalled = Vec::new();
        for _ in 0..n {
            let (app, _) = st.spawn_app(t, scales, 0);
            let rid = st.apps[&app].node_req[0].unwrap();
            st.waiting.retain(|&x| x != rid);
            let own = blocks.take_prefix(40);
            {
                let r = st.reqs.get_mut(&rid).unwrap();
                r.blocks = own;
                r.critical_path = false;
                r.fc = Some(crate::coordination::FcRt {
                    name: "web_search".into(),
                    started_us: 0,
                    predicted_end_us: 30_000_000,
                    tool_done: false,
                    finished_us: 0,
                    result_tokens: 480,
                    user_estimate_us: None,
                });
            }
            st.set_req_state(rid, ReqState::Stalled);
            stalled.push(rid);
        }
        st.refresh_priorities(0);
        (st, stalled)
    }

    #[test]
    fn burst_drains_in_one_multi_victim_batch() {
        // A pressure burst with 5 stalled apps drains via ONE planning
        // event: all candidates scored once, issued as a single
        // bandwidth-capped batch.
        let (mut st, stalled) = burst_state(5);
        let snap = st.snapshot();
        run_phase(&mut st, &snap, 0);
        for rid in &stalled {
            assert_eq!(
                st.reqs[rid].state,
                ReqState::PendingOffload,
                "{rid:?} must be in the batch"
            );
        }
        assert_eq!(st.metrics.offload_count, 5);
        assert_eq!(st.metrics.counters.offload_batches, 1);
        assert_eq!(st.metrics.counters.offload_batch_victims, 5);
        assert_eq!(st.ledger.inflight_offload_blocks(), 200);
        assert!(
            st.ledger.inflight_offload_blocks()
                <= st.cfg.policy.offload_inflight_cap_blocks
        );
    }

    #[test]
    fn partial_batch_respects_bandwidth_cap_and_resumes() {
        // Cap of 100 blocks: only 2 of 5 forty-block victims fit the
        // first window; the rest stay unevaluated (partial-batch
        // fallback) and go out once the in-flight transfers complete.
        let (mut st, _stalled) = burst_state(5);
        st.cfg.policy.offload_inflight_cap_blocks = 100;
        let snap = st.snapshot();
        run_phase(&mut st, &snap, 0);
        assert_eq!(st.metrics.offload_count, 2);
        assert_eq!(st.ledger.inflight_offload_blocks(), 80);
        // Deferred victims keep their candidacy.
        let pending: Vec<_> = st
            .stalled_ids
            .iter()
            .filter(|rid| !st.reqs[rid].offload_evaluated)
            .collect();
        assert_eq!(pending.len(), 3);
        // Complete the in-flight D2H legs → budget frees (and the epoch
        // bumps) → the next planning event resumes the batch.
        let xfers: Vec<_> = st
            .outbox
            .drain(..)
            .map(|a| match a {
                Action::TransferIssued { xfer, .. } => xfer,
            })
            .collect();
        for x in xfers {
            on_transfer_done(&mut st, x, 10_000);
        }
        assert_eq!(st.ledger.inflight_offload_blocks(), 0);
        let snap = st.snapshot();
        run_phase(&mut st, &snap, 10_000);
        assert_eq!(st.metrics.offload_count, 4);
        assert_eq!(st.metrics.counters.offload_batches, 2);
        assert_eq!(st.metrics.counters.offload_batch_victims, 4);
    }

    #[test]
    fn epoch_gate_skips_steady_ticks_and_wakes_on_events() {
        // No temporal events → the gate never runs the planner.
        let mut st = ServeState::new(ServeConfig::default());
        let g = templates::rag();
        st.register_graph(&g);
        for i in 0..10u64 {
            assert!(!maybe_run_phase(&mut st, 1_000 + i));
        }
        assert_eq!(st.metrics.counters.planner_runs, 0);
        assert_eq!(st.metrics.counters.planner_skips, 10);

        // A stall (call_start) bumps the temporal epoch: exactly one
        // planning event runs, then steady ticks skip again.
        let (mut st, rid) = running_state();
        st.running.remove(rid);
        call_start(&mut st, rid, "web_search", Some(30_000_000), 480, 0);
        assert!(maybe_run_phase(&mut st, 1_000));
        assert_eq!(st.metrics.counters.planner_runs, 1);
        assert!(st.reqs[&rid].offload_evaluated);
        for i in 0..10u64 {
            assert!(!maybe_run_phase(&mut st, 2_000 + i));
        }
        assert_eq!(st.metrics.counters.planner_runs, 1);
    }
}
