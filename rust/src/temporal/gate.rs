//! The opportunistic offload policy (§4.2, Algorithm 1).
//!
//! Offloading a stalled agent's KV cache is worthwhile only when (a) the
//! predicted stall covers a round-trip transfer, (b) a waiting request can
//! actually use the freed blocks, and (c) the later upload can be prepared
//! without displacing more important work. Four hard rejections run before
//! any scoring; survivors get a composite soft score.

use crate::config::{Mode, SelectionPolicy};
use crate::coordination::{PressureSnapshot, ReqState, RequestId, ServeState};

/// Why the gate rejected an offload (observability + tests + Fig 16).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// CPU pool cannot hold the cache.
    CpuCapacity,
    /// Predicted stall shorter than the round-trip transfer (Alg 1 line 4).
    StallTooShort,
    /// No waiting request fits the freed blocks / token capacity.
    NoWaitingFit,
    /// GPU pressure below the configured watermark — freed blocks would
    /// just sit idle (Fig 16's selectivity principle).
    PressureBelowWatermark,
    /// Composite score under threshold (critical / near-done / churny).
    ScoreTooLow,
}

/// Gate verdict.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OffloadDecision {
    Accept {
        score: f64,
        /// The waiting request the freed blocks would admit.
        beneficiary: RequestId,
    },
    Reject(RejectReason),
}

impl OffloadDecision {
    pub fn accepted(&self) -> bool {
        matches!(self, OffloadDecision::Accept { .. })
    }
}

/// Search the waiting queue for a request whose admission demand fits in
/// `freed_blocks` and whose total remaining work fits `token_capacity`
/// (Algorithm 1's FindFirstFitRequest, generalized to the three §7.5
/// policies).
pub fn find_fit(
    st: &ServeState,
    freed_blocks: u32,
    token_capacity: u64,
    policy: SelectionPolicy,
) -> Option<RequestId> {
    let fits = |rid: &RequestId| -> Option<(RequestId, u32, f64)> {
        let r = st.reqs.get(rid)?;
        if r.state != ReqState::Waiting {
            return None;
        }
        let demand = st.admission_demand(r);
        if demand == 0 || demand > freed_blocks {
            return None;
        }
        let remaining_work = r.remaining_prefill as u64
            + (r.total_gen_target() - r.tokens_generated) as u64;
        if remaining_work > token_capacity {
            return None;
        }
        Some((*rid, demand, r.priority))
    };

    match policy {
        SelectionPolicy::FirstFit => {
            st.waiting.iter().find_map(|rid| fits(rid).map(|f| f.0))
        }
        SelectionPolicy::BestFit => st
            .waiting
            .iter()
            .filter_map(fits)
            .min_by_key(|&(_, demand, _)| freed_blocks - demand)
            .map(|f| f.0),
        SelectionPolicy::PriorityFirst => st
            .waiting
            .iter()
            .filter_map(fits)
            .max_by(|a, b| a.2.total_cmp(&b.2))
            .map(|f| f.0),
    }
}

/// Evaluate whether to offload the stalled request `rid` (Algorithm 1 +
/// the hard-rejection / soft-scoring pipeline of §4.2).
pub fn evaluate_offload(
    st: &ServeState,
    snap: &PressureSnapshot,
    rid: RequestId,
    now_us: u64,
) -> OffloadDecision {
    let r = &st.reqs[&rid];
    debug_assert_eq!(r.state, ReqState::Stalled);
    let p = &st.cfg.policy;
    let profile = &st.cfg.profile;
    let n_blocks = r.blocks.len();

    // InferCept baseline: intercept-and-swap, no cost model — offload
    // whenever CPU space exists (Table 2's "Min-Waste" reduced to a
    // capacity check; it has no FC duration prediction).
    if st.cfg.mode == Mode::Infercept {
        if snap.cpu_free < n_blocks {
            return OffloadDecision::Reject(RejectReason::CpuCapacity);
        }
        // Still needs *some* beneficiary to be meaningful for admission,
        // but InferCept swaps regardless; use self as placeholder.
        return OffloadDecision::Accept {
            score: 1.0,
            beneficiary: rid,
        };
    }

    // ---- Hard rejection 1: CPU capacity. ----
    if snap.cpu_free < n_blocks {
        return OffloadDecision::Reject(RejectReason::CpuCapacity);
    }

    // ---- Hard rejection 2: stall too short (Alg 1 lines 2–5). ----
    let t_transfer = profile.round_trip_us(n_blocks);
    let fc = r.fc.as_ref().expect("stalled without fc");
    let t_fc_remaining = fc.predicted_end_us.saturating_sub(now_us);
    if t_fc_remaining <= t_transfer {
        return OffloadDecision::Reject(RejectReason::StallTooShort);
    }
    let t_window = t_fc_remaining - t_transfer;

    // ---- Hard rejection 3: waiting-request fit (Alg 1 lines 7–10). ----
    // Token capacity from the *system's* observed decode throughput (the
    // paper's formulation): within the window the freed blocks can host
    // that much useful work. A discounted share (÷ sqrt(batch)) tempers
    // the batch-wide optimism that §7.3 identifies as migration churn,
    // without collapsing to the overly pessimistic per-sequence rate.
    let active = (st.running.len() + st.prefilling.len()).max(1) as f64;
    let discounted_tps = st.throughput.tokens_per_sec() / active.sqrt();
    let n_capacity = (t_window as f64 / 1e6 * discounted_tps) as u64;
    let Some(beneficiary) =
        find_fit(st, n_blocks + snap.gpu_free, n_capacity, p.selection)
    else {
        return OffloadDecision::Reject(RejectReason::NoWaitingFit);
    };

    // ---- Hard rejection 4: pressure watermark (Fig 16). ----
    // Freed blocks are useful only when someone is waiting for memory:
    // demand from the waiting queue must exceed the watermark fraction,
    // and the pool must actually be under usage pressure.
    if snap.waiting_pressure() < p.pressure_watermark
        || snap.usage < p.offload_usage_threshold
    {
        return OffloadDecision::Reject(RejectReason::PressureBelowWatermark);
    }

    // ---- Soft scoring. ----
    let stall_ratio = t_fc_remaining as f64 / t_transfer.max(1) as f64;
    // Dominant positive term: stalls long relative to transfer.
    let margin_term = ((stall_ratio - 1.0) / 4.0).clamp(0.0, 1.0);
    let pressure_term = snap.usage.clamp(0.0, 1.0);
    let fit_quality = {
        let demand = st.admission_demand(&st.reqs[&beneficiary]);
        (demand as f64 / n_blocks.max(1) as f64).clamp(0.0, 1.0)
    };
    let cpu_term =
        (snap.cpu_free as f64 / st.cpu.total().max(1) as f64).clamp(0.0, 1.0);

    let mut score = 0.40 * margin_term
        + 0.30 * pressure_term
        + 0.20 * fit_quality
        + 0.10 * cpu_term;

    // Multi-tenant QoS: SLO distance nudges the batching score — an
    // app with a whole SLO of headroom gains up to +0.10 (safest
    // victim), one already past its SLO loses the same. Exactly zero
    // when QoS is off (`ShardQos::off` returns neutral headroom).
    if st.qos.enabled {
        let age_us =
            now_us.saturating_sub(st.apps[&r.app_id].arrival_us);
        score += 0.10
            * st.qos.headroom_frac(
                st.apps.template_of(&r.app_id),
                age_us,
            );
    }

    // Penalties — only when the mode is agent-aware (the §7.3 "offload"
    // ablation runs the temporal scheduler *without* agent context).
    if st.cfg.mode.agent_aware() {
        let is_critical = r.critical_path
            || st.spatial.critical_types.contains(&r.type_id);
        if is_critical {
            score -= p.critical_penalty * st.importance(r);
        }
    }
    if r.progress() > 0.8 {
        score -= p.near_completion_penalty;
    }
    score -= p.churn_penalty * r.migrations as f64;

    // Emergency exception: severe GPU pressure + large stall margin
    // overrides even a high-importance penalty.
    let emergency = snap.usage >= p.emergency_usage
        && stall_ratio >= p.emergency_margin;

    if score >= p.score_threshold || emergency {
        OffloadDecision::Accept { score, beneficiary }
    } else {
        OffloadDecision::Reject(RejectReason::ScoreTooLow)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Mode, ServeConfig};
    use crate::coordination::FcRt;
    use crate::graph::templates;
    use crate::kvcache::{AllocOutcome, Route};
    use crate::workload::SampledLengths;

    /// Build a state with one stalled request holding `blocks` blocks and
    /// one waiting request, under controllable pressure.
    fn setup(gpu_fill: f64) -> (ServeState, RequestId) {
        let mut cfg = ServeConfig::default();
        cfg.mode = Mode::TokenCake;
        // Small pool so a single waiting request constitutes real pressure.
        cfg.gpu_mem_frac = 0.01; // 130 blocks
        let mut st = ServeState::new(cfg);
        let g = templates::code_writer();
        let t = st.register_graph(&g);
        let scales = SampledLengths {
            prompt_scale: 1.0,
            gen_scale: 1.0,
        };
        // Waiting request (beneficiary candidate).
        st.spawn_app(t, scales, 0);
        // Stalled request: spawn a second app and hand-place its root.
        let (app2, _) = st.spawn_app(t, scales, 0);
        let rid = st.apps[&app2].node_req[0].unwrap();
        st.waiting.retain(|&x| x != rid);
        // Fill the pool to the requested usage.
        let total = st.gpu.total();
        let fill = (total as f64 * gpu_fill) as u32;
        let AllocOutcome::Granted { mut blocks, .. } =
            st.gpu.alloc(fill, Route::Shared)
        else {
            panic!()
        };
        // Give the stalled request 64 of those blocks (the rest stay
        // allocated to keep the pool under pressure).
        let own = blocks.take_prefix(64.min(blocks.len()));
        {
            let r = st.reqs.get_mut(&rid).unwrap();
            r.blocks = own;
            r.fc = Some(FcRt {
                name: "web_search".into(),
                started_us: 0,
                predicted_end_us: 5_000_000, // 5 s stall
                tool_done: false,
                finished_us: 0,
                result_tokens: 480,
                user_estimate_us: None,
            });
        }
        st.set_req_state(rid, ReqState::Stalled);
        st.refresh_priorities(0);
        (st, rid)
    }

    #[test]
    fn accepts_long_stall_under_pressure() {
        let (mut st, rid) = setup(0.9);
        st.reqs.get_mut(&rid).unwrap().critical_path = false;
        let snap = st.snapshot();
        let d = evaluate_offload(&st, &snap, rid, 0);
        assert!(d.accepted(), "{d:?}");
    }

    #[test]
    fn rejects_short_stall() {
        let (mut st, rid) = setup(0.9);
        st.reqs.get_mut(&rid).unwrap().fc.as_mut().unwrap()
            .predicted_end_us = 10_000; // 10 ms << round trip
        let snap = st.snapshot();
        assert_eq!(
            evaluate_offload(&st, &snap, rid, 0),
            OffloadDecision::Reject(RejectReason::StallTooShort)
        );
    }

    #[test]
    fn rejects_when_pressure_low() {
        let (st, rid) = setup(0.1); // pool nearly empty
        let snap = st.snapshot();
        assert_eq!(
            evaluate_offload(&st, &snap, rid, 0),
            OffloadDecision::Reject(RejectReason::PressureBelowWatermark)
        );
    }

    #[test]
    fn rejects_without_waiting_requests() {
        let (mut st, rid) = setup(0.9);
        st.waiting.clear();
        let snap = st.snapshot();
        assert_eq!(
            evaluate_offload(&st, &snap, rid, 0),
            OffloadDecision::Reject(RejectReason::NoWaitingFit)
        );
    }

    #[test]
    fn rejects_when_cpu_full() {
        let (mut st, rid) = setup(0.9);
        let all = st.cpu.free_blocks();
        st.cpu.alloc(all).unwrap();
        let snap = st.snapshot();
        assert_eq!(
            evaluate_offload(&st, &snap, rid, 0),
            OffloadDecision::Reject(RejectReason::CpuCapacity)
        );
    }

    #[test]
    fn churn_penalty_blocks_repeat_migrators() {
        let (mut st, rid) = setup(0.9);
        {
            let r = st.reqs.get_mut(&rid).unwrap();
            r.critical_path = false;
            r.migrations = 5;
        }
        let snap = st.snapshot();
        assert_eq!(
            evaluate_offload(&st, &snap, rid, 0),
            OffloadDecision::Reject(RejectReason::ScoreTooLow)
        );
    }

    #[test]
    fn critical_penalty_requires_agent_awareness() {
        // Same critical request: rejected under TokenCake, accepted under
        // OffloadOnly (agent-blind), matching §7.3's ablation semantics.
        let (mut st, rid) = setup(0.85);
        {
            let r = st.reqs.get_mut(&rid).unwrap();
            r.critical_path = true;
            r.priority = 1.2; // high importance
        }
        let snap = st.snapshot();
        let d_tc = evaluate_offload(&st, &snap, rid, 0);
        st.cfg.mode = Mode::OffloadOnly;
        let d_ob = evaluate_offload(&st, &snap, rid, 0);
        assert!(!d_tc.accepted(), "critical agent must be protected");
        assert!(d_ob.accepted(), "agent-blind mode offloads it anyway");
    }

    #[test]
    fn emergency_overrides_critical_penalty() {
        let (mut st, rid) = setup(0.99);
        {
            let r = st.reqs.get_mut(&rid).unwrap();
            r.critical_path = true;
            r.priority = 1.2;
            // Very long stall → large margin.
            r.fc.as_mut().unwrap().predicted_end_us = 60_000_000;
        }
        let snap = st.snapshot();
        assert!(evaluate_offload(&st, &snap, rid, 0).accepted());
    }

    #[test]
    fn infercept_skips_cost_model() {
        let (mut st, rid) = setup(0.1); // no pressure at all
        st.cfg.mode = Mode::Infercept;
        st.reqs.get_mut(&rid).unwrap().fc.as_mut().unwrap()
            .predicted_end_us = 10_000; // even short stalls
        let snap = st.snapshot();
        assert!(evaluate_offload(&st, &snap, rid, 0).accepted());
    }

    #[test]
    fn slo_headroom_biases_the_offload_score() {
        use crate::qos::{QosConfig, ShardQos, Tier};
        let (mut st, rid) = setup(0.9);
        st.reqs.get_mut(&rid).unwrap().critical_path = false;
        let snap = st.snapshot();
        let now = 1_000_000; // app age: 1 s
        let OffloadDecision::Accept {
            score: score_off, ..
        } = evaluate_offload(&st, &snap, rid, now)
        else {
            panic!("baseline offload must be accepted");
        };
        // A whole SLO of headroom (100 s SLO, 1 s age → 0.990 frac)
        // adds exactly +0.10 × 0.990 — the fixed-point term is
        // deterministic, so the delta is exact.
        let qcfg = QosConfig {
            enabled: true,
            slo_us: [100_000_000; 3],
            ..QosConfig::default()
        };
        st.qos = ShardQos::configure(&qcfg, vec![Tier::Interactive]);
        let OffloadDecision::Accept { score: score_hi, .. } =
            evaluate_offload(&st, &snap, rid, now)
        else {
            panic!("headroom must not reject an accepted offload");
        };
        assert!((score_hi - score_off - 0.099).abs() < 1e-9);
        // Past its SLO the same app scores strictly lower (or drops
        // under the threshold entirely).
        let qcfg = QosConfig {
            enabled: true,
            slo_us: [500_000; 3],
            ..QosConfig::default()
        };
        st.qos = ShardQos::configure(&qcfg, vec![Tier::Interactive]);
        match evaluate_offload(&st, &snap, rid, now) {
            OffloadDecision::Accept { score, .. } => {
                assert!(score < score_off)
            }
            OffloadDecision::Reject(RejectReason::ScoreTooLow) => {}
            d => panic!("unexpected verdict: {d:?}"),
        }
    }

    #[test]
    fn find_fit_policies_differ() {
        let (st, _) = setup(0.5);
        // One waiting request exists; all policies find it.
        let cap = u64::MAX;
        let free = st.gpu.total();
        for pol in [
            SelectionPolicy::FirstFit,
            SelectionPolicy::BestFit,
            SelectionPolicy::PriorityFirst,
        ] {
            assert!(find_fit(&st, free, cap, pol).is_some(), "{pol:?}");
        }
        // Nothing fits in zero blocks.
        assert!(find_fit(&st, 0, cap, SelectionPolicy::FirstFit).is_none());
        // Nothing fits in zero token capacity.
        assert!(find_fit(&st, free, 0, SelectionPolicy::FirstFit).is_none());
    }
}
