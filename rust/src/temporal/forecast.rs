//! Dynamic tool-time forecasting (§4.1, Eq. 1).
//!
//! Per-function-type estimate lifecycle:
//! 1. no history, no user estimate → conservative system default;
//! 2. no history, user estimate → the user estimate;
//! 3. history only → EWMA of observed durations;
//! 4. both → blend: t = α·t_user + (1−α)·t_history  (Eq. 1).

use std::collections::HashMap;

/// Per-function-type execution time model.
#[derive(Debug, Clone)]
pub struct Forecaster {
    /// Eq. 1 blend weight α on the user estimate.
    alpha_user: f64,
    /// EWMA smoothing factor for new observations.
    ewma: f64,
    /// System-wide conservative default (µs).
    default_us: u64,
    /// name → smoothed observed duration (µs).
    history: HashMap<String, f64>,
    /// name → observation count.
    counts: HashMap<String, u64>,
}

impl Forecaster {
    pub fn new(alpha_user: f64, ewma: f64, default_us: u64) -> Self {
        assert!((0.0..=1.0).contains(&alpha_user));
        assert!((0.0..=1.0).contains(&ewma));
        Self {
            alpha_user,
            ewma,
            default_us,
            history: HashMap::new(),
            counts: HashMap::new(),
        }
    }

    /// Predict the duration of a call of type `name` with an optional
    /// user-supplied estimate.
    pub fn predict_us(&self, name: &str, user_estimate_us: Option<u64>) -> u64 {
        match (self.history.get(name), user_estimate_us) {
            (Some(&h), Some(u)) => {
                (self.alpha_user * u as f64 + (1.0 - self.alpha_user) * h)
                    as u64
            }
            (Some(&h), None) => h as u64,
            (None, Some(u)) => u,
            (None, None) => self.default_us,
        }
    }

    /// Feed back an observed execution (call_finish → Eq. 1 refinement).
    pub fn observe_us(&mut self, name: &str, actual_us: u64) {
        let c = self.counts.entry(name.to_string()).or_insert(0);
        *c += 1;
        let h = self.history.entry(name.to_string()).or_insert(0.0);
        if *c == 1 {
            *h = actual_us as f64;
        } else {
            *h = (1.0 - self.ewma) * *h + self.ewma * actual_us as f64;
        }
    }

    pub fn observations(&self, name: &str) -> u64 {
        self.counts.get(name).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_then_user_then_history() {
        let mut f = Forecaster::new(0.4, 0.3, 2_000_000);
        // No info at all → default.
        assert_eq!(f.predict_us("git", None), 2_000_000);
        // User estimate wins when no history.
        assert_eq!(f.predict_us("git", Some(500_000)), 500_000);
        // First observation seeds the EWMA directly.
        f.observe_us("git", 100_000);
        assert_eq!(f.predict_us("git", None), 100_000);
        // Eq. 1 blend once both exist: 0.4*500k + 0.6*100k = 260k.
        assert_eq!(f.predict_us("git", Some(500_000)), 260_000);
    }

    #[test]
    fn ewma_converges_toward_observations() {
        let mut f = Forecaster::new(0.4, 0.3, 1_000);
        f.observe_us("t", 100);
        for _ in 0..50 {
            f.observe_us("t", 1_000);
        }
        let p = f.predict_us("t", None);
        assert!((900..=1_000).contains(&p), "p={p}");
        assert_eq!(f.observations("t"), 51);
    }

    #[test]
    fn types_are_independent_streams() {
        let mut f = Forecaster::new(0.5, 0.5, 7);
        f.observe_us("a", 100);
        assert_eq!(f.predict_us("b", None), 7);
        assert_eq!(f.predict_us("a", None), 100);
    }
}
