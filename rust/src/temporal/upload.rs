//! Predictive upload (§4.3).
//!
//! If the upload starts only when the tool returns, the resumed request
//! stalls on the H2D transfer; if destination blocks are all grabbed up
//! front, active requests lose memory too early. The resolution is a
//! budgeted, gradual reservation:
//!
//! * candidates ranked by `P_upload = I + U` (importance from the Spatial
//!   Scheduler's metric + urgency from proximity to predicted completion);
//! * per-step budget  B_upload = max(0, B_gpu_free − max(0, D_critical −
//!   B_shared_free))   (Eq. 3) so uploads never consume blocks critical
//!   waiting requests need;
//! * per-candidate reservation  B_reserve = min(B_remain, ⌈B_deficit/2⌉,
//!   B_upload)   (Eq. 4) — at most half the remaining deficit per step,
//!   amortizing allocation over several cycles.

use crate::coordination::{
    Action, PressureSnapshot, ReqState, RequestId, ServeState,
};
use crate::kvcache::{AllocOutcome, Direction, Route};

/// Upload urgency U: 0 far from the predicted completion, →1 as it
/// approaches, >1 once the tool has already returned (overdue).
fn urgency(st: &ServeState, rid: RequestId, now_us: u64) -> f64 {
    let r = &st.reqs[&rid];
    let Some(fc) = &r.fc else { return 0.0 };
    if fc.tool_done {
        return 1.5;
    }
    let n_blocks = r.cpu_blocks.len() as u32;
    let lead = lead_time_us(st, n_blocks, fc.predicted_end_us, fc.started_us);
    let remaining = fc.predicted_end_us.saturating_sub(now_us);
    if remaining >= lead {
        0.0
    } else if lead == 0 {
        1.0
    } else {
        1.0 - remaining as f64 / lead as f64
    }
}

/// How early to begin preparing the upload: enough to cover the transfer
/// several times over, or the configured fraction of the whole stall.
fn lead_time_us(
    st: &ServeState,
    n_blocks: u32,
    predicted_end_us: u64,
    started_us: u64,
) -> u64 {
    let transfer = st.cfg.profile.upload_us(n_blocks);
    let stall = predicted_end_us.saturating_sub(started_us);
    (3 * transfer).max((st.cfg.policy.upload_lead_frac * stall as f64) as u64)
}

/// Eq. 3: this step's upload budget.
pub fn upload_budget(snap: &PressureSnapshot) -> u32 {
    let critical_unmet =
        snap.critical_demand.saturating_sub(snap.shared_free);
    snap.gpu_free.saturating_sub(critical_unmet)
}

/// Phase-3a: advance gradual reservations and fire ready uploads.
/// Returns whether any reservation advanced or a transfer fired — the
/// epoch gate uses this to back off instead of replanning every tick
/// when urgent work exists but nothing can move.
///
/// Convoy-deadlock discipline: at most one request system-wide may hold an
/// *incomplete* reservation. Multiple half-reserved uploads would strand
/// blocks none of them can use (each blocks the others' completion *and*
/// all admissions) — the gradual schedule of Eq. 4 applies to the focused
/// candidate; everyone else starts only once the pool has no partials.
pub fn upload_phase(
    st: &mut ServeState,
    snap: &PressureSnapshot,
    now_us: u64,
) -> bool {
    let mut progressed = false;
    if st.offloaded_ids.is_empty() {
        return progressed; // common case: nothing CPU-resident, zero work
    }
    // Collect candidates off the incremental offloaded index (id order):
    // CPU-resident caches whose urgency is positive, plus anyone already
    // holding a partial reservation (must finish).
    let mut cands: Vec<(RequestId, f64, bool)> = Vec::new();
    for &rid in &st.offloaded_ids {
        let r = &st.reqs[&rid];
        if r.state != ReqState::Offloaded {
            continue; // stale index entry (defensive)
        }
        let u = urgency(st, rid, now_us);
        let partial = !r.upload_reserved.is_empty();
        if partial || u > 0.0 {
            cands.push((rid, st.importance(r) + u, partial));
        }
    }
    // Partial holders first (finish what we started), then P_upload = I+U;
    // request id breaks exact-score ties so storage order never decides
    // who uploads first.
    cands.sort_by(|a, b| {
        b.2.cmp(&a.2)
            .then(b.1.total_cmp(&a.1))
            .then(a.0.cmp(&b.0))
    });
    let mut partial_outstanding =
        cands.iter().filter(|c| c.2).count() as u32;

    // Eq. 3 budget protects critical *waiting* demand — but an overdue
    // upload (tool already returned) is itself the most urgent waiting
    // work, so it draws on the full free pool instead of starving behind
    // fresh admissions.
    let mut budget = upload_budget(snap);
    let mut overdue_budget = snap.gpu_free;
    for (rid, _, had_partial) in cands {
        let overdue = st.reqs[&rid]
            .fc
            .as_ref()
            .map(|f| f.tool_done)
            .unwrap_or(false);
        if (overdue && overdue_budget == 0)
            || (!overdue && budget == 0)
        {
            continue;
        }
        // Only one incomplete reservation at a time: new candidates wait
        // until no partials are outstanding.
        if !had_partial && partial_outstanding > 0 {
            continue;
        }
        let (needed, deficit, type_id, is_critical) = {
            let r = &st.reqs[&rid];
            let needed = r.cpu_blocks.len() as u32;
            let deficit =
                needed.saturating_sub(r.upload_reserved.len());
            let crit = r.critical_path
                || st.spatial.critical_types.contains(&r.type_id);
            (needed, deficit, r.type_id, crit)
        };
        if needed == 0 {
            continue;
        }
        if deficit > 0 {
            // Eq. 4: at most half the remaining deficit, within budget.
            let avail = if overdue { overdue_budget } else { budget };
            let reserve = deficit.div_ceil(2).min(avail);
            if reserve == 0 {
                continue;
            }
            let route = if is_critical && st.cfg.mode.reserves_memory() {
                Route::Reserved(type_id)
            } else {
                Route::Shared
            };
            if let AllocOutcome::Granted {
                blocks,
                reserved_charged,
            } = st.gpu.alloc(reserve, route)
            {
                if overdue {
                    overdue_budget = overdue_budget.saturating_sub(reserve);
                } else {
                    budget = budget.saturating_sub(reserve);
                }
                let r = st.reqs.get_mut(&rid).unwrap();
                r.upload_reserved.absorb(blocks);
                r.upload_reserved_charged += reserved_charged;
                progressed = true;
            }
        }
        // Fully reserved → fire the transfer.
        let ready = {
            let r = &st.reqs[&rid];
            r.upload_reserved.len() >= needed
        };
        if ready {
            issue_upload(st, rid, now_us);
            progressed = true;
            if had_partial {
                partial_outstanding -= 1;
            }
        } else if !had_partial {
            partial_outstanding += 1;
        }
    }
    progressed
}

/// Earliest absolute time the predictive-upload schedule has work: a
/// partial reservation or an overdue tool means *now*; otherwise the
/// soonest lead-window entry among CPU-resident caches; `u64::MAX` when
/// nothing is offloaded. The epoch gate sleeps until this deadline —
/// between temporal events, ticks before it skip the planner entirely.
pub fn next_upload_due_us(st: &ServeState) -> u64 {
    let mut due = u64::MAX;
    for &rid in &st.offloaded_ids {
        let r = &st.reqs[&rid];
        if r.state != ReqState::Offloaded {
            continue; // stale index entry (defensive)
        }
        if !r.upload_reserved.is_empty() {
            return 0; // gradual reservation in progress: every tick
        }
        let Some(fc) = &r.fc else { continue };
        if fc.tool_done {
            return 0; // overdue: retry every tick until blocks appear
        }
        let n = r.cpu_blocks.len() as u32;
        let lead =
            lead_time_us(st, n, fc.predicted_end_us, fc.started_us);
        // urgency() turns positive once remaining < lead, i.e. strictly
        // after predicted_end − lead.
        due = due.min(
            fc.predicted_end_us.saturating_sub(lead).saturating_add(1),
        );
    }
    due
}

/// Fire the H2D transfer for a fully reserved (or force-allocated) upload.
pub fn issue_upload(st: &mut ServeState, rid: RequestId, now_us: u64) {
    let (gpu_blocks, cpu_blocks, n) = {
        let r = st.reqs.get_mut(&rid).unwrap();
        debug_assert_eq!(r.state, ReqState::Offloaded);
        let gpu_blocks = r.upload_reserved.take();
        let n = gpu_blocks.len();
        debug_assert_eq!(n, r.cpu_blocks.len() as u32);
        r.state = ReqState::PendingUpload;
        (gpu_blocks, r.cpu_blocks.clone(), n)
    };
    st.reindex_request(rid, ReqState::PendingUpload);
    let completes = now_us + st.cfg.profile.upload_us(n);
    let xfer = st.ledger.issue(
        rid.0,
        Direction::H2D,
        gpu_blocks,
        cpu_blocks,
        now_us,
        completes,
    );
    st.trace.transfer_start(
        xfer.0,
        rid.0,
        crate::obs::xfer::REQUEST,
        false,
        n,
        completes - now_us,
    );
    st.metrics.upload_count += 1;
    st.outbox.push(Action::TransferIssued {
        xfer,
        completes_us: completes,
    });
}

/// Attempt an *immediate* full reservation + upload (early tool return or
/// reactive baselines). Returns false if blocks are unavailable — the
/// request stays Offloaded and upload_phase retries with urgency 1.5.
pub fn try_immediate_upload(
    st: &mut ServeState,
    rid: RequestId,
    now_us: u64,
) -> bool {
    let (deficit, type_id, is_critical) = {
        let r = &st.reqs[&rid];
        let needed = r.cpu_blocks.len() as u32;
        (
            needed.saturating_sub(r.upload_reserved.len()),
            r.type_id,
            r.critical_path
                || st.spatial.critical_types.contains(&r.type_id),
        )
    };
    if deficit > 0 {
        let route = if is_critical && st.cfg.mode.reserves_memory() {
            Route::Reserved(type_id)
        } else {
            Route::Shared
        };
        match st.gpu.alloc(deficit, route) {
            AllocOutcome::Granted {
                blocks,
                reserved_charged,
            } => {
                let r = st.reqs.get_mut(&rid).unwrap();
                r.upload_reserved.absorb(blocks);
                r.upload_reserved_charged += reserved_charged;
            }
            AllocOutcome::Deferred => return false,
        }
    }
    issue_upload(st, rid, now_us);
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;
    use crate::coordination::FcRt;
    use crate::graph::templates;
    use crate::workload::SampledLengths;

    fn offloaded_state(n_cpu_blocks: u32) -> (ServeState, RequestId) {
        let mut st = ServeState::new(ServeConfig::default());
        let g = templates::rag();
        let t = st.register_graph(&g);
        let scales = SampledLengths {
            prompt_scale: 1.0,
            gen_scale: 1.0,
        };
        let (app, _) = st.spawn_app(t, scales, 0);
        let rid = st.apps[&app].node_req[0].unwrap();
        st.waiting.retain(|&x| x != rid);
        let cpu = st.cpu.alloc(n_cpu_blocks).unwrap();
        {
            let r = st.reqs.get_mut(&rid).unwrap();
            r.cpu_blocks = cpu;
            r.fc = Some(FcRt {
                name: "web_search".into(),
                started_us: 0,
                predicted_end_us: 3_000_000,
                tool_done: false,
                finished_us: 0,
                result_tokens: 480,
                user_estimate_us: None,
            });
        }
        // Through the index-maintaining setter, not a raw field write.
        st.set_req_state(rid, ReqState::Offloaded);
        (st, rid)
    }

    #[test]
    fn eq3_budget_protects_critical_demand() {
        let snap = PressureSnapshot {
            gpu_free: 100,
            shared_free: 60,
            critical_demand: 80,
            ..Default::default()
        };
        // unmet critical = 80-60 = 20 → budget = 100-20 = 80.
        assert_eq!(upload_budget(&snap), 80);
        let snap2 = PressureSnapshot {
            gpu_free: 10,
            shared_free: 0,
            critical_demand: 50,
            ..Default::default()
        };
        assert_eq!(upload_budget(&snap2), 0);
    }

    #[test]
    fn gradual_reservation_halves_deficit() {
        let (mut st, rid) = offloaded_state(32);
        // Make upload urgent: predicted end now.
        st.reqs.get_mut(&rid).unwrap().fc.as_mut().unwrap()
            .predicted_end_us = 1_000;
        let snap = st.snapshot();
        upload_phase(&mut st, &snap, 900);
        let r = &st.reqs[&rid];
        // First step reserves ceil(32/2) = 16.
        assert_eq!(r.upload_reserved.len(), 16);
        assert_eq!(r.state, ReqState::Offloaded);
        // Second step: 8, then 4, 2, 1, 1 → issue on the step reaching 32.
        let mut steps = 1;
        while st.reqs[&rid].state == ReqState::Offloaded && steps < 10 {
            let snap = st.snapshot();
            upload_phase(&mut st, &snap, 900 + steps);
            steps += 1;
        }
        assert_eq!(st.reqs[&rid].state, ReqState::PendingUpload);
        assert_eq!(st.ledger.inflight_count(), 1);
        assert_eq!(st.metrics.upload_count, 1);
        assert!(!st.outbox.is_empty());
    }

    #[test]
    fn no_reservation_before_lead_window() {
        let (mut st, rid) = offloaded_state(16);
        // Predicted end far in the future → urgency 0 → untouched.
        st.reqs.get_mut(&rid).unwrap().fc.as_mut().unwrap()
            .predicted_end_us = 3_600_000_000;
        let snap = st.snapshot();
        upload_phase(&mut st, &snap, 0);
        assert!(st.reqs[&rid].upload_reserved.is_empty());
    }

    #[test]
    fn immediate_upload_on_early_return() {
        let (mut st, rid) = offloaded_state(16);
        st.reqs.get_mut(&rid).unwrap().fc.as_mut().unwrap().tool_done =
            true;
        assert!(try_immediate_upload(&mut st, rid, 100));
        assert_eq!(st.reqs[&rid].state, ReqState::PendingUpload);
    }

    #[test]
    fn immediate_upload_fails_gracefully_when_full() {
        let (mut st, rid) = offloaded_state(16);
        let all = st.gpu.free_blocks();
        let crate::kvcache::AllocOutcome::Granted { .. } =
            st.gpu.alloc(all, Route::Shared)
        else {
            panic!()
        };
        assert!(!try_immediate_upload(&mut st, rid, 100));
        assert_eq!(st.reqs[&rid].state, ReqState::Offloaded);
    }

    #[test]
    fn overdue_tool_maxes_urgency() {
        let (mut st, rid) = offloaded_state(8);
        st.reqs.get_mut(&rid).unwrap().fc.as_mut().unwrap().tool_done =
            true;
        assert!(urgency(&st, rid, 0) > 1.0);
    }
}
