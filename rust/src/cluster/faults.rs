//! Deterministic fault injection and crash recovery.
//!
//! A seeded [`FaultPlan`] schedules shard crashes and interconnect
//! partition windows at fixed simulated times; the cluster engine
//! executes them on the shared clock, so the same seed and config
//! produce byte-identical digests with or without faults enabled.
//!
//! * **Crash** — the shard loses every KV block instantly. Its live and
//!   stalled applications re-queue through the router onto survivors
//!   (re-prefill charged on the destination, lifetime EWMAs retained —
//!   the predictor is cluster-level), the prefix directory invalidates
//!   the dead holder and promotes surviving replicas, mid-wire
//!   transfers *into* the shard are re-accounted as dropped, and the
//!   autoscale controller sees an un-drained capacity hole it regrows
//!   through the normal warm-up path.
//! * **Partition** — a straggling link between one shard pair: bulk
//!   transfers planned across it while the window is open pay
//!   `factor ×` wire cost plus a fixed delivery hold, or (hard
//!   partition) are skipped at planning time.
//!
//! Every block a crash destroys lands in the [`CrashLossLedger`], which
//! extends the conservation invariant: a block is free, held,
//! prefix-resident, wire-accounted, or *explicitly crash-lost* — never
//! silently gone. The ledger is only ever mutated here (CI-enforced):
//! the engine's crash mechanics return loss counts, and this module
//! records them.

use crate::config::FaultConfig;
use crate::obs;
use crate::sim::Rng;

use super::engine::ClusterEngine;

/// One planned fault on the shared clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Shard `shard` loses its entire GPU/CPU KV state instantly.
    Crash { shard: usize },
    /// The `a`↔`b` link degrades until the matching [`Self::PartitionEnd`]:
    /// bulk transfers planned across it pay `factor_milli / 1000 ×`
    /// wire cost plus `hold_us`; with `drop_wire` the planner skips the
    /// pair entirely (hard partition).
    PartitionStart {
        a: usize,
        b: usize,
        factor_milli: u64,
        hold_us: u64,
        drop_wire: bool,
    },
    /// The `a`↔`b` link heals.
    PartitionEnd { a: usize, b: usize },
}

/// A fault and the simulated instant it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    pub at_us: u64,
    pub kind: FaultKind,
}

/// The full, deterministic fault schedule for one run.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Time-sorted (ties broken by kind then shard indices).
    pub events: Vec<FaultEvent>,
}

/// Stable tie-break rank so plan order never depends on build order.
fn sort_key(e: &FaultEvent) -> (u64, u8, usize, usize) {
    match e.kind {
        FaultKind::Crash { shard } => (e.at_us, 0, shard, 0),
        FaultKind::PartitionStart { a, b, .. } => (e.at_us, 1, a, b),
        FaultKind::PartitionEnd { a, b } => (e.at_us, 2, a, b),
    }
}

impl FaultPlan {
    /// Expand config into a concrete schedule. The explicit
    /// `crash_schedule` entries come first; `crashes` / `partitions`
    /// random faults land uniformly in the configured window, drawn
    /// from decorrelated sub-streams of the fault seed (seed 0 derives
    /// from the workload seed, so a seed sweep also sweeps placement).
    pub fn build(
        cfg: &FaultConfig,
        shards: usize,
        workload_seed: u64,
    ) -> FaultPlan {
        let mut events: Vec<FaultEvent> = Vec::new();
        for part in
            cfg.crash_schedule.split(';').filter(|s| !s.is_empty())
        {
            let (s, ms) = part
                .split_once('@')
                .expect("crash_schedule entry must be shard@ms");
            let shard: usize = s
                .trim()
                .parse()
                .expect("crash_schedule shard must be an integer");
            assert!(
                shard < shards,
                "crash_schedule names shard {shard} but the fleet \
                 provisions {shards}"
            );
            let at_ms: u64 = ms
                .trim()
                .parse()
                .expect("crash_schedule time must be integer ms");
            events.push(FaultEvent {
                at_us: at_ms * 1000,
                kind: FaultKind::Crash { shard },
            });
        }
        let seed = if cfg.seed == 0 { workload_seed } else { cfg.seed };
        let base = Rng::new(seed).fold(0xFA_17);
        for k in 0..cfg.crashes {
            let mut r = base.fold(10 + k as u64);
            let shard = r.range_u64(0, shards as u64) as usize;
            let at_us = cfg.window_start_us
                + r.range_u64(0, cfg.window_len_us);
            events.push(FaultEvent {
                at_us,
                kind: FaultKind::Crash { shard },
            });
        }
        if shards >= 2 {
            let factor_milli = (cfg.partition_factor * 1000.0) as u64;
            for k in 0..cfg.partitions {
                let mut r = base.fold(1000 + k as u64);
                let a = r.range_u64(0, shards as u64) as usize;
                let mut b = r.range_u64(0, shards as u64) as usize;
                while b == a {
                    b = r.range_u64(0, shards as u64) as usize;
                }
                let start = cfg.window_start_us
                    + r.range_u64(0, cfg.window_len_us);
                events.push(FaultEvent {
                    at_us: start,
                    kind: FaultKind::PartitionStart {
                        a,
                        b,
                        factor_milli,
                        hold_us: cfg.partition_hold_us,
                        drop_wire: cfg.drop_wire,
                    },
                });
                events.push(FaultEvent {
                    at_us: start + cfg.partition_len_us,
                    kind: FaultKind::PartitionEnd { a, b },
                });
            }
        }
        events.sort_by_key(sort_key);
        FaultPlan { events }
    }
}

/// Everything a crash destroyed and what recovery did about it — built
/// by `ClusterEngine::crash_shard`, recorded into the ledger here.
#[derive(Debug, Clone, Copy, Default)]
pub(super) struct CrashOutcome {
    /// Request KV blocks wiped (GPU-resident plus offloaded CPU tier).
    pub(super) lost_app_blocks: u64,
    /// Prefix-cache blocks purged from the dead shard (all copies).
    pub(super) lost_prefix_blocks: u64,
    /// Subset of the purged prefix blocks with no surviving replica.
    pub(super) sole_prefix_blocks: u64,
    /// Mid-wire migration payloads headed *into* the dead shard.
    pub(super) lost_wire_blocks: u64,
    pub(super) requeued_apps: u64,
    /// Re-prefill tokens recovery charged on the destinations.
    pub(super) requeued_tokens: u64,
}

/// Accounted loss: every block a crash destroys is recorded in exactly
/// one bucket, closing the conservation invariant (free | held |
/// prefix-resident | wire-accounted | crash-lost). Mutated only inside
/// this module — a CI grep confines `note_lost` call sites here.
#[derive(Debug, Clone, Copy, Default)]
pub struct CrashLossLedger {
    crashes: u64,
    app_blocks: u64,
    prefix_blocks: u64,
    sole_prefix_blocks: u64,
    wire_blocks: u64,
    replica_drop_blocks: u64,
    requeued_apps: u64,
    requeued_tokens: u64,
}

impl CrashLossLedger {
    fn note_lost_crash(&mut self, o: &CrashOutcome) {
        self.crashes += 1;
        self.app_blocks += o.lost_app_blocks;
        self.prefix_blocks += o.lost_prefix_blocks;
        self.sole_prefix_blocks += o.sole_prefix_blocks;
        self.wire_blocks += o.lost_wire_blocks;
        self.requeued_apps += o.requeued_apps;
        self.requeued_tokens += o.requeued_tokens;
    }

    fn note_lost_replica(&mut self, blocks: u32) {
        self.replica_drop_blocks += blocks as u64;
    }

    pub fn crashes(&self) -> u64 {
        self.crashes
    }

    /// Request KV blocks wiped at crash instants.
    pub fn app_blocks(&self) -> u64 {
        self.app_blocks
    }

    /// Prefix blocks purged from dead shards.
    pub fn prefix_blocks(&self) -> u64 {
        self.prefix_blocks
    }

    /// Purged prefix blocks whose last copy died with the shard.
    pub fn sole_prefix_blocks(&self) -> u64 {
        self.sole_prefix_blocks
    }

    /// Migration payloads dropped mid-wire by a destination crash —
    /// the crash-loss term of the migration conservation equation.
    pub fn wire_blocks(&self) -> u64 {
        self.wire_blocks
    }

    /// Prefix-replica copies discarded because their destination
    /// crashed while they were on the wire.
    pub fn replica_drop_blocks(&self) -> u64 {
        self.replica_drop_blocks
    }

    pub fn requeued_apps(&self) -> u64 {
        self.requeued_apps
    }

    pub fn requeued_tokens(&self) -> u64 {
        self.requeued_tokens
    }
}

/// An open partition window (unordered shard pair).
#[derive(Debug, Clone, Copy)]
struct OpenWindow {
    a: usize,
    b: usize,
    factor_milli: u64,
    hold_us: u64,
    drop_wire: bool,
}

impl OpenWindow {
    fn covers(&self, x: usize, y: usize) -> bool {
        (self.a == x && self.b == y) || (self.a == y && self.b == x)
    }
}

/// Live fault-injection state the cluster engine carries through a run.
#[derive(Debug, Default)]
pub struct FaultState {
    plan: FaultPlan,
    /// Next unexecuted plan entry.
    next: usize,
    open: Vec<OpenWindow>,
    ledger: CrashLossLedger,
}

impl FaultState {
    pub fn new(plan: FaultPlan) -> Self {
        Self {
            plan,
            next: 0,
            open: Vec::new(),
            ledger: CrashLossLedger::default(),
        }
    }

    /// Simulated time of the next unexecuted fault, if any — the
    /// engine's clock jumps never overshoot it.
    pub fn next_due_us(&self) -> Option<u64> {
        self.plan.events.get(self.next).map(|e| e.at_us)
    }

    pub fn ledger(&self) -> &CrashLossLedger {
        &self.ledger
    }

    /// Wire-cost penalty for the `x`↔`y` link right now:
    /// `(factor_milli, hold_us)` while a partition window is open.
    pub(super) fn wire_penalty(
        &self,
        x: usize,
        y: usize,
    ) -> Option<(u64, u64)> {
        self.open
            .iter()
            .find(|w| w.covers(x, y))
            .map(|w| (w.factor_milli, w.hold_us))
    }

    /// Hard partition: is the `x`↔`y` link dropping bulk transfers?
    pub(super) fn drops_wire(&self, x: usize, y: usize) -> bool {
        self.open
            .iter()
            .any(|w| w.covers(x, y) && w.drop_wire)
    }

    /// A prefix replica died on the wire with its crashed destination.
    pub(super) fn record_replica_loss(&mut self, blocks: u32) {
        self.ledger.note_lost_replica(blocks);
    }

    /// Pop the next plan entry due at or before `now`. The cursor
    /// advances before the entry executes, and the borrow ends before
    /// [`tick`] touches the engine — the plan stays on the engine the
    /// whole time, so a panic mid-execution can neither lose it (the
    /// old take/put-back dance dropped it on unwind) nor replay the
    /// entry on a recovered run.
    fn pop_due(&mut self, now: u64) -> Option<FaultEvent> {
        let ev = *self.plan.events.get(self.next)?;
        if ev.at_us > now {
            return None;
        }
        self.next += 1;
        Some(ev)
    }
}

/// Execute every fault due at `now`. Runs after warm-ups activate and
/// before same-instant arrivals route, so a crash at `t` is fully
/// recovered — router mask updated, apps re-queued — before any
/// arrival at `t` is placed (the trace auditor's embargo rule).
///
/// Borrow-split with `eng.faults`: each due entry is popped through
/// [`FaultState::pop_due`] (a short `&mut` borrow of the state alone),
/// then executed against the full engine — `wire_penalty`,
/// `record_replica_loss`, and the lifecycle predicates all stay live
/// mid-tick because the state is never taken out of the engine.
pub(super) fn tick(eng: &mut ClusterEngine, now: u64) {
    while let Some(ev) =
        eng.faults.as_mut().and_then(|fs| fs.pop_due(now))
    {
        match ev.kind {
            FaultKind::Crash { shard } => crash(eng, shard, now),
            FaultKind::PartitionStart {
                a,
                b,
                factor_milli,
                hold_us,
                drop_wire,
            } => {
                eng.trace.fault(
                    obs::fault::PARTITION,
                    a as u32,
                    b as u32,
                    factor_milli,
                );
                if let Some(fs) = eng.faults.as_mut() {
                    fs.open.push(OpenWindow {
                        a,
                        b,
                        factor_milli,
                        hold_us,
                        drop_wire,
                    });
                }
            }
            FaultKind::PartitionEnd { a, b } => {
                let healed = eng
                    .faults
                    .as_mut()
                    .and_then(|fs| {
                        let i = fs
                            .open
                            .iter()
                            .position(|w| w.covers(a, b))?;
                        fs.open.remove(i);
                        Some(())
                    })
                    .is_some();
                if healed {
                    eng.trace.fault(
                        obs::fault::HEAL,
                        a as u32,
                        b as u32,
                        0,
                    );
                }
            }
        }
    }
}

/// One shard crash: guard, then hand the mechanics to the engine and
/// record what it lost. Skipped (deterministically) when the target is
/// already down, not serving, or the last router-eligible shard —
/// killing the whole fleet would leave arrivals unroutable.
fn crash(eng: &mut ClusterEngine, shard: usize, now: u64) {
    if shard >= eng.shards.len()
        || eng.crashed[shard]
        || !eng.is_steppable(shard)
    {
        return;
    }
    let survivors = (0..eng.shards.len())
        .filter(|&s| s != shard && eng.router.is_eligible(s))
        .count();
    if survivors == 0 {
        return;
    }
    eng.crashed[shard] = true;
    let outcome = eng.crash_shard(shard, now);
    if let Some(fs) = eng.faults.as_mut() {
        fs.ledger.note_lost_crash(&outcome);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> FaultConfig {
        FaultConfig {
            enabled: true,
            ..FaultConfig::default()
        }
    }

    #[test]
    fn explicit_schedule_parses_and_sorts() {
        let mut c = cfg();
        c.crash_schedule = "3@6000;1@2500".to_string();
        let plan = FaultPlan::build(&c, 4, 42);
        assert_eq!(plan.events.len(), 2);
        assert_eq!(plan.events[0].at_us, 2_500_000);
        assert_eq!(
            plan.events[0].kind,
            FaultKind::Crash { shard: 1 }
        );
        assert_eq!(plan.events[1].at_us, 6_000_000);
        assert_eq!(
            plan.events[1].kind,
            FaultKind::Crash { shard: 3 }
        );
    }

    #[test]
    #[should_panic(expected = "names shard 7")]
    fn explicit_schedule_rejects_out_of_range_shard() {
        let mut c = cfg();
        c.crash_schedule = "7@1000".to_string();
        FaultPlan::build(&c, 4, 42);
    }

    #[test]
    fn random_plan_is_seed_deterministic() {
        let mut c = cfg();
        c.crashes = 3;
        c.partitions = 2;
        c.seed = 99;
        let a = FaultPlan::build(&c, 4, 1);
        let b = FaultPlan::build(&c, 4, 2);
        // Explicit fault seed: the workload seed must not matter.
        assert_eq!(a.events, b.events);
        c.seed = 0;
        let d1 = FaultPlan::build(&c, 4, 1);
        let d2 = FaultPlan::build(&c, 4, 1);
        let d3 = FaultPlan::build(&c, 4, 2);
        // Seed 0 derives from the workload seed instead.
        assert_eq!(d1.events, d2.events);
        assert_ne!(d1.events, d3.events);
    }

    #[test]
    fn random_faults_land_inside_the_window() {
        let mut c = cfg();
        c.crashes = 8;
        c.partitions = 4;
        c.window_start_us = 500_000;
        c.window_len_us = 1_000_000;
        let plan = FaultPlan::build(&c, 4, 7);
        for e in &plan.events {
            match e.kind {
                FaultKind::Crash { shard } => {
                    assert!(shard < 4);
                    assert!(
                        (500_000..1_500_000).contains(&e.at_us)
                    );
                }
                FaultKind::PartitionStart { a, b, .. } => {
                    assert_ne!(a, b);
                    assert!(
                        (500_000..1_500_000).contains(&e.at_us)
                    );
                }
                FaultKind::PartitionEnd { .. } => {}
            }
        }
        assert!(plan
            .events
            .windows(2)
            .all(|w| w[0].at_us <= w[1].at_us));
    }

    #[test]
    fn open_windows_price_both_orders_and_heal() {
        let mut fs = FaultState::new(FaultPlan::default());
        fs.open.push(OpenWindow {
            a: 0,
            b: 2,
            factor_milli: 4_000,
            hold_us: 50_000,
            drop_wire: false,
        });
        assert_eq!(fs.wire_penalty(0, 2), Some((4_000, 50_000)));
        assert_eq!(fs.wire_penalty(2, 0), Some((4_000, 50_000)));
        assert_eq!(fs.wire_penalty(0, 1), None);
        assert!(!fs.drops_wire(0, 2));
        fs.open[0].drop_wire = true;
        assert!(fs.drops_wire(2, 0));
        fs.open.clear();
        assert_eq!(fs.wire_penalty(0, 2), None);
    }

    #[test]
    fn ledger_accumulates_losses() {
        let mut fs = FaultState::new(FaultPlan::default());
        fs.ledger.note_lost_crash(&CrashOutcome {
            lost_app_blocks: 10,
            lost_prefix_blocks: 6,
            sole_prefix_blocks: 2,
            lost_wire_blocks: 4,
            requeued_apps: 3,
            requeued_tokens: 900,
        });
        fs.record_replica_loss(5);
        let l = fs.ledger();
        assert_eq!(l.crashes(), 1);
        assert_eq!(l.app_blocks(), 10);
        assert_eq!(l.prefix_blocks(), 6);
        assert_eq!(l.sole_prefix_blocks(), 2);
        assert_eq!(l.wire_blocks(), 4);
        assert_eq!(l.replica_drop_blocks(), 5);
        assert_eq!(l.requeued_apps(), 3);
        assert_eq!(l.requeued_tokens(), 900);
    }
}
