//! Sharded multi-worker serving: the layer above the single-GPU
//! coordinator.
//!
//! The Space/Time Schedulers (§4, §5) solve KV contention *within one
//! worker*; production multi-agent serving needs a fleet. This module
//! adds that fleet while keeping every worker's internals untouched — a
//! shard *is* a [`SimEngine`], pools and schedulers included:
//!
//! ```text
//!                 ┌────────────────────────────────────────┐
//!   apps ───────▶ │ QosGate: per-tier token buckets,       │
//!   (Poisson mix, │ aging queues (no starvation), Batch    │
//!    tiered)      │ load-shedding under overload           │
//!                 └──────────────────┬─────────────────────┘
//!                                    ▼ admitted
//!                 ┌────────────────────────────────────────┐
//!                 │ Router: RoundRobin | LeastLoaded |     │
//!                 │         AgentAffinity (KV-aware,       │
//!                 │         tier-weighted drain bias)      │
//!                 └───────┬──────────┬──────────┬──────────┘
//!                         ▼          ▼          ▼
//!                    ┌────────┐ ┌────────┐ ┌────────┐
//!                    │ shard0 │ │ shard1 │ │ shardN │  SimEngine each:
//!                    │ GPU+CPU│ │ GPU+CPU│ │ GPU+CPU│  spatial+temporal
//!                    │ pools  │ │ pools  │ │ pools  │  schedulers,
//!                    └───┬────┘ └───▲────┘ └────────┘  ledger, prefix $
//!                        │         │
//!                        └─────────┘ cross-worker KV migration of
//!                          stalled agents (pending-free + ledger on the
//!                          source, re-allocation on the destination)
//!
//!                    ┌────────────────────────────────────────┐
//!                    │ PrefixDir: federated prefix residency  │
//!                    │ shard event feeds → warmth credit for  │
//!                    │ routing, remote-pointer seeding, hot-  │
//!                    │ prefix replication (budget-bounded)    │
//!                    └────────────────────────────────────────┘
//!
//!                    ┌────────────────────────────────────────┐
//!                    │ autoscale: elastic fleet sizing —      │
//!                    │ pressure-gated hysteresis controller   │
//!                    │ grows (modeled warm-up) / drains       │
//!                    │ (migration-path evacuation, prefix     │
//!                    │ relocation, conserve-and-retire), with │
//!                    │ KV-lifetime-aware placement bias       │
//!                    └────────────────────────────────────────┘
//!
//!                    ┌────────────────────────────────────────┐
//!                    │ faults: seeded deterministic crash /   │
//!                    │ partition plan — crashed shards lose   │
//!                    │ KV into a crash-loss ledger, apps      │
//!                    │ re-queue through the Router, the       │
//!                    │ prefix directory promotes surviving    │
//!                    │ replicas, autoscale regrows the hole   │
//!                    └────────────────────────────────────────┘
//! ```
//!
//! Everything runs on **one shared event clock** ([`ClusterEngine`] owns
//! it): arrivals, each shard's iteration completions, and migration
//! transfers interleave through a single FIFO-tie-broken event queue, so
//! a cluster run is exactly as reproducible as a single-worker run —
//! same seed and [`ClusterConfig`] ⇒ byte-identical [`ClusterReport`]
//! digests.
//!
//! **Determinism survives parallelism.** With `ClusterConfig::parallel`
//! (CLI `--parallel`) the shard-local phases of each iteration —
//! advancing a shard's local events to `now`, and its scheduling
//! step/iteration kick — execute on scoped threads over disjoint
//! `&mut` shard borrows. Anything a shard wants to tell the rest of
//! the cluster accumulates in per-shard outboxes (orphaned tool
//! finishes, prefix events, lifetime observations, trace records) and
//! drains at a serial barrier in canonical `(time, shard-id, seq)`
//! order, exactly as the serial sweep would have observed it. The
//! router, prefix directory, autoscale controller, fault executor,
//! and QoS gate only ever run at barriers. `--serial` (the default)
//! is the oracle mode: same code path, one thread, shard index order
//! — and the two modes are byte-identical per seed, digests and
//! traces both (`serial_parallel_digest_parity`, CI
//! `--assert-parity`).
//!
//! The headline policy is **agent affinity**: an application is routed to
//! the shard that already serves its agent types (warm shared-prefix
//! cache, trained tool forecaster), falling back to a pressure-aware
//! score from each shard's [`PressureSnapshot`] when the affinity target
//! saturates. When saturation persists, the migration planner moves a
//! bandwidth-capped *batch* of stalled applications per planning window
//! — each one's KV travels while its agent is blocked on a function
//! call anyway, hiding the interconnect hop inside the stall, exactly
//! the §4 insight lifted to cluster scope; a burst of skew drains in
//! one window instead of one victim per window.
//!
//! [`SimEngine`]: crate::engine::sim::SimEngine
//! [`ClusterConfig`]: crate::config::ClusterConfig
//! [`PressureSnapshot`]: crate::coordination::PressureSnapshot

pub mod autoscale;
mod engine;
pub mod faults;
pub mod prefix_dir;
mod router;

pub use autoscale::{AutoscaleStats, LifetimePredictor};
pub use engine::{ClusterEngine, ClusterReport};
pub use faults::{FaultKind, FaultPlan};
pub use prefix_dir::PrefixDir;
pub use router::Router;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, Mode, PlacementPolicy, ServeConfig};
    use crate::graph::templates;
    use crate::workload::{ClusterWorkload, Dataset};

    fn small_cfg(
        shards: usize,
        placement: PlacementPolicy,
        frac: f64,
    ) -> ClusterConfig {
        let serve = ServeConfig::default()
            .with_mode(Mode::TokenCake)
            .with_seed(11)
            .with_gpu_mem_frac(frac);
        ClusterConfig::default()
            .with_serve(serve)
            .with_shards(shards)
            .with_placement(placement)
    }

    fn mixed_workload(qps: f64, apps: usize) -> ClusterWorkload {
        ClusterWorkload::mixed(
            &[
                (templates::code_writer(), 2.0),
                (templates::deep_research(), 1.0),
            ],
            qps,
            apps,
        )
        .with_dataset(Dataset::D1)
    }

    #[test]
    fn single_shard_cluster_completes() {
        let cfg = small_cfg(1, PlacementPolicy::RoundRobin, 1.0);
        let rep = ClusterEngine::new(cfg).run(&mixed_workload(0.5, 4));
        assert!(!rep.truncated);
        assert_eq!(rep.aggregate.apps_completed, 4);
        assert_eq!(rep.shards.len(), 1);
        assert!(rep.aggregate.latency.mean_s() > 0.0);
    }

    #[test]
    fn all_policies_complete_on_four_shards() {
        for placement in [
            PlacementPolicy::RoundRobin,
            PlacementPolicy::LeastLoaded,
            PlacementPolicy::AgentAffinity,
        ] {
            let cfg = small_cfg(4, placement, 0.5);
            let rep =
                ClusterEngine::new(cfg).run(&mixed_workload(1.0, 8));
            assert!(!rep.truncated, "{placement:?} truncated");
            assert_eq!(
                rep.aggregate.apps_completed, 8,
                "{placement:?}"
            );
            // Work landed on more than one shard.
            let active = rep
                .shards
                .iter()
                .filter(|m| m.apps_completed > 0)
                .count();
            assert!(active >= 2, "{placement:?}: all apps on one shard");
        }
    }

    #[test]
    fn round_robin_spreads_apps_evenly() {
        let cfg = small_cfg(4, PlacementPolicy::RoundRobin, 1.0);
        let rep = ClusterEngine::new(cfg).run(&mixed_workload(0.5, 8));
        for m in &rep.shards {
            assert_eq!(m.apps_completed, 2);
        }
    }

    #[test]
    fn digest_is_reproducible_and_policy_tagged() {
        let run = || {
            let cfg = small_cfg(2, PlacementPolicy::AgentAffinity, 0.1);
            ClusterEngine::new(cfg).run(&mixed_workload(1.0, 6))
        };
        let a = run().digest();
        let b = run().digest();
        assert_eq!(a, b, "same seed+config must be byte-identical");
        assert!(a.contains("policy=agent-affinity"));
        assert!(a.contains("shard1"));
    }

    #[test]
    fn block_pools_drain_after_run() {
        let cfg = small_cfg(2, PlacementPolicy::LeastLoaded, 0.05);
        let mut eng = ClusterEngine::new(cfg);
        let rep = eng.run(&mixed_workload(1.0, 6));
        assert!(!rep.truncated);
        for i in 0..2 {
            let st = &eng.shard(i).st;
            // Every block is either free or pinned by the shard's prefix
            // index; nothing leaks to dead requests.
            assert_eq!(
                st.gpu.free_blocks() + st.prefix.resident_gpu_blocks(),
                st.gpu.total(),
                "shard {i}"
            );
            assert_eq!(st.gpu.pending_free_blocks(), 0, "shard {i}");
            assert_eq!(
                st.cpu.used_blocks(),
                st.prefix.resident_cpu_blocks(),
                "shard {i}"
            );
        }
    }
}
