//! Cluster prefix directory: a federation of the per-shard
//! [`PrefixIndex`]es.
//!
//! Each shard's index is honest about its own residency (owned backing,
//! see `kvcache::prefix`), but warm only for the apps its shard served.
//! The directory lifts that knowledge to cluster scope:
//!
//! * **Event feed** — shards publish every index lifecycle mutation
//!   (insert / evict / relocate / remote hit) through
//!   [`ServeState::drain_prefix_events`]; the cluster engine drains the
//!   logs after every shard step and replays them here, so the directory
//!   is an eventually-exact mirror of the per-shard indexes on the
//!   shared clock.
//! * **Routing warmth** — `AgentAffinity` scores a shard's warmth for a
//!   template from the *actual resident prefix blocks* the directory
//!   tracks (GPU full weight, CPU half, remote pointer a quarter), not
//!   just the boolean served-here bit.
//! * **Remote hits** — when an app spills to a cold shard, the engine
//!   seeds *remote pointers* (backing-less entries priced at the
//!   interconnect factor) for every prefix some other shard holds. An
//!   admission hit on a pointer charges the interconnect-scaled H2D debt
//!   through the migration ledger instead of re-prefilling.
//! * **Bounded replication** — once a prefix's remote-hit count crosses
//!   [`crate::config::ClusterConfig::prefix_replicate_threshold`], the
//!   directory copies it into the hitting shard's CPU tier (local price
//!   afterwards), drawing on the same per-window interconnect budget as
//!   the migration batch planner.
//! * **Coherence** — when the last real holder of a prefix evicts it,
//!   every outstanding pointer is invalidated at the next event-feed
//!   sync, so a remote hit is only ever issued against a copy the
//!   directory saw live as of the previous sync (staleness is bounded
//!   by one drain cycle of the shared event loop). A hit that is
//!   already in flight when the source evicts still completes: like a
//!   migration leg, the transfer models data captured on the wire at
//!   issue time, not a live read of the source blocks.
//!
//! [`PrefixIndex`]: crate::kvcache::PrefixIndex

use std::collections::{BTreeMap, BTreeSet, HashMap};

use crate::config::ModelProfile;
use crate::coordination::{PrefixEvent, ServeState};
use crate::graph::{AppGraph, NodeKind};
use crate::kvcache::{PrefixBacking, PrefixKey, PrefixLocation};

/// Residency weights for the warmth credit.
const W_GPU: f64 = 1.0;
const W_CPU: f64 = 0.5;
const W_POINTER: f64 = 0.25;

#[derive(Debug, Default, Clone)]
struct DirEntry {
    /// Shards holding a real local copy (GPU backing or CPU replica).
    holders: BTreeMap<usize, PrefixLocation>,
    /// Shards holding a directory-seeded remote pointer.
    pointers: BTreeSet<usize>,
    /// Shards with a replica copy in flight on the interconnect.
    replicating: BTreeSet<usize>,
    /// Remote-pointer hits since the last replication.
    remote_hits: u32,
    blocks: u32,
    tokens: u32,
}

/// The directory: key → cluster-wide residency, plus the per-template
/// key sets the router and the pointer seeder consult.
#[derive(Debug, Default, Clone)]
pub struct PrefixDir {
    /// Per template: `(key, blocks, tokens)` of every shared agent
    /// prefix, key-sorted (deterministic seeding/replication order).
    template_keys: Vec<Vec<(PrefixKey, u32, u32)>>,
    entries: HashMap<PrefixKey, DirEntry>,
}

impl PrefixDir {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a template's prefix keys (same registration order as the
    /// shards', so template indices agree cluster-wide).
    pub fn register_template(
        &mut self,
        g: &AppGraph,
        profile: &ModelProfile,
    ) -> usize {
        let mut keys: Vec<(PrefixKey, u32, u32)> = Vec::new();
        for node in g.nodes() {
            if let NodeKind::Agent(a) = &node.kind {
                if a.shared_prefix == 0 {
                    continue;
                }
                let key = PrefixKey::of_parts(
                    &g.name,
                    &a.agent_type,
                    a.shared_prefix,
                );
                let blocks = profile.blocks_for_tokens(a.shared_prefix);
                if !keys.iter().any(|(k, _, _)| *k == key) {
                    keys.push((key, blocks, a.shared_prefix));
                }
            }
        }
        keys.sort_by_key(|(k, _, _)| *k);
        self.template_keys.push(keys);
        self.template_keys.len() - 1
    }

    pub fn template_keys(&self, template: usize) -> &[(PrefixKey, u32, u32)] {
        &self.template_keys[template]
    }

    /// Replay one shard-published lifecycle event. Returns the shards
    /// whose remote pointers became dangling (last real holder gone) —
    /// the engine must clear those pointers from the shard indexes.
    pub fn apply_event(
        &mut self,
        shard: usize,
        ev: &PrefixEvent,
    ) -> Vec<usize> {
        match *ev {
            PrefixEvent::Inserted {
                key,
                blocks,
                tokens,
                location,
            } => {
                let e = self.entries.entry(key).or_default();
                e.blocks = blocks;
                e.tokens = tokens;
                e.holders.insert(shard, location);
                e.pointers.remove(&shard);
                Vec::new()
            }
            PrefixEvent::Relocated { key, location } => {
                if let Some(e) = self.entries.get_mut(&key) {
                    e.holders.insert(shard, location);
                }
                Vec::new()
            }
            PrefixEvent::Removed { key } => {
                let Some(e) = self.entries.get_mut(&key) else {
                    return Vec::new();
                };
                e.holders.remove(&shard);
                if e.holders.is_empty() {
                    // Last real copy is gone: every pointer dangles.
                    let orphaned: Vec<usize> =
                        std::mem::take(&mut e.pointers)
                            .into_iter()
                            .collect();
                    e.remote_hits = 0;
                    orphaned
                } else {
                    Vec::new()
                }
            }
            PrefixEvent::RemoteHit { key } => {
                if let Some(e) = self.entries.get_mut(&key) {
                    e.remote_hits += 1;
                }
                Vec::new()
            }
        }
    }

    /// Warm credit of `shard` for `template`, in [0,1]: resident prefix
    /// blocks weighted by tier over the template's total prefix blocks.
    pub fn warmth(&self, template: usize, shard: usize) -> f64 {
        let keys = &self.template_keys[template];
        let total: u32 = keys.iter().map(|(_, b, _)| *b).sum();
        if total == 0 {
            return 0.0;
        }
        let mut score = 0.0;
        for (key, blocks, _) in keys {
            let Some(e) = self.entries.get(key) else { continue };
            match e.holders.get(&shard) {
                Some(PrefixLocation::Gpu) => {
                    score += W_GPU * *blocks as f64
                }
                Some(PrefixLocation::Cpu) => {
                    score += W_CPU * *blocks as f64
                }
                Some(PrefixLocation::Remote) => {}
                None => {
                    if e.pointers.contains(&shard) {
                        score += W_POINTER * *blocks as f64;
                    }
                }
            }
        }
        (score / total as f64).min(1.0)
    }

    pub fn holds_local(&self, key: PrefixKey, shard: usize) -> bool {
        self.entries
            .get(&key)
            .map(|e| e.holders.contains_key(&shard))
            .unwrap_or(false)
    }

    pub fn has_pointer(&self, key: PrefixKey, shard: usize) -> bool {
        self.entries
            .get(&key)
            .map(|e| e.pointers.contains(&shard))
            .unwrap_or(false)
    }

    /// Does any *other* shard hold a real copy a pointer could read?
    pub fn has_holder_other_than(
        &self,
        key: PrefixKey,
        shard: usize,
    ) -> bool {
        self.entries
            .get(&key)
            .map(|e| e.holders.keys().any(|&s| s != shard))
            .unwrap_or(false)
    }

    pub fn remote_hits(&self, key: PrefixKey) -> u32 {
        self.entries.get(&key).map(|e| e.remote_hits).unwrap_or(0)
    }

    pub fn entry_size(&self, key: PrefixKey) -> Option<(u32, u32)> {
        self.entries.get(&key).map(|e| (e.blocks, e.tokens))
    }

    /// Record a directory-seeded pointer on `shard`.
    pub fn note_pointer(&mut self, shard: usize, key: PrefixKey) {
        if let Some(e) = self.entries.get_mut(&key) {
            e.pointers.insert(shard);
        }
    }

    /// Is a replica copy already in flight toward `shard`?
    pub fn is_replicating(&self, shard: usize, key: PrefixKey) -> bool {
        self.entries
            .get(&key)
            .map(|e| e.replicating.contains(&shard))
            .unwrap_or(false)
    }

    pub fn set_replicating(&mut self, shard: usize, key: PrefixKey) {
        if let Some(e) = self.entries.get_mut(&key) {
            e.replicating.insert(shard);
        }
    }

    pub fn clear_replicating(&mut self, shard: usize, key: PrefixKey) {
        if let Some(e) = self.entries.get_mut(&key) {
            e.replicating.remove(&shard);
        }
    }

    /// Record a completed replication: the shard is now a real CPU
    /// holder and its pointer (if any) is upgraded.
    pub fn note_replica(&mut self, shard: usize, key: PrefixKey) {
        if let Some(e) = self.entries.get_mut(&key) {
            e.holders.insert(shard, PrefixLocation::Cpu);
            e.pointers.remove(&shard);
            e.remote_hits = 0;
        }
    }

    /// Invalidate every residency record of a crashed shard at once.
    /// Surviving holders keep serving their keys (replica promotion is
    /// implicit — the directory simply stops naming the dead shard);
    /// keys whose *only* real copy died are reported as sole-copy
    /// losses, and pointers that dangled on survivors are returned so
    /// the engine can clear them from the shard indexes. Keys are
    /// visited in sorted order, so the outcome is deterministic.
    pub fn purge_shard(&mut self, shard: usize) -> PurgeOutcome {
        let mut keys: Vec<PrefixKey> =
            self.entries.keys().copied().collect();
        keys.sort();
        let mut out = PurgeOutcome::default();
        for key in keys {
            let e = self.entries.get_mut(&key).expect("key just listed");
            let held = e.holders.remove(&shard).is_some();
            e.pointers.remove(&shard);
            e.replicating.remove(&shard);
            if !held {
                continue;
            }
            if e.holders.is_empty() {
                out.sole_losses.push((key, e.blocks));
                for s in std::mem::take(&mut e.pointers) {
                    out.orphaned_pointers.push((s, key));
                }
                e.remote_hits = 0;
            } else {
                out.survived.push((key, e.blocks));
            }
        }
        out
    }
}

/// What [`PrefixDir::purge_shard`] found when a shard crashed.
#[derive(Debug, Default, Clone)]
pub struct PurgeOutcome {
    /// Keys whose only real copy died with the shard (`(key, blocks)`).
    pub sole_losses: Vec<(PrefixKey, u32)>,
    /// `(survivor shard, key)` pointers orphaned by a sole-copy loss.
    pub orphaned_pointers: Vec<(usize, PrefixKey)>,
    /// Keys that keep at least one surviving real holder.
    pub survived: Vec<(PrefixKey, u32)>,
}

// ----------------------------------------------------------------------
// Shard-side seeding (the only PrefixIndex::insert sites outside
// `spatial` — CI enforces that lifecycle ownership set)
// ----------------------------------------------------------------------

/// Seed a backing-less remote pointer into a spilled shard's index so
/// admission can hit the prefix at interconnect price. No-op when the
/// shard already has any entry for the key.
pub fn seed_pointer(
    st: &mut ServeState,
    key: PrefixKey,
    blocks: u32,
    tokens: u32,
    interconnect_factor: f64,
    now_us: u64,
) -> bool {
    if st.prefix.location_of(key).is_some() {
        return false;
    }
    st.prefix.insert(
        key,
        blocks,
        tokens,
        PrefixBacking::Remote,
        interconnect_factor.max(1.0),
        now_us,
    );
    st.note_prefix_mutation();
    true
}

/// Replicate a hot remote prefix into this shard's CPU tier: later hits
/// pay the local H2D price instead of the interconnect. The replica
/// displaces the shard's remote pointer. Fails (false) when the mode has
/// no CPU tier, the entry is pinned, or the CPU pool cannot make room
/// even after dropping colder cached prefixes.
pub fn seed_replica(
    st: &mut ServeState,
    key: PrefixKey,
    blocks: u32,
    tokens: u32,
    now_us: u64,
) -> bool {
    if !st.cfg.mode.prefix_cpu_tier() || st.prefix.is_pinned(key) {
        return false;
    }
    // Only a remote pointer (or no entry at all) upgrades: a real local
    // copy that appeared since the remote hit (a finishing request
    // recorded one) is at least as good as the replica would be. The
    // no-entry case is the drain path — evacuating a retiring shard's
    // sole copy emits its `Removed` event (orphaning this shard's
    // pointer) before the replica's wire time elapses.
    match st.prefix.location_of(key) {
        None | Some(PrefixLocation::Remote) => {}
        Some(_) => return false,
    }
    if st.cpu.free_blocks() < blocks
        && !crate::spatial::reclaim_prefix_cpu(st, blocks)
    {
        return false;
    }
    let Some(cpu) = st.cpu.alloc(blocks) else {
        return false;
    };
    match st.prefix.insert(
        key,
        blocks,
        tokens,
        PrefixBacking::Cpu(cpu),
        1.0,
        now_us,
    ) {
        Some(PrefixBacking::Cpu(b)) => st.cpu.release(b),
        Some(PrefixBacking::Gpu(b)) => st.gpu.free(b, 0, None),
        _ => {}
    }
    st.note_prefix_mutation();
    true
}

/// Drop a dangling remote pointer (its last real holder evicted).
pub fn clear_pointer(st: &mut ServeState, key: PrefixKey) -> bool {
    if st.prefix.remove_pointer(key) {
        st.note_prefix_mutation();
        true
    } else {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::templates;

    fn dir_with_template() -> (PrefixDir, usize, Vec<(PrefixKey, u32, u32)>) {
        let mut dir = PrefixDir::new();
        let profile = ModelProfile::qwen14b_a100();
        let t = dir.register_template(&templates::code_writer(), &profile);
        let keys = dir.template_keys(t).to_vec();
        (dir, t, keys)
    }

    #[test]
    fn template_registration_collects_sorted_prefix_keys() {
        let (_, _, keys) = dir_with_template();
        assert!(!keys.is_empty(), "code-writer has shared prefixes");
        for w in keys.windows(2) {
            assert!(w[0].0 < w[1].0, "keys must be sorted and distinct");
        }
        for (_, blocks, tokens) in &keys {
            assert!(*blocks > 0 && *tokens > 0);
        }
    }

    #[test]
    fn events_track_holders_and_orphan_pointers() {
        let (mut dir, t, keys) = dir_with_template();
        let (key, blocks, tokens) = keys[0];
        let ins = PrefixEvent::Inserted {
            key,
            blocks,
            tokens,
            location: PrefixLocation::Gpu,
        };
        assert!(dir.apply_event(0, &ins).is_empty());
        assert!(dir.holds_local(key, 0));
        assert!(dir.has_holder_other_than(key, 1));
        assert!(dir.warmth(t, 0) > 0.0);
        assert_eq!(dir.warmth(t, 1), 0.0);
        // A pointer on shard 1; the GPU holder evicts → pointer orphaned.
        dir.note_pointer(1, key);
        assert!(dir.has_pointer(key, 1));
        let orphaned =
            dir.apply_event(0, &PrefixEvent::Removed { key });
        assert_eq!(orphaned, vec![1]);
        assert!(!dir.holds_local(key, 0));
        assert!(!dir.has_pointer(key, 1));
    }

    #[test]
    fn warmth_orders_gpu_over_cpu_over_pointer() {
        let (mut dir, t, keys) = dir_with_template();
        for &(key, blocks, tokens) in &keys {
            dir.apply_event(
                0,
                &PrefixEvent::Inserted {
                    key,
                    blocks,
                    tokens,
                    location: PrefixLocation::Gpu,
                },
            );
            dir.apply_event(
                1,
                &PrefixEvent::Inserted {
                    key,
                    blocks,
                    tokens,
                    location: PrefixLocation::Cpu,
                },
            );
            dir.note_pointer(2, key);
        }
        let (g, c, p) =
            (dir.warmth(t, 0), dir.warmth(t, 1), dir.warmth(t, 2));
        assert!(g > c && c > p && p > 0.0, "{g} {c} {p}");
        assert_eq!(dir.warmth(t, 3), 0.0);
    }

    #[test]
    fn purge_separates_sole_losses_from_survivors() {
        let (mut dir, _, keys) = dir_with_template();
        let (key, blocks, tokens) = keys[0];
        // Shard 0 is the only holder; shard 1 has a pointer to it.
        dir.apply_event(
            0,
            &PrefixEvent::Inserted {
                key,
                blocks,
                tokens,
                location: PrefixLocation::Gpu,
            },
        );
        dir.note_pointer(1, key);
        let out = dir.purge_shard(0);
        assert_eq!(out.sole_losses, vec![(key, blocks)]);
        assert_eq!(out.orphaned_pointers, vec![(1, key)]);
        assert!(out.survived.is_empty());
        assert!(!dir.holds_local(key, 0));
        assert!(!dir.has_pointer(key, 1));

        // With a surviving CPU replica the key survives the crash.
        dir.apply_event(
            0,
            &PrefixEvent::Inserted {
                key,
                blocks,
                tokens,
                location: PrefixLocation::Gpu,
            },
        );
        dir.apply_event(
            2,
            &PrefixEvent::Inserted {
                key,
                blocks,
                tokens,
                location: PrefixLocation::Cpu,
            },
        );
        let out = dir.purge_shard(0);
        assert!(out.sole_losses.is_empty());
        assert_eq!(out.survived, vec![(key, blocks)]);
        assert!(dir.holds_local(key, 2));
    }

    #[test]
    fn remote_hits_accumulate_and_reset_on_replica() {
        let (mut dir, _, keys) = dir_with_template();
        let (key, blocks, tokens) = keys[0];
        dir.apply_event(
            0,
            &PrefixEvent::Inserted {
                key,
                blocks,
                tokens,
                location: PrefixLocation::Gpu,
            },
        );
        dir.note_pointer(1, key);
        dir.apply_event(1, &PrefixEvent::RemoteHit { key });
        dir.apply_event(1, &PrefixEvent::RemoteHit { key });
        assert_eq!(dir.remote_hits(key), 2);
        dir.note_replica(1, key);
        assert_eq!(dir.remote_hits(key), 0);
        assert!(dir.holds_local(key, 1));
        assert!(!dir.has_pointer(key, 1));
    }
}
