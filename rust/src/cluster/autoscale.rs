//! Elastic replica autoscaling: a deterministic hysteresis controller
//! that grows and drains worker shards from the aggregate pressure
//! signal, with Continuum-style lifetime-aware placement.
//!
//! The fixed fleet the rest of the cluster layer serves is here made
//! elastic. Capacity up to `autoscale.max_shards` is *provisioned* at
//! construction (engines built, id ranges reserved — which is what keeps
//! whole-cluster runs byte-identical); the controller decides how much
//! of it *serves*:
//!
//! ```text
//!        grow                     warm-up elapses on the clock
//!  Cold ───────▶ Warming ──────────────────────────▶ Active
//!                                                      │ drain
//!                  cancel (load returned) ◀────────────▼
//!  Retired ◀─────────────────────────────────────── Draining
//!            pool empty, no live apps, no in-flight
//! ```
//!
//! * **Signal** — per-shard load score (GPU occupancy + waiting demand)
//!   plus the stalled/offloaded KV fraction: cache parked for a function
//!   call *returns as demand* when the tool finishes, so counting it
//!   dampens the flapping a naive occupancy signal would cause (the
//!   fleet is never drained out from under work about to resume). The
//!   controller re-evaluates only when some serving shard's **pressure
//!   epoch** moved (the free list crossed a watermark band — the same
//!   O(1) gate the schedulers use), an arrival landed, or a grow/drain
//!   is mid-flight: at steady state the control plane costs one epoch
//!   comparison per shard.
//! * **Hysteresis** — grow at/above `grow_watermark` immediately (under
//!   a cooldown); drain only after `drain_confirm` consecutive
//!   evaluations at/below `drain_watermark`. A drain is *cancelled* (the
//!   shard simply resumes serving) if pressure returns while it is still
//!   evacuating — the cheapest capacity is the capacity not yet gone.
//! * **Grow** — the lowest-index cold (or previously retired) shard
//!   warms for `warmup_cost_us` of clock time, modeling model load + KV
//!   pool init; the router sends it nothing until the warm-up elapses.
//!   Warm-ups are tracked beside the event queue (not on it), so a
//!   pending warm-up caps the cluster's clock jumps without ever
//!   masking the fully-idle deadlock-rescue path.
//! * **Drain** — the victim is the active shard with the least
//!   committed long-lived KV (stalled blocks weighted by predicted
//!   remaining stall, then raw occupancy; the highest index breaks
//!   ties). The router stops placing onto it, its stalled applications
//!   leave through the *existing* batched cross-worker migration path
//!   under the shared per-window interconnect budget, its running work
//!   finishes in place, and its prefix cache evacuates: entries another
//!   shard also holds are dropped free, sole copies are replicated into
//!   an active shard's CPU tier (same budget) before the local copy is
//!   freed. The shard retires only when its pools are empty and no
//!   transfer touches it — blocks conserved end to end, which
//!   `ClusterEngine::check_conservation` and the drain proptest pin.
//! * **Lifetime-aware placement** — a per-template KV-lifetime
//!   predictor (the template's static tool-call count × an EWMA of its
//!   observed stall durations, fed by `ServeState::note_fc_lifetime` on
//!   every FC finish) biases routing: long-lifetime applications avoid
//!   the *youngest* active shards — exactly the ones the controller
//!   drains first when load falls — so a drain finds mostly short-lived
//!   work in its way. Draining shards are excluded from placement
//!   outright.
//!
//! Shard **retirement is only reachable from this module** (CI greps for
//! `ShardPhase::Retired` / `retire_shard` elsewhere): every path that
//! returns capacity runs the quiescence check here.

use crate::config::AutoscaleConfig;
use crate::coordination::{Action, PrefixEvent, PressureSnapshot};
use crate::graph::{AppGraph, NodeKind};
use crate::kvcache::{Direction, PrefixBacking, Route, TransferKind};
use crate::obs;

use super::engine::ClusterEngine;
use super::router::Router;

/// Additive routing-score penalty at full lifetime × full youth —
/// deliberately smaller than the affinity warmth bonus so KV reuse
/// still dominates placement.
const LIFETIME_BIAS: f64 = 0.15;

/// Weight of the stalled/offloaded resumption demand inside the
/// controller's pressure signal.
const RESUME_DEMAND_WEIGHT: f64 = 0.5;

/// Where a provisioned shard is in its serving lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ShardPhase {
    /// Provisioned but never (or not currently) part of the fleet.
    Cold,
    /// Spinning up; joins the fleet when the warm-up event lands.
    Warming,
    Active,
    /// Excluded from placement; evacuating apps and prefix entries.
    Draining,
    /// Quiesced and returned — may be re-grown later.
    Retired,
}

/// Per-template KV-lifetime predictor (Continuum): how long will an
/// application of this template keep KV alive across function-call
/// stalls? Static profile (the graph's tool-call count) × an EWMA of
/// observed stall durations for the template.
#[derive(Debug, Clone, Default)]
pub struct LifetimePredictor {
    static_calls: Vec<u32>,
    ewma_stall_us: Vec<f64>,
    seeded: Vec<bool>,
    ewma: f64,
    default_stall_us: f64,
}

impl LifetimePredictor {
    pub fn new(ewma: f64, default_stall_us: u64) -> Self {
        Self {
            static_calls: Vec::new(),
            ewma_stall_us: Vec::new(),
            seeded: Vec::new(),
            ewma,
            default_stall_us: default_stall_us as f64,
        }
    }

    /// Register a template (same order as the shards register graphs,
    /// so template indices agree). Counts the tool-call profile: agent
    /// phases that end in a call plus standalone func nodes.
    pub fn register_template(&mut self, g: &AppGraph) -> usize {
        let mut calls = 0u32;
        for node in g.nodes() {
            match &node.kind {
                NodeKind::Agent(a) => {
                    calls += a
                        .phases
                        .iter()
                        .filter(|p| p.call.is_some())
                        .count() as u32;
                }
                NodeKind::Func(_) => calls += 1,
            }
        }
        self.static_calls.push(calls);
        self.ewma_stall_us.push(self.default_stall_us);
        self.seeded.push(false);
        self.static_calls.len() - 1
    }

    /// Fold one observed FC stall duration into the template's EWMA.
    pub fn observe(&mut self, template: usize, stall_us: u64) {
        let Some(v) = self.ewma_stall_us.get_mut(template) else {
            return;
        };
        if self.seeded[template] {
            *v = (1.0 - self.ewma) * *v + self.ewma * stall_us as f64;
        } else {
            *v = stall_us as f64;
            self.seeded[template] = true;
        }
    }

    /// Predicted KV lifetime of one application of `template` (µs):
    /// its call count × the per-call stall estimate.
    pub fn predicted_lifetime_us(&self, template: usize) -> f64 {
        let calls =
            self.static_calls.get(template).copied().unwrap_or(0);
        let stall = self
            .ewma_stall_us
            .get(template)
            .copied()
            .unwrap_or(self.default_stall_us);
        calls as f64 * stall
    }

    /// Lifetime normalized against the longest-lived registered
    /// template, in [0,1].
    pub fn lifetime_norm(&self, template: usize) -> f64 {
        let max = (0..self.static_calls.len())
            .map(|t| self.predicted_lifetime_us(t))
            .fold(0.0f64, f64::max);
        if max <= 0.0 {
            return 0.0;
        }
        (self.predicted_lifetime_us(template) / max).clamp(0.0, 1.0)
    }

    pub fn observations_seeded(&self, template: usize) -> bool {
        self.seeded.get(template).copied().unwrap_or(false)
    }
}

/// Controller statistics — surfaced on [`super::ClusterReport`] and in
/// every digest (scale decisions are scheduler decisions: reruns must
/// agree byte-for-byte).
#[derive(Debug, Clone, Default)]
pub struct AutoscaleStats {
    pub scale_up_events: u64,
    pub scale_down_events: u64,
    pub drain_cancels: u64,
    pub shards_retired: u64,
    /// KV blocks migrated off draining shards.
    pub drained_app_blocks: u64,
    /// Sole-copy prefix blocks replicated off draining shards.
    pub drained_prefix_blocks: u64,
    /// Prefix blocks dropped in a drain (no CPU tier / no directory).
    pub drained_prefix_dropped_blocks: u64,
    /// Activation→retirement lifetime of each retired shard (µs), in
    /// retirement order — the shard-lifetime histogram.
    pub shard_lifetimes_us: Vec<u64>,
    /// Controller evaluations run vs. skipped by the pressure-epoch
    /// gate (the control plane's steady-state cost headline).
    pub evals: u64,
    pub eval_skips: u64,
}

/// The autoscale control plane one [`ClusterEngine`] owns.
pub(super) struct Autoscaler {
    cfg: AutoscaleConfig,
    phase: Vec<ShardPhase>,
    activated_at_us: Vec<u64>,
    /// First time the shard ever activated (None = never) — the start
    /// of its provisioned span for utilization weighting.
    first_activated_at_us: Vec<Option<u64>>,
    retired_at_us: Vec<Option<u64>>,
    ever_active: Vec<bool>,
    /// Pressure-epoch watermarks: the controller re-evaluates only when
    /// some serving shard's pressure epoch moved past these.
    consumed_pressure: Vec<u64>,
    saw_arrival: bool,
    last_eval_us: u64,
    evaluated_once: bool,
    cooldown_until_us: u64,
    /// Consecutive below-drain-watermark evaluations (hysteresis).
    below_count: u32,
    next_drain_window_us: u64,
    predictor: LifetimePredictor,
    stats: AutoscaleStats,
}

impl Autoscaler {
    pub(super) fn new(
        cfg: AutoscaleConfig,
        total: usize,
        initial: usize,
    ) -> Self {
        assert!(initial >= 1 && initial <= total);
        let phase: Vec<ShardPhase> = (0..total)
            .map(|i| {
                if i < initial {
                    ShardPhase::Active
                } else {
                    ShardPhase::Cold
                }
            })
            .collect();
        let predictor = LifetimePredictor::new(
            cfg.lifetime_ewma,
            // Seed the per-call stall estimate with the forecaster's
            // conservative system default.
            2_000_000,
        );
        Self {
            phase,
            activated_at_us: vec![0; total],
            first_activated_at_us: (0..total)
                .map(|i| if i < initial { Some(0) } else { None })
                .collect(),
            retired_at_us: vec![None; total],
            ever_active: (0..total).map(|i| i < initial).collect(),
            consumed_pressure: vec![0; total],
            saw_arrival: false,
            last_eval_us: 0,
            evaluated_once: false,
            cooldown_until_us: 0,
            below_count: 0,
            next_drain_window_us: 0,
            predictor,
            stats: AutoscaleStats::default(),
            cfg,
        }
    }

    pub(super) fn register_template(&mut self, g: &AppGraph) {
        self.predictor.register_template(g);
    }

    pub(super) fn is_placeable(&self, i: usize) -> bool {
        self.phase[i] == ShardPhase::Active
    }

    pub(super) fn is_steppable(&self, i: usize) -> bool {
        matches!(
            self.phase[i],
            ShardPhase::Active | ShardPhase::Draining
        )
    }

    pub(super) fn is_runnable(&self, i: usize) -> bool {
        matches!(
            self.phase[i],
            ShardPhase::Active | ShardPhase::Draining | ShardPhase::Warming
        )
    }

    pub(super) fn ever_active(&self, i: usize) -> bool {
        self.ever_active[i]
    }

    pub(super) fn retired_at(&self, i: usize) -> Option<u64> {
        self.retired_at_us[i]
    }

    /// Shards currently serving (active or draining).
    pub(super) fn serving_count(&self) -> usize {
        self.phase
            .iter()
            .filter(|p| {
                matches!(p, ShardPhase::Active | ShardPhase::Draining)
            })
            .count()
    }

    /// Shards that count against `max_shards` (serving or warming).
    fn provisioned_count(&self) -> usize {
        self.phase
            .iter()
            .filter(|p| {
                matches!(
                    p,
                    ShardPhase::Active
                        | ShardPhase::Draining
                        | ShardPhase::Warming
                )
            })
            .count()
    }

    pub(super) fn phase_name(&self, i: usize) -> &'static str {
        match self.phase[i] {
            ShardPhase::Cold => "cold",
            ShardPhase::Warming => "warming",
            ShardPhase::Active => "active",
            ShardPhase::Draining => "draining",
            ShardPhase::Retired => "retired",
        }
    }

    pub(super) fn stats(&self) -> &AutoscaleStats {
        &self.stats
    }

    /// An arrival is a demand signal the pressure bands may not have
    /// caught yet; wake the next evaluation.
    pub(super) fn note_arrival(&mut self) {
        self.saw_arrival = true;
    }

    /// A grown shard's warm-up elapsed. Returns whether it joined (a
    /// drain-cancelled shard may have re-activated meanwhile).
    pub(super) fn on_warm(&mut self, i: usize, now: u64) -> bool {
        if self.phase[i] != ShardPhase::Warming {
            return false;
        }
        self.phase[i] = ShardPhase::Active;
        self.activated_at_us[i] = now;
        if self.first_activated_at_us[i].is_none() {
            self.first_activated_at_us[i] = Some(now);
        }
        self.ever_active[i] = true;
        true
    }

    /// Clock time shard `i` was provisioned by `end_us`: first
    /// activation → retirement (or the run end). Zero for never-grown
    /// capacity. (A retire→regrow gap is counted — the approximation
    /// errs toward under-reporting elastic utilization, never
    /// inflating it.)
    pub(super) fn provisioned_us(&self, i: usize, end_us: u64) -> u64 {
        let Some(start) = self.first_activated_at_us[i] else {
            return 0;
        };
        let end = match self.phase[i] {
            ShardPhase::Retired => {
                self.retired_at_us[i].unwrap_or(end_us)
            }
            _ => end_us,
        };
        end.saturating_sub(start)
    }

    /// A drain-evacuation replica was discarded at landing with no
    /// surviving copy anywhere: those blocks were dropped, not
    /// relocated — move them between the two counters.
    pub(super) fn note_evacuation_dropped(&mut self, blocks: u32) {
        self.stats.drained_prefix_blocks = self
            .stats
            .drained_prefix_blocks
            .saturating_sub(blocks as u64);
        self.stats.drained_prefix_dropped_blocks += blocks as u64;
    }

    /// A shard crashed out from under the controller (see
    /// `super::faults`). The capacity hole is *un-drained*: no
    /// quiescence check applies — the lost blocks are the crash-loss
    /// ledger's to account — so the shard returns to `Cold`, and the
    /// normal grow path can regrow it through warm-up. Deliberately
    /// not `Retired`: retirement asserts conservation, a crash asserts
    /// loss. The anti-flap cooldown is cleared (a crash is not a
    /// controller decision) and the next evaluation is woken so the
    /// concentrated survivor load is seen immediately.
    pub(super) fn note_crash(&mut self, i: usize, now: u64) {
        if self.phase[i] == ShardPhase::Cold {
            return;
        }
        self.phase[i] = ShardPhase::Cold;
        self.retired_at_us[i] = None;
        self.saw_arrival = true;
        self.cooldown_until_us = now;
    }

    /// Lifetime-aware placement bias for one arriving application:
    /// penalize young active shards (the next drain victims) in
    /// proportion to the app's predicted KV lifetime. All-zero when the
    /// template is short-lived or ages don't differ.
    pub(super) fn route_bias(
        &self,
        template: usize,
        now: u64,
    ) -> Vec<f64> {
        let n = self.phase.len();
        let mut bias = vec![0.0; n];
        let l = self.predictor.lifetime_norm(template);
        if l <= 0.0 {
            return bias;
        }
        let ages: Vec<u64> = (0..n)
            .map(|i| {
                if self.is_placeable(i) {
                    now.saturating_sub(self.activated_at_us[i])
                } else {
                    0
                }
            })
            .collect();
        let max_age = ages.iter().copied().max().unwrap_or(0);
        if max_age == 0 {
            return bias;
        }
        for i in 0..n {
            if self.is_placeable(i) {
                let youth = 1.0 - ages[i] as f64 / max_age as f64;
                bias[i] = LIFETIME_BIAS * l * youth;
            }
        }
        bias
    }
}

/// One shard's contribution to the controller's pressure signal: the
/// router's load score plus the stalled/offloaded KV fraction — parked
/// cache that resumes as demand when its tool returns (predicted
/// near-term demand, the anti-flap term).
pub fn shard_signal(snap: &PressureSnapshot) -> f64 {
    let total = snap.gpu_total.max(1) as f64;
    let resume = (snap.offloadable_stalled + snap.offloaded_blocks)
        as f64
        / total;
    Router::load_score(snap) + RESUME_DEMAND_WEIGHT * resume
}

/// The control-plane entry the engine calls once per loop iteration.
pub(super) fn tick(a: &mut Autoscaler, eng: &mut ClusterEngine, now: u64) {
    tick_inner(a, eng, now, false);
}

/// Test hook: one control step with the interval, cooldown, and
/// confirmation gates bypassed.
pub(super) fn step_forced(
    a: &mut Autoscaler,
    eng: &mut ClusterEngine,
    now: u64,
) {
    a.next_drain_window_us = 0;
    a.cooldown_until_us = 0;
    a.last_eval_us = 0;
    a.evaluated_once = false;
    a.below_count = a.cfg.drain_confirm;
    tick_inner(a, eng, now, true);
}

fn tick_inner(
    a: &mut Autoscaler,
    eng: &mut ClusterEngine,
    now: u64,
    force: bool,
) {
    // Fold the shards' published FC-stall observations into the
    // lifetime predictor every tick, ahead of the evaluation gate:
    // taking an empty Vec is free, and observations must neither pool
    // unboundedly through a gated quiet stretch nor reach the
    // predictor stale.
    for i in 0..eng.shards.len() {
        for (template, stall_us) in
            eng.shards[i].st.drain_lifetime_obs()
        {
            a.predictor.observe(template, stall_us);
        }
    }
    let any_draining =
        a.phase.iter().any(|p| *p == ShardPhase::Draining);
    if any_draining {
        drain_windows(a, eng, now);
    }
    maybe_evaluate(a, eng, now, force);
}

/// Controller evaluation, behind the pressure-epoch gate and the
/// evaluation interval: at steady state (no band crossing, no arrival,
/// nothing warming or draining) this is a handful of integer compares.
fn maybe_evaluate(
    a: &mut Autoscaler,
    eng: &mut ClusterEngine,
    now: u64,
    force: bool,
) {
    if !force
        && a.evaluated_once
        && now < a.last_eval_us + a.cfg.interval_us
    {
        return;
    }
    let mut moved = a.saw_arrival || !a.evaluated_once;
    for i in 0..eng.shards.len() {
        if !a.is_steppable(i) {
            continue;
        }
        if eng.shards[i].st.epochs.pressure != a.consumed_pressure[i] {
            moved = true;
        }
    }
    let busy_phase = a.phase.iter().any(|p| {
        matches!(p, ShardPhase::Warming | ShardPhase::Draining)
    });
    if !moved && !busy_phase && !force {
        a.stats.eval_skips += 1;
        return;
    }
    a.stats.evals += 1;
    a.last_eval_us = now;
    a.evaluated_once = true;
    a.saw_arrival = false;
    for i in 0..eng.shards.len() {
        a.consumed_pressure[i] = eng.shards[i].st.epochs.pressure;
    }

    let signal = aggregate_signal(a, eng);
    if signal >= a.cfg.grow_watermark {
        a.below_count = 0;
        grow_or_cancel_drain(a, eng, now, force);
    } else if signal <= a.cfg.drain_watermark {
        a.below_count = a.below_count.saturating_add(1);
        if a.below_count >= a.cfg.drain_confirm {
            maybe_drain(a, eng, now, force);
        }
    } else {
        a.below_count = 0;
    }
}

/// Mean pressure signal over the capacity that will remain: draining
/// shards' load still counts (it must land somewhere) but their
/// capacity does not — so a drain that concentrates load too much
/// reads as pressure and gets cancelled.
fn aggregate_signal(a: &Autoscaler, eng: &ClusterEngine) -> f64 {
    let mut sum = 0.0;
    let mut active = 0usize;
    for i in 0..eng.shards.len() {
        match a.phase[i] {
            ShardPhase::Active => active += 1,
            ShardPhase::Draining => {}
            _ => continue,
        }
        sum += shard_signal(&eng.shards[i].st.snapshot());
    }
    if active == 0 {
        return f64::INFINITY;
    }
    sum / active as f64
}

fn grow_or_cancel_drain(
    a: &mut Autoscaler,
    eng: &mut ClusterEngine,
    now: u64,
    force: bool,
) {
    // Cancel an in-progress drain first: the cheapest capacity is the
    // capacity not yet gone — the shard just resumes serving (whatever
    // already migrated away stays away).
    if let Some(i) =
        a.phase.iter().position(|p| *p == ShardPhase::Draining)
    {
        a.phase[i] = ShardPhase::Active;
        eng.router.set_eligible(i, true);
        a.stats.drain_cancels += 1;
        a.cooldown_until_us = now + a.cfg.cooldown_us;
        eng.trace.autoscale(
            obs::scale::CANCEL,
            i as u32,
            a.serving_count() as u32,
        );
        return;
    }
    if !force && now < a.cooldown_until_us {
        return;
    }
    if a.provisioned_count() >= a.cfg.max_shards {
        return;
    }
    let Some(i) = (0..a.phase.len()).find(|&i| {
        matches!(a.phase[i], ShardPhase::Cold | ShardPhase::Retired)
    }) else {
        return;
    };
    a.phase[i] = ShardPhase::Warming;
    a.retired_at_us[i] = None;
    // Tracked outside the event queue: a pending warm-up caps the
    // cluster's clock jumps but never masks the idle-rescue path.
    eng.pending_warm.push((now + a.cfg.warmup_cost_us, i));
    a.stats.scale_up_events += 1;
    a.cooldown_until_us = now + a.cfg.cooldown_us;
    eng.trace.autoscale(
        obs::scale::GROW,
        i as u32,
        a.serving_count() as u32,
    );
}

fn maybe_drain(
    a: &mut Autoscaler,
    eng: &mut ClusterEngine,
    now: u64,
    force: bool,
) {
    if !force && now < a.cooldown_until_us {
        return;
    }
    let active: Vec<usize> = (0..a.phase.len())
        .filter(|&i| a.phase[i] == ShardPhase::Active)
        .collect();
    // Capacity after every in-progress drain completes must still meet
    // the floor.
    if active.len() <= a.cfg.min_shards {
        return;
    }
    // Victim: least committed long-lived KV first (stalled blocks ×
    // predicted remaining stall, in block·ms), then least raw
    // occupancy; the highest index breaks exact ties (newest capacity
    // drains first, matching the router's youth bias).
    let victim = active
        .iter()
        .copied()
        .min_by_key(|&i| {
            let st = &eng.shards[i].st;
            let mut committed: u64 = 0;
            for rid in &st.stalled_ids {
                let r = &st.reqs[rid];
                let rem_ms = r
                    .fc
                    .as_ref()
                    .map(|f| f.predicted_end_us.saturating_sub(now))
                    .unwrap_or(0)
                    / 1000;
                committed +=
                    r.blocks.len() as u64 * rem_ms.max(1);
            }
            let used =
                st.gpu.total() - st.gpu.free_blocks();
            (committed, used, std::cmp::Reverse(i))
        })
        .expect("active set checked non-empty");
    a.phase[victim] = ShardPhase::Draining;
    eng.router.set_eligible(victim, false);
    a.stats.scale_down_events += 1;
    a.below_count = 0;
    a.cooldown_until_us = now + a.cfg.cooldown_us;
    eng.trace.autoscale(
        obs::scale::DRAIN,
        victim as u32,
        a.serving_count() as u32,
    );
    // Evacuate immediately — don't wait for the next window.
    a.next_drain_window_us = 0;
    drain_windows(a, eng, now);
}

/// One evacuation window per rebalance interval for every draining
/// shard, plus the retirement check.
fn drain_windows(a: &mut Autoscaler, eng: &mut ClusterEngine, now: u64) {
    if now >= a.next_drain_window_us {
        a.next_drain_window_us =
            now + eng.cfg.rebalance_interval_us;
        for src in 0..eng.shards.len() {
            if a.phase[src] == ShardPhase::Draining {
                drain_one_window(a, eng, src, now);
                // Sync after EVERY shard's window, not once at the
                // end: with two shards draining, the second must see
                // the first's drops applied, or each could treat the
                // other as a surviving holder and the cluster's last
                // copy would be dropped instead of relocated.
                eng.sync_prefix_dir();
            }
        }
    }
    for i in 0..eng.shards.len() {
        if a.phase[i] == ShardPhase::Draining {
            try_retire(a, eng, i, now);
        }
    }
}

/// One bandwidth-capped evacuation window for a draining shard: stalled
/// applications leave through the existing cross-worker migration path,
/// then the prefix cache evacuates — all under the shared per-window
/// interconnect budget.
fn drain_one_window(
    a: &mut Autoscaler,
    eng: &mut ClusterEngine,
    src: usize,
    now: u64,
) {
    let n = eng.shards.len();
    let usages: Vec<f64> =
        eng.shards.iter().map(|s| s.st.gpu.usage()).collect();
    // Destination room, tracked logically across the batch exactly as
    // the load-balancing planner does.
    let mut room: Vec<u32> = (0..n)
        .map(|i| {
            if i != src && a.is_placeable(i) {
                eng.shards[i].st.gpu.available_for(Route::Shared)
            } else {
                0
            }
        })
        .collect();
    let mut victims = 0u64;
    let mut window_blocks = 0u64;
    for (app_id, rid, blocks, _predicted_end) in eng.pick_candidates(src)
    {
        // Least-loaded active destination with room (id breaks ties).
        let Some(dst) = (0..n)
            .filter(|&d| room[d] >= blocks && blocks > 0)
            .min_by(|&x, &y| {
                usages[x].total_cmp(&usages[y]).then(x.cmp(&y))
            })
        else {
            continue;
        };
        // Unlike the load balancer there is no payback test — the KV
        // must leave regardless — but the wire is still budgeted.
        // Partial-batch fallback (as in `plan_migration`): an
        // over-budget candidate is skipped, smaller later ones may
        // still pack into the window's remainder.
        if !eng.ic_window_take(blocks, now) {
            continue;
        }
        let cost_us = eng.wire_cost_us(blocks);
        eng.start_migration(src, dst, app_id, rid, blocks, cost_us, now);
        room[dst] -= blocks;
        a.stats.drained_app_blocks += blocks as u64;
        victims += 1;
        window_blocks += blocks as u64;
    }
    if victims > 0 {
        eng.migration_batches += 1;
        eng.max_window_migration_blocks =
            eng.max_window_migration_blocks.max(window_blocks);
    }

    // Prefix evacuation. Entries another shard also holds are dropped
    // free (a pure discard — nothing travels); a sole copy is
    // replicated into an active shard's CPU tier (interconnect-priced,
    // same window budget) — TokenDance-style collective sharing is
    // what makes a drain affordable. Pinned entries (in-flight reads)
    // wait for the next window; an exhausted window budget defers only
    // the relocations, never the free drops.
    let mut entries = eng.shards[src].st.prefix.local_entries();
    if eng.shards[src].st.qos.enabled {
        // Tier-ordered evacuation: Interactive sole copies relocate
        // first, so a window budget that runs dry defers Batch-tier
        // entries — never the latency-critical ones. Stable sort keeps
        // the key order within a tier, preserving determinism.
        let prefix = &eng.shards[src].st.prefix;
        entries.sort_by_key(|&(key, ..)| prefix.tier_of(key));
    }
    let mut budget_dry = false;
    for (key, _loc, blocks, tokens, pinned) in entries {
        if pinned {
            continue;
        }
        if !eng.prefix_enabled {
            // No directory: the cache is shard-local; dropping costs
            // only future recompute. Blocks go straight back.
            drop_local_prefix(eng, src, key);
            a.stats.drained_prefix_dropped_blocks += blocks as u64;
            continue;
        }
        if eng.prefix_dir.has_holder_other_than(key, src) {
            // Another real copy exists cluster-wide — nothing to save.
            drop_local_prefix(eng, src, key);
            continue;
        }
        // Sole copy: relocate it if a CPU tier exists somewhere active.
        let dst = (0..n)
            .filter(|&d| d != src && a.is_placeable(d))
            .min_by(|&x, &y| {
                usages[x].total_cmp(&usages[y]).then(x.cmp(&y))
            });
        let can_replicate = eng.cfg.serve.mode.prefix_cpu_tier();
        match dst {
            Some(dst) if can_replicate => {
                if budget_dry || eng.prefix_dir.is_replicating(dst, key)
                {
                    continue; // retry next window
                }
                // Pre-checked not-replicating, so a refusal here is
                // the window budget running dry.
                if !eng.issue_replica(dst, key, blocks, tokens, true, now)
                {
                    budget_dry = true;
                    continue;
                }
                let cost_us = eng.wire_cost_us(blocks);
                evacuate_local_prefix(eng, src, key, now, cost_us);
                a.stats.drained_prefix_blocks += blocks as u64;
            }
            _ => {
                drop_local_prefix(eng, src, key);
                a.stats.drained_prefix_dropped_blocks +=
                    blocks as u64;
            }
        }
    }
}

/// Free one prefix entry's local backing on a draining shard and
/// publish the removal (the directory invalidates dangling pointers on
/// the next sync). A *discard*: nothing travels, so the blocks return
/// immediately — exactly like `spatial::drop_prefix_gpu_lru`.
fn drop_local_prefix(
    eng: &mut ClusterEngine,
    shard: usize,
    key: crate::kvcache::PrefixKey,
) {
    let st = &mut eng.shards[shard].st;
    match st.prefix.remove(key) {
        Some(PrefixBacking::Gpu(b)) => st.gpu.free(b, 0, None),
        Some(PrefixBacking::Cpu(b)) => st.cpu.release(b),
        Some(PrefixBacking::Remote) | None => {}
    }
    st.metrics.counters.prefix_evictions += 1;
    st.push_prefix_event(PrefixEvent::Removed { key });
}

/// Release an entry's backing *behind its relocation transfer*: GPU
/// blocks ride the pending-free + migration-ledger D2H path for the
/// wire duration, exactly like prefix demotion and app migration — the
/// capacity is not reusable while the copy is on the interconnect (and
/// `try_retire` waits on the pending-free drain). CPU backing is
/// wire-captured at issue, matching how remote-hit reads treat a
/// source that evicts mid-flight (the CPU pool models no transfer
/// engine of its own).
fn evacuate_local_prefix(
    eng: &mut ClusterEngine,
    shard: usize,
    key: crate::kvcache::PrefixKey,
    now: u64,
    cost_us: u64,
) {
    let st = &mut eng.shards[shard].st;
    match st.prefix.remove(key) {
        Some(PrefixBacking::Gpu(b)) => {
            st.gpu.mark_pending_free(&b, 0, None);
            let nb = b.len() as u32;
            let completes = now + cost_us;
            let xfer = st.ledger.issue_tagged(
                TransferKind::PrefixEvict { key },
                u64::MAX,
                Direction::D2H,
                b,
                Vec::new(),
                now,
                completes,
            );
            st.outbox.push(Action::TransferIssued {
                xfer,
                completes_us: completes,
            });
            st.trace.transfer_start(
                xfer.0,
                u64::MAX,
                obs::xfer::PREFIX_EVICT,
                true,
                nb,
                cost_us,
            );
        }
        Some(PrefixBacking::Cpu(b)) => st.cpu.release(b),
        Some(PrefixBacking::Remote) | None => {}
    }
    st.metrics.counters.prefix_evictions += 1;
    st.push_prefix_event(PrefixEvent::Removed { key });
}

/// Quiescence check and the single retirement site in the codebase.
fn try_retire(
    a: &mut Autoscaler,
    eng: &mut ClusterEngine,
    i: usize,
    now: u64,
) {
    debug_assert_eq!(a.phase[i], ShardPhase::Draining);
    if eng.inflight_touches(i) {
        return;
    }
    if eng.shards[i].next_local_event_us().is_some() {
        return; // pending tool finishes / func delays / transfers
    }
    let st = &eng.shards[i].st;
    let quiescent = st.reqs.live_len() == 0
        && st.waiting.is_empty()
        && st.gpu.free_blocks() == st.gpu.total()
        && st.gpu.pending_free_blocks() == 0
        && st.cpu.used_blocks() == 0
        && st.prefix.resident_gpu_blocks() == 0
        && st.prefix.resident_cpu_blocks() == 0;
    if !quiescent {
        return;
    }
    retire_shard(a, i, now);
    eng.trace.autoscale(
        obs::scale::RETIRE,
        i as u32,
        a.serving_count() as u32,
    );
}

/// The only constructor of [`ShardPhase::Retired`] (CI-enforced): the
/// shard's pools are empty, nothing references it, its capacity
/// returns, and its lifetime enters the histogram.
fn retire_shard(a: &mut Autoscaler, i: usize, now: u64) {
    a.phase[i] = ShardPhase::Retired;
    a.retired_at_us[i] = Some(now);
    a.stats.shards_retired += 1;
    a.stats
        .shard_lifetimes_us
        .push(now.saturating_sub(a.activated_at_us[i]));
}

/// Test/ops hook behind [`ClusterEngine::request_drain`]: start a drain
/// directly (min-shards floor still enforced; watermark, confirmation,
/// and cooldown gates bypassed).
pub(super) fn force_drain(
    a: &mut Autoscaler,
    eng: &mut ClusterEngine,
    i: usize,
) -> bool {
    if a.phase[i] != ShardPhase::Active {
        return false;
    }
    let active = a
        .phase
        .iter()
        .filter(|p| **p == ShardPhase::Active)
        .count();
    if active <= a.cfg.min_shards {
        return false;
    }
    a.phase[i] = ShardPhase::Draining;
    eng.router.set_eligible(i, false);
    a.stats.scale_down_events += 1;
    a.next_drain_window_us = 0;
    eng.trace.autoscale(
        obs::scale::DRAIN,
        i as u32,
        a.serving_count() as u32,
    );
    true
}

#[cfg(test)]
mod tests {
    use super::super::prefix_dir;
    use super::*;
    use crate::config::{
        ClusterConfig, Mode, PlacementPolicy, ServeConfig,
    };
    use crate::coordination::ReqState;
    use crate::graph::templates;
    use crate::kvcache::{AllocOutcome, PrefixKey, PrefixLocation};
    use crate::temporal;
    use crate::workload::{SampledLengths, ToolSim};

    fn autoscale_cfg(
        initial: usize,
        min: usize,
        max: usize,
    ) -> ClusterConfig {
        let serve = ServeConfig::default()
            .with_mode(Mode::TokenCake)
            .with_seed(1)
            .with_gpu_mem_frac(0.05);
        let mut c = ClusterConfig::default()
            .with_serve(serve)
            .with_shards(initial)
            .with_placement(PlacementPolicy::RoundRobin);
        c.autoscale.enabled = true;
        c.autoscale.min_shards = min;
        c.autoscale.max_shards = max;
        c.autoscale.warmup_cost_us = 100_000;
        c.autoscale.cooldown_us = 0;
        c.autoscale.drain_confirm = 1;
        c
    }

    /// Build an engine whose shards all registered the code-writer
    /// template (the cluster contract: identical registration order).
    fn engine(initial: usize, min: usize, max: usize) -> ClusterEngine {
        let mut eng = ClusterEngine::new(autoscale_cfg(initial, min, max));
        let g = templates::code_writer();
        for i in 0..max {
            eng.shard_mut(i).register_template(&g);
        }
        eng
    }

    /// Park one migratable stalled app on `shard` holding `blocks` GPU
    /// blocks (60 s predicted stall).
    fn stalled_app_on(eng: &mut ClusterEngine, shard: usize, blocks: u32) {
        let tool_sim = ToolSim::new(0.0);
        let scales = SampledLengths {
            prompt_scale: 1.0,
            gen_scale: 1.0,
        };
        let app = eng.shard_mut(shard).inject_app(0, scales, &tool_sim);
        let st = &mut eng.shard_mut(shard).st;
        let rid = st.apps[&app].node_req[0].unwrap();
        st.waiting.retain(|&x| x != rid);
        let AllocOutcome::Granted { blocks: b, .. } =
            st.gpu.alloc(blocks, Route::Shared)
        else {
            panic!()
        };
        {
            let r = st.reqs.get_mut(&rid).unwrap();
            r.blocks = b;
            r.state = ReqState::Running;
        }
        temporal::call_start(
            st,
            rid,
            "web_search",
            Some(60_000_000),
            480,
            0,
        );
        assert_eq!(st.reqs[&rid].state, ReqState::Stalled);
    }

    /// The acceptance drain: every stalled app migrates off through the
    /// batched path, the pool empties, the shard retires — and not one
    /// block is lost anywhere.
    #[test]
    fn full_drain_evacuates_apps_and_retires_with_zero_loss() {
        let mut eng = engine(2, 1, 2);
        for _ in 0..3 {
            stalled_app_on(&mut eng, 1, 10);
        }
        let total1 = eng.shard(1).st.gpu.total();
        assert_eq!(eng.shard(1).st.gpu.free_blocks(), total1 - 30);
        assert!(eng.request_drain(1), "drain must start");
        assert_eq!(eng.shard_phase(1), "draining");
        // One forced control step issues the migration batch...
        eng.autoscale_step_now();
        assert_eq!(
            eng.shard(1).st.gpu.pending_free_blocks(),
            30,
            "victims leave through the pending-free D2H path"
        );
        // ...landing the transfers and one more step retires the shard.
        while eng.pump_next_event() {}
        eng.autoscale_step_now();
        assert_eq!(eng.shard_phase(1), "retired");
        let stats = eng.autoscale_stats().unwrap().clone();
        assert_eq!(stats.shards_retired, 1);
        assert_eq!(stats.drained_app_blocks, 30);
        assert_eq!(stats.shard_lifetimes_us.len(), 1);
        // Source pool fully empty; destination holds exactly the
        // landed KV; the migration ledger balances.
        assert_eq!(eng.shard(1).st.gpu.free_blocks(), total1);
        assert_eq!(eng.shard(1).st.gpu.pending_free_blocks(), 0);
        let st0 = &eng.shard(0).st;
        assert_eq!(
            st0.gpu.total() - st0.gpu.free_blocks(),
            30,
            "all drained blocks landed on the active shard"
        );
        let (migs, blocks, _batches, landed, dropped, max_window) =
            eng.migration_stats();
        assert_eq!(migs, 3);
        assert_eq!(blocks, 30);
        assert_eq!(landed + dropped, 30);
        assert!(max_window <= eng.cfg.migrate_batch_budget_blocks as u64);
        assert_eq!(eng.shard(1).st.stalled_ids.len(), 0);
        assert_eq!(eng.shard(0).st.stalled_ids.len(), 3);
    }

    /// A sole-copy prefix entry on a draining shard relocates into an
    /// active shard's CPU tier instead of being lost.
    #[test]
    fn drain_relocates_sole_prefix_copy() {
        let mut eng = engine(2, 1, 2);
        let key = PrefixKey(0xFEED);
        // A CPU-backed prefix on shard 1 (via the directory's legal
        // insert path), registered as the directory's sole holder.
        assert!(prefix_dir::seed_replica(
            &mut eng.shard_mut(1).st,
            key,
            4,
            64,
            0
        ));
        eng.prefix_dir.apply_event(
            1,
            &PrefixEvent::Inserted {
                key,
                blocks: 4,
                tokens: 64,
                location: PrefixLocation::Cpu,
            },
        );
        assert_eq!(eng.shard(1).st.prefix.resident_cpu_blocks(), 4);
        assert!(eng.request_drain(1));
        eng.autoscale_step_now();
        // Local backing freed immediately (wire-captured), replica in
        // flight toward shard 0.
        assert_eq!(eng.shard(1).st.prefix.resident_cpu_blocks(), 0);
        assert_eq!(eng.shard(1).st.cpu.used_blocks(), 0);
        while eng.pump_next_event() {}
        assert_eq!(
            eng.shard(0).st.prefix.resident_cpu_blocks(),
            4,
            "sole copy must land on the surviving shard"
        );
        assert_eq!(
            eng.shard(0).st.prefix.location_of(key),
            Some(PrefixLocation::Cpu)
        );
        eng.autoscale_step_now();
        assert_eq!(eng.shard_phase(1), "retired");
        let stats = eng.autoscale_stats().unwrap();
        assert_eq!(stats.drained_prefix_blocks, 4);
        assert_eq!(stats.drained_prefix_dropped_blocks, 0);
    }

    /// High pressure grows: a warming shard joins only after the
    /// modeled warm-up elapses, and never past `max_shards`.
    #[test]
    fn controller_grows_under_pressure_with_warmup() {
        let mut eng = engine(1, 1, 2);
        // Saturate shard 0 well past the grow watermark.
        let total = eng.shard(0).st.gpu.total();
        let fill = (total as f64 * 0.95) as u32;
        let AllocOutcome::Granted { .. } =
            eng.shard_mut(0).st.gpu.alloc(fill, Route::Shared)
        else {
            panic!()
        };
        eng.autoscale_step_now();
        assert_eq!(eng.shard_phase(1), "warming");
        assert_eq!(
            eng.autoscale_stats().unwrap().scale_up_events,
            1
        );
        // Still warming: not placeable, and growth is capped at max.
        eng.autoscale_step_now();
        assert_eq!(
            eng.autoscale_stats().unwrap().scale_up_events,
            1,
            "provisioned count includes the warming shard"
        );
        assert!(eng.pump_next_event(), "warm-up event pending");
        assert_eq!(eng.shard_phase(1), "active");
    }

    /// Pressure returning mid-drain cancels the drain — the shard
    /// resumes serving instead of finishing the evacuation. (An *empty*
    /// draining shard would just retire; the stalled app keeps this one
    /// mid-evacuation when the signal flips.)
    #[test]
    fn drain_cancels_when_pressure_returns() {
        let mut eng = engine(2, 1, 2);
        stalled_app_on(&mut eng, 1, 10);
        // Saturate the other shard past the grow watermark.
        let total = eng.shard(0).st.gpu.total();
        let fill = (total as f64 * 0.95) as u32;
        let AllocOutcome::Granted { .. } =
            eng.shard_mut(0).st.gpu.alloc(fill, Route::Shared)
        else {
            panic!()
        };
        assert!(eng.request_drain(1));
        assert_eq!(eng.shard_phase(1), "draining");
        eng.autoscale_step_now();
        assert_eq!(
            eng.shard_phase(1),
            "active",
            "returning pressure must cancel the drain"
        );
        assert_eq!(eng.autoscale_stats().unwrap().drain_cancels, 1);
    }

    /// An empty draining shard retires on the first control step —
    /// there is nothing to evacuate.
    #[test]
    fn empty_drain_retires_immediately() {
        let mut eng = engine(2, 1, 2);
        assert!(eng.request_drain(1));
        eng.autoscale_step_now();
        assert_eq!(eng.shard_phase(1), "retired");
        assert_eq!(eng.autoscale_stats().unwrap().shards_retired, 1);
    }

    /// The min-shards floor is unconditional: the last active shard
    /// can never drain, even through the forced hook.
    #[test]
    fn min_shards_floor_holds() {
        let mut eng = engine(1, 1, 2);
        assert!(!eng.request_drain(0));
        assert_eq!(eng.shard_phase(0), "active");
    }

    #[test]
    fn predictor_orders_templates_by_call_profile_and_observations() {
        let mut p = LifetimePredictor::new(0.5, 1_000_000);
        let cw = p.register_template(&templates::code_writer());
        let rag = p.register_template(&templates::rag());
        // code-writer's tool-call profile is deeper than rag's.
        assert!(
            p.predicted_lifetime_us(cw) > p.predicted_lifetime_us(rag),
            "static profile must order the templates"
        );
        assert_eq!(p.lifetime_norm(cw), 1.0);
        // Long observed stalls on rag flip the ordering.
        assert!(!p.observations_seeded(rag));
        for _ in 0..8 {
            p.observe(rag, 60_000_000);
        }
        assert!(p.observations_seeded(rag));
        assert!(
            p.predicted_lifetime_us(rag) > p.predicted_lifetime_us(cw)
        );
        assert_eq!(p.lifetime_norm(rag), 1.0);
        assert!(p.lifetime_norm(cw) < 1.0);
    }

    /// Lifetime bias: long-lived templates are steered off the
    /// youngest active shard (the next drain victim).
    #[test]
    fn route_bias_penalizes_young_shards_for_long_lived_templates() {
        let mut a = Autoscaler::new(
            AutoscaleConfig {
                enabled: true,
                ..Default::default()
            },
            2,
            2,
        );
        a.register_template(&templates::code_writer());
        a.activated_at_us[1] = 900_000; // shard 1 is younger
        let bias = a.route_bias(0, 1_000_000);
        assert_eq!(bias[0], 0.0, "oldest shard carries no penalty");
        assert!(
            bias[1] > 0.0,
            "young shard must be penalized for long-lived apps"
        );
        assert!(bias[1] <= LIFETIME_BIAS + 1e-12);
    }
}
